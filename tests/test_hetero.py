"""Heterogeneous CPU co-execution: pricing, execution path, placement,
spec plumbing — and above all bit-identicality guarantees.

  * host_exec=off is the cache-only host tier: fast vs the retained naive
    reference stays bit-identical (Metrics + decision streams), exactly as
    before this feature existed;
  * host_exec=on is *also* bit-identical fast-vs-reference — the hetero
    pricing arm lives in both ``assignment_cost`` and
    ``assignment_cost_ref``, so the cached and naive cost models agree
    while residency churns;
  * the scheduler's CPU arm equals a seeded naive min() recompute at every
    probe: a host-resident expert costs only its promotion settle gap, a
    non-resident one the full disk leg;
  * host-resident experts execute in place: zero load latency, no disk-leg
    transfer, ``exec`` trace events labeled ``on="host"``, and the event
    timeline still reconciles against ``Metrics`` (<1%);
  * ``host_place`` lets the placement search plan deliberate CPU residents
    and is never worse than the greedy seed, while host_place=off keeps
    the search's RNG stream and results unchanged;
  * DeploymentSpec carries the knob group losslessly and validates the
    cross-field constraints eagerly.
"""
import dataclasses

import pytest

from conftest import run_board_system, strip_wall_clock
from repro.core import COSERVE, TierSpec
from repro.core.engines import SimEngine
from repro.core.workload import BoardSpec, device_profile
from repro.fleet import SearchConfig, replay_cost, search_placement, \
    trace_from_counts
from repro.obs import Tracer
from repro.obs.timeline import reconcile

MB = 1 << 20

HOST_EXEC = dataclasses.replace(COSERVE, host_exec=True)

# thrashy enough that the CPU arm actually wins sometimes: small pools,
# modest disk, Zipf-hot catalog with a long host-resident tail
HET_BOARD = BoardSpec(name="HQ", n_components=60, n_active=36,
                      avg_quantity=3.0, n_detection=8, zipf_s=1.6)
HET_TIER = TierSpec(name="het_numa", disk_bw=530e6, host_to_device_bw=12e9,
                    unified=False, host_cache_bytes=8 << 30,
                    device_bytes=4 << 30)


def run_system(seed, policy=COSERVE, reference=False, decisions=None,
               tracer=None, sim_hook=None, n_requests=250):
    """This suite's operating point over the shared conftest builder."""
    return run_board_system(HET_BOARD, HET_TIER, seed=seed, policy=policy,
                            reference=reference, decisions=decisions,
                            tracer=tracer, sim_hook=sim_hook,
                            n_requests=n_requests)


# --------------------------------------------------------------------------- #
# bit-identicality: off and on, fast vs naive reference
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("policy", [COSERVE, HOST_EXEC],
                         ids=["host_exec_off", "host_exec_on"])
def test_metrics_bit_identical_to_reference(seed, policy):
    fast, _ = run_system(seed, policy=policy)
    ref, _ = run_system(seed, policy=policy, reference=True)
    assert strip_wall_clock(fast) == strip_wall_clock(ref)


@pytest.mark.parametrize("seed", range(3))
def test_host_exec_on_decision_stream_matches_reference(seed):
    fast_log, ref_log = [], []
    run_system(seed, policy=HOST_EXEC, decisions=fast_log)
    run_system(seed, policy=HOST_EXEC, decisions=ref_log, reference=True)
    assert fast_log == ref_log
    assert len(fast_log) >= 250


def test_host_exec_changes_behavior_at_all():
    """Guard against the flag silently wiring to nothing: on a pressured
    tier (slow PCIe, small pools) the CPU arm must win sometimes, so on vs
    off must differ."""
    tight = dataclasses.replace(HET_TIER, name="tight",
                                host_to_device_bw=2e9, device_bytes=2 << 30)
    results = []
    for policy in (COSERVE, HOST_EXEC):
        m, _ = run_board_system(HET_BOARD, tight, policy=policy)
        results.append(strip_wall_clock(m))
    assert results[0] != results[1]


# --------------------------------------------------------------------------- #
# the min() arm: scheduler pricing equals a naive seeded recompute
# --------------------------------------------------------------------------- #

def test_assignment_cost_cpu_arm_matches_naive_recompute_under_churn():
    probes = []

    def hook(sim, system):
        h = system.hierarchy
        coe = system.coe

        def probe(s, now):
            for eid in list(coe.experts)[::4]:
                fast = h.assignment_cost(eid, now, device="cpu")
                ref = h.assignment_cost_ref(eid, now, device="cpu")
                # the naive two-arm min() recompute, from first principles
                if h.host is not None and eid in h.host:
                    naive = max(0.0, h.host.ready_time(eid) - now)
                else:
                    naive = ref      # disk leg: backlog model is private,
                    #                  assignment_cost_ref IS the naive loop
                probes.append((eid, fast, ref, naive))

        sim.add_ticker(0.05, probe)

    run_system(0, policy=HOST_EXEC, sim_hook=hook)
    assert len(probes) > 100
    for eid, fast, ref, naive in probes:
        assert fast == ref == naive, eid
    # residency churn must have exercised BOTH arms
    assert any(naive == 0.0 for _, _, _, naive in probes)
    assert any(naive > 0.0 for _, _, _, naive in probes)


def test_host_resident_cost_is_zero_only_when_enabled():
    _, system = run_system(0, policy=COSERVE, n_requests=40)
    h = system.hierarchy
    resident = [eid for eid in system.coe.experts if h.in_host(eid)]
    assert resident
    eid = resident[0]
    later = 1e6                      # any in-flight promotion long settled
    assert h.assignment_cost(eid, later, device="cpu") > 0.0
    h.host_exec_enabled = True
    assert h.assignment_cost(eid, later, device="cpu") == 0.0
    assert h.assignment_cost_ref(eid, later, device="cpu") == 0.0


# --------------------------------------------------------------------------- #
# the execution path: in place from DRAM, no disk leg
# --------------------------------------------------------------------------- #

def _cpu_system(host_exec: bool):
    from conftest import build_board_system
    policy = HOST_EXEC if host_exec else COSERVE
    return build_board_system(HET_BOARD, HET_TIER, n_gpu=1, n_cpu=1,
                              policy=policy)


def test_sim_engine_host_resident_load_is_free():
    # warm the host tier with a short run, then probe the engine directly
    m, system = run_system(0, policy=HOST_EXEC, n_requests=40)
    engine = system.engine
    assert isinstance(engine, SimEngine)
    cpu_ex = next(e for e in system.executors if e.device == "cpu")
    h = system.hierarchy
    resident = [eid for eid in system.coe.experts if h.in_host(eid)]
    assert resident
    assert engine.load_latency(cpu_ex, resident[0]) == 0.0
    # same expert, co-execution off: the full host-load prediction
    m2, off = run_system(0, policy=COSERVE, n_requests=40)
    off_cpu = next(e for e in off.executors if e.device == "cpu")
    off_resident = [eid for eid in off.coe.experts
                    if off.hierarchy.in_host(eid)]
    assert off_resident
    assert off.engine.load_latency(off_cpu, off_resident[0]) > 0.0


def test_begin_host_load_hit_is_an_instant_settled_transfer():
    m, system = run_system(0, policy=HOST_EXEC, n_requests=40)
    h = system.hierarchy
    resident = [eid for eid in system.coe.experts if h.in_host(eid)]
    assert resident
    disk = h.topology.disk_channel
    now = max(1e6, disk.busy_until + 1.0)      # quiet, long-settled instant
    before = (disk.transfers, disk.busy_until)
    t = h.begin_host_load(resident[0], now=now)
    assert t.issued == t.start == now
    assert t.done == now                       # settled: executes in place
    # no disk-channel occupancy was booked for the hit
    assert (disk.transfers, disk.busy_until) == before


def test_exec_events_labeled_host_and_device():
    tracer = Tracer(level="full")
    m, system = run_system(0, policy=HOST_EXEC, tracer=tracer)
    execs = [e for e in tracer.events if e.kind == "exec"]
    assert execs
    assert all(e.attrs.get("on") in ("host", "device") for e in execs)
    by_on = {on: [e for e in execs if e.attrs["on"] == on]
             for on in ("host", "device")}
    assert by_on["host"] and by_on["device"]
    cpu_ids = {e.id for e in system.executors if e.device == "cpu"}
    assert {e.actor for e in by_on["host"]} <= cpu_ids


def test_timeline_reconciles_with_host_exec_on():
    tracer = Tracer(level="full", capacity=200_000)
    m, system = run_system(0, policy=HOST_EXEC, tracer=tracer)
    rec = reconcile(tracer.events, m)
    assert rec["completed_events"] == m.completed
    assert abs(rec["avg_latency_delta"]) < 1e-6
    stall = rec["stall_metrics_s"]
    assert abs(rec["stall_events_s"] - stall) <= max(1e-6, 0.01 * stall)


# --------------------------------------------------------------------------- #
# placement: deliberate CPU residents (host_place)
# --------------------------------------------------------------------------- #

def _place_fixture(seed=0):
    import numpy as np
    from repro.core import CoEModel, ExpertSpec, RoutingModule
    rng = np.random.RandomState(seed)
    coe = CoEModel([ExpertSpec(id=f"e{i:03d}", arch="resnet101",
                               mem_bytes=100 * MB,
                               usage_prob=float(rng.rand()))
                    for i in range(14)],
                   RoutingModule(lambda d: "e000"))
    caps = {"g0": 500 * MB, "g1": 500 * MB, "cpu": 600 * MB}
    pool_devices = {"g0": "gpu", "g1": "gpu", "cpu": "cpu"}
    counts = {e: float(rng.exponential(10.0)) for e in coe.experts}
    trace = trace_from_counts(counts, length=150, exec_s=0.006)
    return coe, caps, pool_devices, trace


@pytest.mark.parametrize("seed", range(3))
def test_host_place_never_worse_and_cost_is_exact(seed):
    coe, caps, pool_devices, trace = _place_fixture(seed)
    cfg = SearchConfig(iterations=150, seed=seed, replication=1,
                       host_place=True, host_exec_factor=12.0)
    res = search_placement(coe, caps, trace, HET_TIER, links="per-device",
                           pool_devices=pool_devices, config=cfg)
    assert res.cost <= res.seed_cost + 1e-9
    assert res.cost == replay_cost(
        coe, caps, res.plan, trace, HET_TIER, links="per-device",
        pool_devices=pool_devices, host_groups=["cpu"],
        host_exec_s=12.0 * trace.exec_s)


@pytest.mark.parametrize("seed", range(3))
def test_host_place_off_is_unchanged_by_the_feature(seed):
    """host_place=False must not perturb the search: same RNG stream, same
    proposals, same plan as a config that never heard of host groups."""
    coe, caps, pool_devices, trace = _place_fixture(seed)
    caps = {g: c for g, c in caps.items() if g != "cpu"}
    pool_devices = {g: d for g, d in pool_devices.items() if g != "cpu"}
    base = search_placement(
        coe, caps, trace, HET_TIER, links="per-device",
        pool_devices=pool_devices,
        config=SearchConfig(iterations=120, seed=seed, replication=1))
    feat = search_placement(
        coe, caps, trace, HET_TIER, links="per-device",
        pool_devices=pool_devices,
        config=SearchConfig(iterations=120, seed=seed, replication=1,
                            host_place=True, host_exec_factor=12.0))
    # no host-capable groups exist -> host_place must be a strict no-op
    assert base.proposed == feat.proposed
    assert base.cost == feat.cost
    assert base.plan.assignments == feat.plan.assignments


def test_host_place_can_plan_cpu_residents():
    coe, caps, pool_devices, trace = _place_fixture(1)
    cfg = SearchConfig(iterations=400, seed=1, replication=1,
                       host_place=True, host_exec_factor=3.0)
    res = search_placement(coe, caps, trace, HET_TIER, links="per-device",
                           pool_devices=pool_devices, config=cfg)
    hosted = [eid for eid, groups in res.plan.assignments.items()
              if "cpu" in groups]
    # a cheap CPU (3x device time) makes deliberate residents worthwhile
    assert hosted


# --------------------------------------------------------------------------- #
# spec + build plumbing
# --------------------------------------------------------------------------- #

def test_spec_round_trips_hetero_section():
    from repro.api.spec import DeploymentSpec, FleetSection, HeteroSection
    spec = DeploymentSpec(
        fleet=FleetSection(placement="search"),
        hetero=HeteroSection(host_exec=True, cpu_multiplier=9.0,
                             host_place=True))
    assert DeploymentSpec.from_dict(spec.to_dict()) == spec


@pytest.mark.parametrize("kwargs,match", [
    (dict(cpu_multiplier=-1.0), "cpu_multiplier"),
    (dict(host_place=True), "host_place"),       # needs host_exec
])
def test_hetero_section_validation(kwargs, match):
    from repro.api.spec import HeteroSection, SpecError
    with pytest.raises(SpecError, match=match):
        HeteroSection(**kwargs)


def test_spec_cross_field_validation():
    from repro.api.spec import (DeploymentSpec, FleetSection, HeteroSection,
                                PolicySection, SpecError)
    with pytest.raises(SpecError, match="fleet.cpu"):
        DeploymentSpec(fleet=FleetSection(cpu=0),
                       hetero=HeteroSection(host_exec=True))
    with pytest.raises(SpecError, match="samba"):
        DeploymentSpec(policy=PolicySection(name="samba"),
                       hetero=HeteroSection(host_exec=True))
    with pytest.raises(SpecError, match="host_place"):
        DeploymentSpec(hetero=HeteroSection(host_exec=True,
                                            host_place=True))


def test_build_wires_host_exec_through_policy_and_hierarchy():
    from repro.api.build import build_context
    from repro.api.spec import DeploymentSpec, HeteroSection
    ctx = build_context(DeploymentSpec(
        hetero=HeteroSection(host_exec=True, cpu_multiplier=8.0)))
    assert ctx.system.policy.host_exec
    assert ctx.system.hierarchy.host_exec_enabled
    off = build_context(DeploymentSpec())
    assert not off.system.policy.host_exec
    assert not off.system.hierarchy.host_exec_enabled


def test_cpu_multiplier_derives_cpu_service_time_from_device_time():
    gpu = device_profile("gpu", HET_TIER)
    cpu = device_profile("cpu", HET_TIER, cpu_multiplier=8.0)
    for arch, prof in cpu.arch_profiles.items():
        g = gpu.arch_profiles[arch]
        # non-unified tiers carry the seed's 1.1x cross-socket factor on k
        assert prof.k == pytest.approx(g.k * 8.0 * 1.1)
        assert prof.b == pytest.approx(g.b * 8.0)
        assert prof.cpu_exec_latency(4) == prof.cpu_k * 4 + prof.cpu_b


def test_arch_profile_cpu_exec_latency():
    from repro.core.profiler import ArchProfile
    p = ArchProfile(arch="a", k=0.01, b=0.002, mem_bytes=1,
                    act_bytes_per_item=1, max_batch=8,
                    cpu_k=0.08, cpu_b=0.01)
    assert p.cpu_exec_latency(0) == 0.0
    assert p.cpu_exec_latency(3) == pytest.approx(0.08 * 3 + 0.01)
