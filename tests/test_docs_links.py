"""Docs integrity: relative links in README.md and docs/ must resolve
(the same check CI's docs job runs via tools/check_links.py)."""
import importlib.util
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _checker():
    path = os.path.join(ROOT, "tools", "check_links.py")
    spec = importlib.util.spec_from_file_location("check_links", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist():
    for name in ("architecture.md", "placement.md", "serving.md",
                 "benchmarks.md"):
        assert os.path.exists(os.path.join(ROOT, "docs", name)), name


def test_no_dead_relative_links():
    mod = _checker()
    broken = []
    for md in mod.iter_markdown([os.path.join(ROOT, "README.md"),
                                 os.path.join(ROOT, "docs")]):
        broken.extend(mod.dead_links(md))
    assert broken == []


def test_checker_handles_titles_and_ignores_code_fences(tmp_path):
    mod = _checker()
    md = tmp_path / "x.md"
    md.write_text('[a](missing.md "title")\n\n```\n[b](also/missing.md)\n```\n')
    broken = mod.dead_links(str(md))
    assert [t for _, t in broken] == ["missing.md"]


def test_readme_points_at_docs():
    with open(os.path.join(ROOT, "README.md"), encoding="utf-8") as f:
        text = f.read()
    for target in ("docs/architecture.md", "docs/placement.md",
                   "docs/benchmarks.md"):
        assert target in text, f"README must link {target}"


def test_benchmarks_doc_covers_every_registered_suite():
    """docs/benchmarks.md must name every key in the benchmarks.run
    registry — the registry is the source of truth, the doc follows it."""
    import sys
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import SUITES
    finally:
        sys.path.pop(0)
    with open(os.path.join(ROOT, "docs", "benchmarks.md"),
              encoding="utf-8") as f:
        text = f.read()
    missing = [k for k in SUITES if f"`{k}`" not in text]
    assert not missing, f"docs/benchmarks.md omits suites: {missing}"


def test_suite_help_generated_from_registry():
    import sys
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import SUITES, suite_help
    finally:
        sys.path.pop(0)
    for key in SUITES:
        assert key in suite_help()