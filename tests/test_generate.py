"""Autoregressive generation + mini multi-device dry-run guards."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import sampling, transformer


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(smoke_config(get_config("starcoder2_3b")),
                              compute_dtype="float32")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_greedy_generation_matches_teacher_forcing(tiny):
    """Greedy generate() must reproduce argmax decoding of the full forward
    at every step (prefill + ring-cache decode path end to end)."""
    cfg, params = tiny
    prompt = jnp.asarray(np.random.RandomState(0).randint(0, 500, (2, 8)),
                         jnp.int32)
    n_new = 5
    out = sampling.generate(params, prompt, cfg, max_new_tokens=n_new)
    assert out.shape == (2, n_new)
    seq = prompt
    for i in range(n_new):
        logits, _ = transformer.forward(params, seq, cfg, mode="eval")
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(out[:, i]), np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


def test_temperature_sampling_respects_top_k(tiny):
    cfg, params = tiny
    logits = jnp.asarray([[0.0, 10.0, 9.0, -5.0]])
    for seed in range(10):
        tok = sampling.sample_token(logits, jax.random.PRNGKey(seed),
                                    temperature=1.0, top_k=2)
        assert int(tok[0]) in (1, 2)


def test_generation_ring_cache_wrap(tiny):
    """Cache narrower than prompt+new tokens: the ring must wrap without
    shape errors or NaNs (sliding-window semantics)."""
    cfg, params = tiny
    cfg = dataclasses.replace(cfg, sliding_window=12)
    prompt = jnp.asarray(np.random.RandomState(1).randint(0, 500, (1, 10)),
                         jnp.int32)
    out = sampling.generate(params, prompt, cfg, max_new_tokens=8,
                            cache_width=12)
    assert out.shape == (1, 8)
    assert (np.asarray(out) >= 0).all()


# --------------------------------------------------------------------------- #
# mini multi-device dry-run (subprocess: needs its own XLA_FLAGS)
# --------------------------------------------------------------------------- #

MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_config
from repro.models import transformer
from repro.sharding.logical import rules_for
from repro.sharding.partition import param_shardings
from repro.training.optimizer import adamw_init
from repro.training.train_loop import make_train_step

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = dataclasses.replace(smoke_config(get_config("mixtral_8x22b")),
                          remat=False)
rules = rules_for(cfg, mesh, "train")
abstract = transformer.abstract_params(cfg)
p_shard = param_shardings(abstract, transformer.param_axes(cfg), mesh, rules)
opt = jax.eval_shape(lambda p: adamw_init(p), abstract)
opt_shard = type(opt)(step=NamedSharding(mesh, P()),
                      mu=p_shard, nu=p_shard)
batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
b_shard = {k: NamedSharding(mesh, P(("pod", "data"))) for k in batch}
step = make_train_step(cfg)
lowered = jax.jit(step, in_shardings=(p_shard, opt_shard, b_shard),
                  donate_argnums=(0, 1)).lower(abstract, opt, batch)
compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):   # pre-0.4.31 jax: one dict per device
    ca = ca[0]
assert ca.get("flops", 0) > 0
print("MINI_DRYRUN_OK")
"""


def test_mini_multipod_dryrun_compiles():
    """A 2x2x2 'pod/data/model' mesh must lower+compile the MoE smoke config
    end to end — the CI-speed version of the production dry-run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MINI_DRYRUN_OK" in out.stdout, out.stderr[-2000:]
