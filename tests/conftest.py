import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS before importing jax; never set device-count flags globally here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import pytest  # noqa: E402

from repro.core import COSERVE, CoServeSystem, Simulation  # noqa: E402
from repro.core.reference import apply_reference  # noqa: E402
from repro.core.workload import (BoardSpec, build_board_coe,  # noqa: E402
                                 make_executor_specs, make_task_requests)
from repro.memory import NUMA  # noqa: E402

# --------------------------------------------------------------------------- #
# shared small-system builder: every suite that drives a board catalog over a
# tier (simperf/hetero/fleet equivalence, decode) builds through here instead
# of hand-wiring CoServeSystem + Simulation its own way
# --------------------------------------------------------------------------- #

SMALL_BOARD = BoardSpec(name="S", n_components=20, n_active=12,
                        n_detection=4)


def build_board_system(board, tier, n_gpu=3, n_cpu=1, *, policy=COSERVE,
                       links="shared", replication=0, seed=0, tracer=None,
                       decode=None, cpu_multiplier=0.0, gpu_pool_bytes=None):
    """One board catalog on one tier: (pools, specs) from the seed layout
    helper, wired into a CoServeSystem. ``decode`` takes a DecodeConfig for
    token-level runs (None = stage-level, the pre-PR-9 behaviour)."""
    coe = build_board_coe(board, seed=seed)
    pools, specs = make_executor_specs(tier, n_gpu, n_cpu,
                                       gpu_pool_bytes=gpu_pool_bytes,
                                       cpu_multiplier=cpu_multiplier)
    return CoServeSystem(coe, specs, pools, policy=policy, tier=tier,
                         links=links, replication=replication,
                         tracer=tracer, decode=decode)


def record_decisions(system, log):
    """Wrap ``system.assign`` to record every scheduling decision: executor
    choice pins assign; the target queue's (expert, size) profile after
    insertion pins the arrange (join/new-group) call."""
    orig_assign = system.assign

    def recording_assign(req, now):
        ex = orig_assign(req, now)
        log.append((req.expert_id, ex.id,
                    tuple((g.expert_id, len(g)) for g in ex.queue)))
        return ex

    system.assign = recording_assign


def run_board_system(board, tier, *, n_requests=250, interval=0.004,
                     request_seed=None, reference=False, decisions=None,
                     sim_hook=None, seed=0, **build_kw):
    """Build + simulate the paper task stream; returns (Metrics, system).

    ``reference`` swaps in the retained naive scheduler/cost paths
    (``apply_reference``) for bit-identicality pairs; ``decisions`` appends
    the recorded assign/arrange stream; ``sim_hook(sim, system)`` runs
    before submission (tickers, failure injections)."""
    system = build_board_system(board, tier, seed=seed, **build_kw)
    if reference:
        apply_reference(system)
    if decisions is not None:
        record_decisions(system, decisions)
    sim = Simulation(system)
    if sim_hook is not None:
        sim_hook(sim, system)
    rs = seed if request_seed is None else request_seed
    sim.submit(make_task_requests(board, n_requests, interval=interval,
                                  seed=rs))
    return sim.run(), system


def strip_wall_clock(m):
    """Metrics minus the wall-clock fields that legitimately differ
    between two otherwise bit-identical runs."""
    d = dataclasses.asdict(m)
    for k in ("wall_s", "sched_time", "mgmt_time"):
        d.pop(k, None)
    for ex in d.get("per_executor", {}).values():
        if isinstance(ex, dict):
            ex.pop("mgmt_time", None)
    return d


@pytest.fixture
def small_system():
    """A compact 2-GPU + 1-CPU board system on the NUMA tier (function
    scope: simulations mutate pool/queue state)."""
    return build_board_system(SMALL_BOARD, NUMA, n_gpu=2, n_cpu=1)
