"""Cost-model placement search + peer-link channel tests: seeded-random
greedy-equivalence (search never scores worse than its seed), replica-budget
and capacity invariants by construction, peer-channel accounting (copies
never bypass backlog pricing), and the online-fleet CLI path."""
import dataclasses

import numpy as np
import pytest

from repro.core import COSERVE, CoEModel, CoServeSystem, ExpertSpec, \
    RoutingModule
from repro.core.workload import device_profile
from repro.core.serving import ExecutorSpec
from repro.fleet import (FleetSpec, PlacementPlan, SearchConfig, build_fleet,
                         replay_cost, search_placement, trace_from_counts,
                         trace_from_requests, trace_from_usage,
                         validate_pool_groups)
from repro.memory import MemoryHierarchy, Residency, TierSpec

MB = 1 << 20

PEER_TIER = TierSpec(name="pt", disk_bw=2000e6, host_to_device_bw=3e9,
                     unified=False, host_cache_bytes=8 << 30,
                     device_bytes=2 << 30, peer_bw=50e9)
NO_PEER_TIER = dataclasses.replace(PEER_TIER, peer_bw=0.0)


def make_coe(n_experts=12, seed=0, mem_bytes=100 * MB, chain=False):
    rng = np.random.RandomState(seed)
    experts = [ExpertSpec(id=f"e{i:03d}", arch="resnet101",
                          mem_bytes=mem_bytes,
                          usage_prob=float(rng.rand()))
               for i in range(n_experts)]
    chain_prob = {"e000": {"e001": 0.9}} if chain and n_experts > 1 else None
    return CoEModel(experts, RoutingModule(lambda d: "e000",
                                           chain_prob=chain_prob))


def two_pool_hierarchy(tier=PEER_TIER, links="per-device"):
    coe = make_coe()
    h = MemoryHierarchy(coe, tier, pools={"gpu0": 500 * MB, "gpu1": 500 * MB},
                        links=links)
    return coe, h


# --------------------------------------------------------------------------- #
# workload traces
# --------------------------------------------------------------------------- #

def test_trace_from_counts_proportional_and_deterministic():
    counts = {"a": 30, "b": 10, "c": 0}
    t1 = trace_from_counts(counts, length=40)
    t2 = trace_from_counts(counts, length=40)
    assert t1.events == t2.events
    w = t1.weights()
    assert w["a"] == 30 and w["b"] == 10 and "c" not in w
    # interleaved, not sorted runs: "b" appears before the last "a"
    assert t1.events.index("b") < len(t1.events) - 1 - \
        t1.events[::-1].index("a")


def test_trace_from_requests_includes_expected_chain():
    from repro.core.coe import Request
    coe = make_coe(chain=True)
    reqs = [Request(id=i, expert_id="e000") for i in range(3)]
    trace = trace_from_requests(coe, reqs, chain_threshold=0.5)
    assert trace.weights() == {"e000": 3, "e001": 3}
    # below-threshold edges are not expanded
    trace_hi = trace_from_requests(coe, reqs, chain_threshold=0.95)
    assert trace_hi.weights() == {"e000": 3}


def test_trace_from_usage_covers_positive_probability_experts():
    coe = make_coe(n_experts=6)
    trace = trace_from_usage(coe, length=60)
    assert set(trace.events) == set(coe.experts)


# --------------------------------------------------------------------------- #
# search: greedy equivalence + invariants
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(8))
def test_search_never_scores_worse_than_greedy_seed_random(seed):
    """Seeded-random equivalence: on any trace, the searched plan's replay
    cost is <= the greedy seed plan's, its capacity/replica invariants hold,
    and a fallback returns the seed plan object itself."""
    rng = np.random.RandomState(seed)
    coe = make_coe(n_experts=int(rng.randint(8, 24)), seed=seed,
                   mem_bytes=int(rng.randint(40, 150)) * MB)
    n_pools = int(rng.randint(1, 4))
    caps = {f"g{p}": int(rng.randint(200, 900)) * MB for p in range(n_pools)}
    counts = {e: float(rng.exponential(10.0)) for e in coe.experts
              if rng.rand() < 0.7}
    trace = trace_from_counts(counts, length=120, exec_s=0.006)
    cfg = SearchConfig(iterations=60, patience=30, seed=seed,
                       replication=int(rng.randint(0, 3)),
                       replica_fraction=float(rng.uniform(0.1, 0.5)))
    seed_plan = PlacementPlan.build(coe, caps)
    res = search_placement(coe, caps, trace, PEER_TIER, links="per-device",
                           seed_plan=seed_plan, config=cfg)
    assert res.cost <= res.seed_cost + 1e-9
    assert res.seed_cost == pytest.approx(
        replay_cost(coe, caps, seed_plan, trace, PEER_TIER,
                    links="per-device"))
    res.plan.validate()
    snap = res.plan.snapshot()
    for g, cap in caps.items():
        assert snap["planned_bytes"].get(g, 0) <= cap
    if res.fell_back:
        assert res.plan is seed_plan
    for eid in coe.experts:
        pools = res.plan.pools_for(eid)
        assert len(set(pools)) == len(pools)


def test_search_replica_bytes_within_budget():
    """Peer-channel replication invariant: a searched plan's replica bytes
    never exceed the configured per-pool replica budget."""
    coe = make_coe(n_experts=10, seed=3)
    caps = {"g0": 500 * MB, "g1": 500 * MB, "g2": 500 * MB}
    frac = 0.3
    trace = trace_from_counts({"e000": 50, "e001": 20, "e002": 10},
                              length=100, exec_s=0.006)
    res = search_placement(
        coe, caps, trace, PEER_TIER, links="per-device",
        config=SearchConfig(iterations=120, seed=1, replication=2,
                            replica_fraction=frac))
    snap = res.plan.snapshot()
    for g, cap in caps.items():
        assert snap["replica_bytes"].get(g, 0) <= int(cap * frac)


def test_search_beats_greedy_on_observed_load_divergence():
    """When observed traffic diverges from the static P(use) the greedy
    sweep placed by, the search must strictly improve and give the truly
    hot expert a device copy."""
    coe = make_coe(n_experts=12, seed=0)     # e000's P(use) is mid-pack
    caps = {"gpu0": 300 * MB, "gpu1": 300 * MB}
    trace = trace_from_counts({"e000": 100, "e001": 5, "e002": 5},
                              length=200, exec_s=0.006)
    seed_plan = PlacementPlan.build(coe, caps)
    assert "e000" not in {e for e, _ in seed_plan.layout()}
    res = search_placement(coe, caps, trace, PEER_TIER, links="per-device",
                           seed_plan=seed_plan,
                           config=SearchConfig(iterations=200, seed=0,
                                               replication=1))
    assert res.cost < res.seed_cost
    assert not res.fell_back
    assert res.plan.pools_for("e000")


def test_from_assignments_rejects_invalid_plans():
    coe = make_coe(n_experts=4)
    caps = {"g0": 250 * MB, "g1": 250 * MB}
    with pytest.raises(ValueError, match="unknown pool"):
        PlacementPlan.from_assignments(coe, caps, {"e000": ["nope"]})
    with pytest.raises(ValueError, match="replica"):
        PlacementPlan.from_assignments(          # replication cap exceeded
            coe, caps, {"e000": ["g0", "g1"]}, replication=0,
            replica_fraction=0.5)
    with pytest.raises(ValueError, match="replica budget"):
        PlacementPlan.from_assignments(          # 100 MB replica vs 25 MB cap
            coe, caps, {"e000": ["g0", "g1"]}, replication=1,
            replica_fraction=0.1)
    with pytest.raises(ValueError, match="overflows pool"):
        PlacementPlan.from_assignments(
            coe, caps, {"e000": ["g0"], "e001": ["g0"], "e002": ["g0"]})
    with pytest.raises(ValueError, match="not in the catalog"):
        PlacementPlan.from_assignments(coe, caps, {"nope": ["g0"]})


def test_observed_load_not_inflated_by_requeued_orphans():
    """A scale-down / failure re-queues queued work through assign();
    expert_load (the rebalance replica signal) must stay one count per
    served stage, not gain a spurious count per re-queue."""
    from repro.core.coe import Request
    from repro.core.profiler import ArchProfile, DeviceProfile

    coe = make_coe(n_experts=3)
    arch = ArchProfile(arch="resnet101", k=0.005, b=0.02, max_batch=8,
                       mem_bytes=100 * MB, act_bytes_per_item=MB,
                       load_latency_host=0.05, load_latency_disk=0.3)
    prof = DeviceProfile(device="gpu", tier=NO_PEER_TIER,
                         arch_profiles={"resnet101": arch})
    specs = [ExecutorSpec("gpu", prof, 64 * MB, "gpu"),
             ExecutorSpec("gpu", prof, 64 * MB, "gpu")]
    system = CoServeSystem(coe, specs, {"gpu": 400 * MB}, policy=COSERVE,
                           tier=NO_PEER_TIER)
    victim = system.executors[0]
    for i in range(6):
        req = Request(id=i, expert_id="e001", arrival_time=0.0)
        system.scheduler._arrange(victim, req)   # queue on the victim only
        system.expert_load["e001"] = system.expert_load.get("e001", 0) + 1
    assert system.expert_load["e001"] == 6
    orphans = system.fail_executor(victim, now=0.0)
    assert len(orphans) == 6
    assert system.expert_load.get("e001", 0) == 0
    for r in orphans:                            # re-assignment re-counts once
        system.assign(r, 0.0)
    assert system.expert_load["e001"] == 6


def test_rebalance_orders_replicas_by_observed_load():
    """Observed per-expert load re-ranks who claims replica slots: the
    statically-cold but observed-hot expert wins the budget."""
    coe = make_coe(n_experts=6, seed=2)
    caps = {"g0": 400 * MB, "g1": 400 * MB}
    cold = min(coe.experts.values(), key=lambda e: e.usage_prob).id
    base = PlacementPlan.build(coe, caps)              # primaries only
    assign = {e: list(base.pools_for(e)) for e in base.assignments}
    plan = PlacementPlan.from_assignments(coe, caps, assign, replication=1,
                                          replica_fraction=0.3)
    new = plan.rebalance({"g0": 1.0, "g1": 1.0},
                         expert_weights={cold: 1000.0})
    assert new and new[0][0] == cold


# --------------------------------------------------------------------------- #
# peer-channel accounting
# --------------------------------------------------------------------------- #

def test_peer_copy_rides_peer_channel_only():
    coe, h = two_pool_hierarchy()
    h.pools["gpu0"].add("e000")
    h.pools["gpu0"].ready.add("e000")
    assert h.peer_source("e000", "gpu1") == "gpu0"
    assert h.peer_source("e000", "gpu0") is None      # holder needs no copy
    tr = h.begin_device_load("e000", 0.0, group="gpu1")
    expect = PEER_TIER.peer_overhead + 100 * MB / PEER_TIER.peer_bw
    assert tr.latency == pytest.approx(expect)
    snap = h.transfer.snapshot()
    assert snap["peer_channel"]["transfers"] == 1
    assert snap["pcie_channel"]["transfers"] == 0
    assert snap["disk_channel"]["transfers"] == 0


def test_peer_copies_serialize_on_destination_ingress():
    """Two same-instant copies into one pool queue FIFO on its peer ingress
    link (no free bandwidth), while a copy into a different pool proceeds
    concurrently."""
    coe, h = two_pool_hierarchy()
    for eid in ("e000", "e001"):
        h.pools["gpu0"].add(eid)
        h.pools["gpu0"].ready.add(eid)
    t1 = h.begin_device_load("e000", 0.0, group="gpu1")
    t2 = h.begin_device_load("e001", 0.0, group="gpu1")
    assert t2.start == pytest.approx(t1.done)
    assert t2.latency == pytest.approx(2 * t1.latency)


def test_peer_backlog_prices_assignment_cost():
    """Peer copies never bypass backlog pricing: a backlogged peer ingress
    link shows up in link_backlog, assignment_cost and the speculation
    gate, exactly like the PCIe/SSD channels."""
    coe, h = two_pool_hierarchy()
    h.pools["gpu0"].add("e000")
    h.pools["gpu0"].ready.add("e000")
    h.topology.peer_for("gpu1").busy_until = 5.0
    assert h.link_backlog("e000", 0.0, "gpu1") == pytest.approx(5.0)
    expect = PEER_TIER.peer_overhead + 100 * MB / PEER_TIER.peer_bw
    assert h.assignment_cost("e000", 0.0, group="gpu1") \
        == pytest.approx(5.0 + expect)
    assert h.load_backlog("e000", 0.0, group="gpu1") == pytest.approx(5.0)
    assert not h.speculation_ok("e000", 0.0, "gpu1")
    # the holder's own pool is unaffected by the sibling's ingress queue
    assert h.link_backlog("e000", 0.0, "gpu0") == 0.0


def test_loading_copy_is_not_a_peer_source():
    coe, h = two_pool_hierarchy()
    h.pools["gpu0"].add("e000")
    h.pools["gpu0"].loading["e000"] = 3.0      # in flight, not settled
    assert h.peer_source("e000", "gpu1") is None


def test_no_peer_fabric_falls_back_to_host_path():
    """peer_bw == 0 (every preset): a sibling-resident expert still loads
    over the host/disk path — byte-identical to the pre-peer behaviour."""
    coe, h = two_pool_hierarchy(tier=NO_PEER_TIER)
    h.pools["gpu0"].add("e000")
    h.pools["gpu0"].ready.add("e000")
    assert h.peer_source("e000", "gpu1") is None
    tr = h.begin_device_load("e000", 0.0, group="gpu1")
    t = NO_PEER_TIER
    expect = t.disk_overhead + t.host_overhead + 100 * MB / t.disk_bw \
        + 100 * MB / t.host_to_device_bw
    assert tr.latency == pytest.approx(expect)
    with pytest.raises(ValueError, match="peer"):
        h.topology.peer_for("gpu1")


def test_scheduler_sees_peer_replica_cost():
    """End to end through the scheduler: with the peer fabric, an executor
    whose sibling holds the expert prices the switch at peer-copy cost plus
    the ingress backlog — not at the host-reload cost."""
    from repro.core.profiler import ArchProfile, DeviceProfile
    from repro.core.coe import Request

    coe = make_coe(n_experts=3)
    arch = ArchProfile(arch="resnet101", k=0.005, b=0.02, max_batch=8,
                       mem_bytes=100 * MB, act_bytes_per_item=MB,
                       load_latency_host=0.05, load_latency_disk=0.3)
    prof = DeviceProfile(device="gpu", tier=PEER_TIER,
                         arch_profiles={"resnet101": arch})
    pools = {"gpu0": 220 * MB, "gpu1": 220 * MB}
    specs = [ExecutorSpec("gpu", prof, 64 * MB, "gpu0"),
             ExecutorSpec("gpu", prof, 64 * MB, "gpu1")]
    system = CoServeSystem(coe, specs, pools, policy=COSERVE, tier=PEER_TIER,
                           links="per-device")
    ex_a, ex_b = system.executors
    for pool in system.pools.values():
        for eid in list(pool.resident):
            pool.remove(eid)
    ex_b.pool.add("e000")
    ex_b.pool.ready.add("e000")
    sched = system.scheduler
    peer_cost = PEER_TIER.peer_overhead + 100 * MB / PEER_TIER.peer_bw
    assert sched.switch_cost(ex_a, "e000", now=0.0) \
        == pytest.approx(peer_cost)
    system.hierarchy.topology.peer_for("gpu0").busy_until = 2.0
    assert sched.switch_cost(ex_a, "e000", now=0.0) \
        == pytest.approx(2.0 + peer_cost)


def test_real_engine_routes_peer_loads_to_peer_thread():
    from repro.core.engines import HostStore, RealEngine
    from repro.memory import TierTopology

    coe, h = two_pool_hierarchy()
    h.pools["gpu0"].add("e000")
    h.pools["gpu0"].ready.add("e000")
    engine = RealEngine(coe, HostStore(), apply_fns={})
    engine.bind_topology(h.topology, h)

    class _Pool:
        def __init__(self, group):
            self.group = group

    class _Ex:
        device = "gpu"

        def __init__(self, group):
            self.pool = _Pool(group)

        @property
        def link_group(self):
            return self.pool.group

    ex1 = _Ex("gpu1")
    assert engine._channel_name(ex1, "e000") == "pt/peer[gpu1]"
    # no sibling copy -> the regular PCIe thread
    assert engine._channel_name(ex1, "e001") == "pt/pcie[gpu1]"
    # unbound hierarchy (seed call shape) never routes to peer
    engine2 = RealEngine(coe, HostStore(), apply_fns={})
    engine2.bind_topology(h.topology)
    assert engine2._channel_name(ex1, "e000") == "pt/pcie[gpu1]"


# --------------------------------------------------------------------------- #
# online-fleet CLI
# --------------------------------------------------------------------------- #

def test_online_fleet_cli_smoke():
    from repro.launch.serve import main
    res = main(["--mode", "online", "--devices", "2", "--links", "per-device",
                "--replication", "1", "--peer-bw", "50",
                "--requests", "120", "--rates", "30",
                "--autoscale", "none"])
    assert res["mode"] == "online" and res["devices"] == 2
    assert res["links"] == "per-device"
    assert res["completed"] > 0
    assert res["completed"] + res["shed"] >= 120


def test_online_fleet_cli_search_placement_and_autoscale():
    from repro.launch.serve import main
    res = main(["--mode", "online", "--devices", "2", "--links", "per-device",
                "--placement", "search", "--requests", "80", "--rates", "25",
                "--autoscale", "auto", "--tick", "0.5"])
    assert res["placement_search"]["cost_s"] \
        <= res["placement_search"]["seed_cost_s"] + 1e-9
    assert res["completed"] > 0


def test_real_modes_reject_fleet_flags():
    from repro.launch.serve import main
    with pytest.raises(SystemExit):
        main(["--mode", "real", "--devices", "2"])
    with pytest.raises(SystemExit):
        main(["--mode", "online", "--engine", "real", "--peer-bw", "50"])
    with pytest.raises(SystemExit):
        main(["--mode", "online", "--engine", "real",
              "--placement", "search"])


def test_fleet_replication_via_peer_lowers_materialization_stall():
    """The acceptance scenario in miniature: replicas pulled onto a fresh
    pool through rebalance_placement cost less wall-clock with the peer
    fabric than via host reload."""
    def stall(peer_bw):
        tier = dataclasses.replace(PEER_TIER, peer_bw=peer_bw)
        coe = make_coe(n_experts=16, seed=4)
        fleet = FleetSpec(n_devices=2, gpu_per_device=1, n_cpu=0,
                          links="per-device")
        pools, specs = build_fleet(tier, fleet)
        plan = PlacementPlan.build(coe, pools, pool_order=["gpu0"])
        system = CoServeSystem(coe, specs, pools, policy=COSERVE, tier=tier,
                               links="per-device", placement=plan)
        for spec in coe.by_usage():
            if spec.mem_bytes <= system.hierarchy.host.free_bytes():
                system.hierarchy.host.insert(spec.id)
        system.placement.replication = 1
        system.placement.replica_fraction = 0.5
        now = total = 0.0
        for _ in range(50):
            issued = system.rebalance_placement(now, max_loads=2)
            if not issued:
                break
            for ex, eid, done in issued:
                total += done - now
                now = max(now, done)
            for ex, eid, done in issued:
                ex.finish_load(eid)
        return total

    host_reload = stall(0.0)
    peer = stall(50e9)
    assert host_reload > 0.0
    assert peer < host_reload