"""PR-9 decode-path kernel tests: ``decode_attention`` against independent
oracles under the serving engine's actual operating conditions.

What this adds over the per-kernel sweeps in test_kernels.py:

  * a *full-history* oracle — attention computed over the chronological
    token stream, never over ring slots — so the ring wrap-around math
    (``pos > width``) is checked against first principles, not against
    ``decode_attention_ref``'s own slot arithmetic;
  * incremental consistency: ``RingKVCache`` (the RealEngine's per-request
    cache) appended token by token matches the oracle at every position
    through several wrap-arounds, in both cache dtypes;
  * the cache geometry the models actually emit: shapes and dtypes come
    from ``slot_cache_shape``/``cache_width`` (heads-major [B,Hkv,W,D],
    float32/bfloat16, SWA-bounded width), not hand-picked constants.

Everything runs the Pallas kernel in interpret mode (CPU-only box).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engines import RingKVCache
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.models.config import ModelConfig
from repro.models.kvcache import cache_width, slot_cache_shape

TOL = {"float32": dict(rtol=2e-5, atol=2e-5),
       "bfloat16": dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOL["bfloat16" if np.dtype(dtype).name == "bfloat16"
               else "float32"]


def history_oracle(q, k_hist, v_hist, pos, width, window=0):
    """Attention over the chronological history [Hkv, T, D]: the last
    ``width`` tokens (the ring's capacity), optionally tightened by a
    sliding window. Pure numpy float32; no ring-slot math anywhere."""
    h, d = q.shape
    hkv = k_hist.shape[0]
    lo = max(0, pos - width + 1)
    if window:
        lo = max(lo, pos - window + 1)
    k = np.repeat(k_hist[:, lo:pos + 1].astype(np.float32), h // hkv, axis=0)
    v = np.repeat(v_hist[:, lo:pos + 1].astype(np.float32), h // hkv, axis=0)
    scores = np.einsum("hd,htd->ht", q.astype(np.float32), k) / np.sqrt(d)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("ht,htd->hd", p, v)


def fill_ring(rng, hkv, width, d, pos, dtype):
    """A ring cache [1, Hkv, W, D] holding the last ``width`` tokens of a
    ``pos + 1``-token history, plus the full history for the oracle."""
    t = pos + 1
    k_hist = rng.standard_normal((hkv, t, d)).astype(np.float32)
    v_hist = rng.standard_normal((hkv, t, d)).astype(np.float32)
    k_ring = np.zeros((hkv, width, d), np.float32)
    v_ring = np.zeros((hkv, width, d), np.float32)
    for p in range(max(0, t - width), t):
        k_ring[:, p % width] = k_hist[:, p]
        v_ring[:, p % width] = v_hist[:, p]
    cast = jnp.asarray(k_ring).astype(dtype), jnp.asarray(v_ring).astype(dtype)
    return cast[0][None], cast[1][None], k_hist, v_hist


# --------------------------------------------------------------------------- #
# ring wrap-around vs the full-history oracle
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("pos", [0, 31, 32, 63, 64, 97, 200])
def test_wraparound_matches_full_history_oracle(pos):
    """Positions straddling 1x/2x/6x the ring width: the validity mask must
    select exactly the last ``width`` tokens regardless of how many times
    the ring has wrapped."""
    h, hkv, w, d = 4, 2, 32, 64
    rng = np.random.default_rng(pos)
    k, v, k_hist, v_hist = fill_ring(rng, hkv, w, d, pos, jnp.float32)
    q = rng.standard_normal((h, d)).astype(np.float32)
    out = decode_attention(jnp.asarray(q)[None], k, v, pos, interpret=True)
    want = history_oracle(q, k_hist, v_hist, pos, w)
    np.testing.assert_allclose(np.asarray(out[0], np.float32), want,
                               rtol=2e-5, atol=2e-5)
    # and the ring-math reference agrees with both
    ref_out = ref.decode_attention_ref(jnp.asarray(q)[None], k, v, pos)
    np.testing.assert_allclose(np.asarray(ref_out[0], np.float32), want,
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [8, 16, 31])
@pytest.mark.parametrize("pos", [40, 64, 150])
def test_sliding_window_under_wraparound(window, pos):
    h, hkv, w, d = 4, 2, 32, 64
    rng = np.random.default_rng(7 * pos + window)
    k, v, k_hist, v_hist = fill_ring(rng, hkv, w, d, pos, jnp.float32)
    q = rng.standard_normal((h, d)).astype(np.float32)
    out = decode_attention(jnp.asarray(q)[None], k, v, pos, window=window,
                           interpret=True)
    want = history_oracle(q, k_hist, v_hist, pos, w, window=window)
    np.testing.assert_allclose(np.asarray(out[0], np.float32), want,
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 2), (4, 1), (16, 4)])
def test_gqa_group_sizes_wrapped(h, hkv):
    """MHA through 4x GQA to MQA, all past one wrap-around."""
    w, d, pos = 32, 64, 50
    rng = np.random.default_rng(h * 10 + hkv)
    k, v, k_hist, v_hist = fill_ring(rng, hkv, w, d, pos, jnp.float32)
    q = rng.standard_normal((h, d)).astype(np.float32)
    out = decode_attention(jnp.asarray(q)[None], k, v, pos, interpret=True)
    want = history_oracle(q, k_hist, v_hist, pos, w)
    np.testing.assert_allclose(np.asarray(out[0], np.float32), want,
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# the geometry the models emit: slot_cache_shape / cache_width
# --------------------------------------------------------------------------- #

def _model_cfg(kv_dtype, sliding_window=0):
    return ModelConfig(name="t", family="dense", num_layers=2, d_model=256,
                       num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=128,
                       kv_cache_dtype=kv_dtype, sliding_window=sliding_window)


@pytest.mark.parametrize("kv_dtype", ["float32", "bfloat16"])
def test_kernel_on_slot_cache_shape_emitted_geometry(kv_dtype):
    """Run the kernel on a cache whose shape AND dtype come straight from
    ``slot_cache_shape`` — the layout contract between models and kernel."""
    cfg = _model_cfg(kv_dtype)
    slot = cfg.block_pattern()[0]
    assert slot.mixer == "attn"
    batch, width = 2, 32
    entry = slot_cache_shape(cfg, slot, batch, width)
    assert entry["k"].dtype == jnp.dtype(kv_dtype)
    # one period's [B, Hkv, W, D] — exactly the kernel's cache shape
    k0, v0 = entry["k"][0], entry["v"][0]
    hkv, d = cfg.num_kv_heads, cfg.resolved_head_dim
    assert k0.shape == (batch, hkv, width, d)

    pos = 70                             # wrapped
    rng = np.random.default_rng(3)
    rings = []
    for b in range(batch):
        k, v, k_hist, v_hist = fill_ring(rng, hkv, width, d, pos,
                                         k0.dtype)
        rings.append((k[0], v[0], k_hist, v_hist))
    k = jnp.stack([r[0] for r in rings])
    v = jnp.stack([r[1] for r in rings])
    q = rng.standard_normal((batch, cfg.num_heads, d)).astype(np.float32)
    out = decode_attention(jnp.asarray(q).astype(k0.dtype), k, v, pos,
                           interpret=True)
    for b in range(batch):
        want = history_oracle(q[b], rings[b][2], rings[b][3], pos, width)
        np.testing.assert_allclose(np.asarray(out[b], np.float32), want,
                                   **_tol(k0.dtype))


def test_cache_width_bounds_ring_by_sliding_window():
    cfg = _model_cfg("float32", sliding_window=16)
    assert cache_width(cfg, 1024) == 16
    assert cache_width(cfg, 8) == 8
    full = _model_cfg("float32")
    assert cache_width(full, 1024) == 1024
    # a ring sized by cache_width with the window mask equals the oracle
    w = cache_width(cfg, 1024)
    rng = np.random.default_rng(11)
    pos = 45
    k, v, k_hist, v_hist = fill_ring(rng, 2, w, 64, pos, jnp.float32)
    q = rng.standard_normal((4, 64)).astype(np.float32)
    out = decode_attention(jnp.asarray(q)[None], k, v, pos,
                           window=cfg.sliding_window, interpret=True)
    want = history_oracle(q, k_hist, v_hist, pos, w,
                          window=cfg.sliding_window)
    np.testing.assert_allclose(np.asarray(out[0], np.float32), want,
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# RingKVCache: the RealEngine's incremental path
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_ring_kv_cache_incremental_matches_oracle(dtype):
    """Append token by token through three wrap-arounds; attend at sampled
    positions and compare against the full-history oracle."""
    h, hkv, w, d = 4, 2, 16, 64
    cache = RingKVCache(num_heads=h, num_kv_heads=hkv, head_dim=d,
                        width=w, dtype=dtype)
    rng = np.random.default_rng(0)
    t = 3 * w + 5
    k_hist = rng.standard_normal((hkv, t, d)).astype(np.float32)
    v_hist = rng.standard_normal((hkv, t, d)).astype(np.float32)
    probe_at = {0, 1, w - 1, w, w + 1, 2 * w, t - 1}
    for p in range(t):
        got = cache.append(k_hist[:, p], v_hist[:, p])
        assert got == p == cache.pos
        if p in probe_at:
            q = rng.standard_normal((h, d)).astype(np.float32)
            out = cache.attend(q)
            want = history_oracle(q, k_hist, v_hist, p, w)
            np.testing.assert_allclose(np.asarray(out, np.float32), want,
                                       **_tol(cache.k.dtype))


def test_ring_kv_cache_window_masks_attention():
    h, hkv, w, d = 4, 2, 16, 64
    window = 4
    cache = RingKVCache(num_heads=h, num_kv_heads=hkv, head_dim=d,
                        width=w, window=window)
    rng = np.random.default_rng(1)
    t = 2 * w + 3
    k_hist = rng.standard_normal((hkv, t, d)).astype(np.float32)
    v_hist = rng.standard_normal((hkv, t, d)).astype(np.float32)
    for p in range(t):
        cache.append(k_hist[:, p], v_hist[:, p])
    q = rng.standard_normal((h, d)).astype(np.float32)
    out = cache.attend(q)
    want = history_oracle(q, k_hist, v_hist, t - 1, w, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), want,
                               rtol=2e-5, atol=2e-5)
