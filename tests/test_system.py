"""End-to-end behaviour tests: the paper's serving claims on a scaled-down
circuit-board workload, plus fault-tolerance / elasticity / work-stealing
(deliverable c, integration tier)."""
import dataclasses

import pytest

from repro.core import (COSERVE, COSERVE_EM, COSERVE_EM_RA, COSERVE_NONE,
                        SAMBA, SAMBA_FIFO, SAMBA_PARALLEL, CoServeSystem,
                        Simulation, SystemPolicy, TierSpec)
from repro.core.workload import (BOARD_A, BoardSpec, build_board_coe,
                                 make_executor_specs, make_task_requests)

# scaled-down board: enough experts that the pool thrashes under FCFS+LRU,
# small enough that every policy simulates in well under a second
TEST_BOARD = BoardSpec(name="T", n_components=80, n_active=48,
                       avg_quantity=3.0, n_detection=10, zipf_s=1.6)
TEST_TIER = TierSpec(name="test_numa", disk_bw=530e6, host_to_device_bw=12e9,
                     unified=False, host_cache_bytes=2 << 30,
                     device_bytes=4 << 30)


def run_policy(policy: SystemPolicy, n_requests: int = 600, n_gpu: int = 3,
               n_cpu: int = 1, board: BoardSpec = TEST_BOARD,
               tier: TierSpec = TEST_TIER, injections=None):
    coe = build_board_coe(board)
    if policy.assign == "single":
        n_gpu, n_cpu = 1, 0
    pools, specs = make_executor_specs(tier, n_gpu, n_cpu)
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier)
    sim = Simulation(system)
    sim.submit(make_task_requests(board, n_requests))
    if injections:
        injections(sim, specs)
    return sim.run()


@pytest.fixture(scope="module")
def metrics():
    """Run every policy once; individual tests assert on the shared result."""
    return {p.name: run_policy(p)
            for p in (COSERVE, COSERVE_NONE, COSERVE_EM, COSERVE_EM_RA,
                      SAMBA, SAMBA_FIFO, SAMBA_PARALLEL)}


# --------------------------------------------------------------------------- #
# paper §5.2 — headline claims
# --------------------------------------------------------------------------- #

def test_all_requests_complete(metrics):
    for name, m in metrics.items():
        assert m.completed >= 600, f"{name}: {m.completed} < 600 submitted"


def test_throughput_beats_samba(metrics):
    """Paper: 4.5x–12x over Samba-CoE. The scaled-down board is gentler on
    FCFS+LRU, so require >= 3x here; the full-scale benchmark reproduces the
    paper's range."""
    ratio = metrics["coserve"].throughput / metrics["samba_coe"].throughput
    assert ratio >= 3.0, f"CoServe only {ratio:.2f}x over Samba-CoE"


def test_throughput_beats_samba_parallel(metrics):
    ratio = (metrics["coserve"].throughput
             / metrics["samba_coe_parallel"].throughput)
    assert ratio > 1.3, f"CoServe only {ratio:.2f}x over Samba-CoE Parallel"


def test_switch_reduction(metrics):
    """Paper Fig. 14: 78.5%–93.87% fewer expert switches than Samba-CoE
    Parallel (the executor-matched baseline)."""
    base = metrics["samba_coe_parallel"].switches
    ours = metrics["coserve"].switches
    red = 1 - ours / base
    assert red >= 0.5, f"switch reduction only {red:.0%} ({base}->{ours})"


def test_ablation_ordering(metrics):
    """Paper Fig. 15/16: None -> +EM -> +EM+RA -> full. Every step removes
    expert switches; throughput grows (the EM step's throughput contribution
    is workload-noise-level when prefetch hides the saved loads, so it gets a
    small tolerance — its switch reduction is the direct mechanism)."""
    t = {k: metrics[k].throughput for k in metrics}
    s = {k: metrics[k].switches for k in metrics}
    assert s["coserve_em"] < s["coserve_none"]
    assert s["coserve_em_ra"] < s["coserve_em"]
    assert s["coserve"] <= s["coserve_em_ra"]
    assert t["coserve_em"] >= t["coserve_none"] * 0.95
    assert t["coserve_em_ra"] >= t["coserve_em"] * 1.1
    assert t["coserve"] >= t["coserve_em_ra"] * 1.1
    assert t["coserve"] > t["coserve_none"] * 1.5


def test_scheduling_overhead_small(metrics):
    """Paper Fig. 19: scheduling+management wall time is a small fraction of
    the (virtual) inference makespan — here just assert it is sub-second for
    600 requests (<3% of even a 30s task)."""
    m = metrics["coserve"]
    assert m.sched_time < 1.0
    assert m.mgmt_time < 1.0


def test_uma_tier_also_improves():
    uma = TierSpec(name="test_uma", disk_bw=3000e6, host_to_device_bw=40e9,
                   host_overhead=0.030, unified=True, host_cache_bytes=0,
                   device_bytes=6 << 30)
    co = run_policy(COSERVE, n_gpu=2, n_cpu=1, tier=uma)
    sam = run_policy(SAMBA, tier=uma)
    assert co.throughput / sam.throughput >= 2.0


# --------------------------------------------------------------------------- #
# scheduling invariants on the live system
# --------------------------------------------------------------------------- #

def test_chained_requests_follow_up():
    """Classification 'ok' outcomes on detection-marked components must spawn
    detection-expert requests (the CoE dependency chain); the chain completes
    as ONE request whose final hop carries a parent_id."""
    coe = build_board_coe(TEST_BOARD)
    pools, specs = make_executor_specs(TEST_TIER, 3, 1)
    system = CoServeSystem(coe, specs, pools, policy=COSERVE, tier=TEST_TIER)
    sim = Simulation(system)
    sim.submit(make_task_requests(TEST_BOARD, 200))
    m = sim.run()
    assert m.completed == 200                      # each chain completes once
    chained = [r for r in sim.completed if r.parent_id is not None]
    expected = [r for r in make_task_requests(TEST_BOARD, 200)
                if r.data["needs_detection"] and r.data["outcome"] == "ok"]
    assert len(chained) == len(expected)           # every ok+flagged chains


def test_switch_counts_deterministic():
    a = run_policy(COSERVE, n_requests=300)
    b = run_policy(COSERVE, n_requests=300)
    assert a.switches == b.switches
    assert a.makespan == b.makespan


# --------------------------------------------------------------------------- #
# fault tolerance / elasticity / straggler mitigation
# --------------------------------------------------------------------------- #

def test_executor_failure_requeues_work():
    def inject(sim, specs):
        sim.fail_executor_at(1.0, 0)   # kill a GPU executor mid-task

    m = run_policy(COSERVE, n_requests=400, injections=inject)
    assert m.completed >= 400          # no request lost


def test_failure_of_all_but_one_still_completes():
    def inject(sim, specs):
        sim.fail_executor_at(0.5, 0)
        sim.fail_executor_at(0.7, 1)
        sim.fail_executor_at(0.9, 3)   # leaves one GPU executor

    m = run_policy(COSERVE, n_requests=300, injections=inject)
    assert m.completed >= 300


def test_elastic_add_executor_helps():
    def inject(sim, specs):
        sim.add_executor_at(0.5, specs[0])   # scale out with one more GPU exec

    base = run_policy(COSERVE, n_requests=500, n_gpu=2)
    elastic = run_policy(COSERVE, n_requests=500, n_gpu=2, injections=inject)
    assert elastic.completed >= 500
    assert elastic.makespan <= base.makespan * 1.05


def test_work_stealing_no_loss_and_not_slower():
    steal = dataclasses.replace(COSERVE, work_stealing=True)
    m_steal = run_policy(steal, n_requests=500)
    m_base = run_policy(COSERVE, n_requests=500)
    assert m_steal.completed >= 500
    assert m_steal.makespan <= m_base.makespan * 1.10


def test_lookahead_reordering_no_loss():
    look = dataclasses.replace(COSERVE, lookahead=4)
    m = run_policy(look, n_requests=500)
    assert m.completed >= 500


# --------------------------------------------------------------------------- #
# beyond-paper: cost-benefit eviction
# --------------------------------------------------------------------------- #

def test_cost_benefit_eviction_runs_clean():
    cb = dataclasses.replace(COSERVE, evict="cost_benefit")
    m = run_policy(cb, n_requests=500)
    assert m.completed >= 500


def test_full_scale_board_a_smoke():
    """One full-scale paper task (Board A, 352 experts) through the simulator
    — the benchmark harness runs all four tasks; this guards the scale path."""
    m = run_policy(COSERVE, n_requests=1000, board=BOARD_A,
                   tier=TierSpec(name="numa", unified=False,
                                 host_cache_bytes=16 << 30,
                                 device_bytes=12 << 30))
    assert m.completed >= 1000
    assert m.throughput > 0
