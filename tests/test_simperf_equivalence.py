"""PR-7 fast-path equivalence: the optimized hot paths must be
decision-for-decision identical to the retained naive reference.

Covers the three tentpole fast paths plus the reorder-head satellite:

  * end-to-end: seeded-random runs (10 seeds x shared/per-device links x
    replication on/off) produce bit-identical final ``Metrics`` and
    bit-identical assign/arrange decision streams under ``apply_reference``;
  * mid-run probes: the epoch-validated pending-time cache equals
    ``reference_pending_time`` and the cached ``assignment_cost`` equals
    ``assignment_cost_ref`` at every ticker while residency churns;
  * ``reorder_head``: the queued-expert-index version picks the same slot as
    the per-slot pool rescan and emits a ``sched`` trace event on reorder;
  * delta-scored placement search: never worse than the greedy seed, and the
    reported cost is an *exact* full-replay cost, not an estimate.
"""
import dataclasses

import pytest

from conftest import run_board_system, strip_wall_clock
from repro.core import (COSERVE, CoServeSystem, Group, Simulation,
                        SystemPolicy, TierSpec)
from repro.core.coe import Request
from repro.core.reference import (ReferenceScheduler,
                                  reference_pending_time)
from repro.core.workload import (BoardSpec, build_board_coe,
                                 make_executor_specs, make_task_requests)
from repro.core.serving import ExecutorSpec
from repro.core.workload import device_profile
from repro.fleet import PlacementPlan, SearchConfig, replay_cost, \
    search_placement, trace_from_counts
from repro.obs import Tracer

MB = 1 << 20

# small enough that one paired run costs ~50 ms, thrashy enough that every
# fast path (loads, evictions, peer copies, arranging) is actually exercised
EQ_BOARD = BoardSpec(name="Q", n_components=60, n_active=36,
                     avg_quantity=3.0, n_detection=8, zipf_s=1.6)
EQ_TIER = TierSpec(name="eq_numa", disk_bw=530e6, host_to_device_bw=12e9,
                   unified=False, host_cache_bytes=2 << 30,
                   device_bytes=4 << 30)
PEER_TIER = dataclasses.replace(EQ_TIER, name="eq_peer", peer_bw=50e9)


def run_system(seed, policy=COSERVE, links="shared", replication=0,
               reference=False, decisions=None, sim_hook=None):
    """This suite's operating point over the shared conftest builder."""
    m, _ = run_board_system(EQ_BOARD, EQ_TIER, seed=seed, policy=policy,
                            links=links, replication=replication,
                            reference=reference, decisions=decisions,
                            sim_hook=sim_hook)
    return m


# --------------------------------------------------------------------------- #
# end-to-end bit-identical metrics + decision streams
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("links", ["shared", "per-device"])
@pytest.mark.parametrize("replication", [0, 2])
def test_metrics_bit_identical_to_reference(seed, links, replication):
    fast = run_system(seed, links=links, replication=replication)
    ref = run_system(seed, links=links, replication=replication,
                     reference=True)
    assert strip_wall_clock(fast) == strip_wall_clock(ref)


@pytest.mark.parametrize("policy", [
    SystemPolicy(name="steal", work_stealing=True),
    SystemPolicy(name="look", lookahead=3),
    SystemPolicy(name="look_steal", lookahead=3, work_stealing=True),
])
def test_metrics_bit_identical_beyond_paper_policies(policy):
    """Work stealing and dequeue-time lookahead ride the same fast paths
    (queued-group index, reorder-head index) — equivalence must hold there
    too, not just under the paper's default policy."""
    for seed in (0, 1):
        fast = run_system(seed, policy=policy)
        ref = run_system(seed, policy=policy, reference=True)
        assert strip_wall_clock(fast) == strip_wall_clock(ref)


@pytest.mark.parametrize("seed", range(3))
def test_assign_and_arrange_decisions_bit_identical(seed):
    fast_log, ref_log = [], []
    run_system(seed, links="per-device", replication=2, decisions=fast_log)
    run_system(seed, links="per-device", replication=2, decisions=ref_log,
               reference=True)
    assert fast_log == ref_log
    assert len(fast_log) >= 250          # every arrival was recorded


# --------------------------------------------------------------------------- #
# mid-run cache probes (exact equality, while state churns)
# --------------------------------------------------------------------------- #

def test_pending_time_cache_matches_reference_midrun():
    probes = []

    def hook(sim, system):
        def probe(s, now):
            for ex in system.live_executors():
                probes.append((ex.pending_time(now),
                               reference_pending_time(ex, now)))
        sim.add_ticker(0.05, probe)

    run_system(0, sim_hook=hook)
    assert len(probes) > 50
    for fast, ref in probes:
        assert fast == ref               # bitwise: same summation order


def test_assignment_cost_cache_matches_ref_under_churn():
    """Cached peer-holder resolution vs the naive per-probe pool scan, on a
    peer-capable two-GPU-pool system while loads/evictions churn residency."""
    coe = build_board_coe(EQ_BOARD, seed=0)
    prof = device_profile("gpu", EQ_TIER)
    specs = [ExecutorSpec("gpu", prof, 512 * MB, "gpu0"),
             ExecutorSpec("gpu", prof, 512 * MB, "gpu1")]
    system = CoServeSystem(coe, specs, {"gpu0": 2 << 30, "gpu1": 2 << 30},
                           policy=COSERVE, tier=PEER_TIER,
                           links="per-device", replication=2)
    h = system.hierarchy
    probes = []

    def probe(sim, now):
        for eid in list(coe.experts)[::5]:
            for g in ("gpu0", "gpu1"):
                probes.append((h.assignment_cost(eid, now, group=g,
                                                 device="gpu"),
                               h.assignment_cost_ref(eid, now, group=g,
                                                     device="gpu")))
            probes.append((h.assignment_cost(eid, now, device="cpu"),
                           h.assignment_cost_ref(eid, now, device="cpu")))

    sim = Simulation(system)
    sim.add_ticker(0.05, probe)
    sim.submit(make_task_requests(EQ_BOARD, 250, seed=0))
    sim.run()
    assert len(probes) > 200
    for fast, ref in probes:
        assert fast == ref


# --------------------------------------------------------------------------- #
# reorder_head: index vs per-slot rescan, plus the trace event
# --------------------------------------------------------------------------- #

def _reorder_fixture(tracer=None):
    coe = build_board_coe(EQ_BOARD, seed=0)
    pools, specs = make_executor_specs(EQ_TIER, 1, 0)
    system = CoServeSystem(coe, specs, pools,
                           policy=SystemPolicy(name="look", lookahead=3),
                           tier=EQ_TIER, tracer=tracer)
    ex = system.executors[0]
    resident = [eid for eid in coe.experts if eid in ex.pool]
    cold = [eid for eid in coe.experts if eid not in ex.pool]
    assert resident and len(cold) >= 2
    # head cold, slot 1 cold, slot 2 resident -> reorder must lift slot 2
    for eid in (cold[0], cold[1], resident[0]):
        ex.queue.append(Group(eid, [Request(id=len(ex.queue),
                                            expert_id=eid)]))
    return system, ex


def test_reorder_head_matches_reference_decision():
    fast_sys, fast_ex = _reorder_fixture()
    ref_sys, ref_ex = _reorder_fixture()
    ref_sched = ReferenceScheduler(list(ref_sys.scheduler.executors),
                                   ref_sys.scheduler.policy)
    before = [g.expert_id for g in fast_ex.queue]
    fast_sys.scheduler.reorder_head(fast_ex, now=1.0)
    ref_sched.reorder_head(ref_ex, now=1.0)
    after_fast = [g.expert_id for g in fast_ex.queue]
    after_ref = [g.expert_id for g in ref_ex.queue]
    assert after_fast == after_ref
    assert after_fast != before                 # the reorder actually fired
    assert after_fast[0] == before[2]           # resident slot lifted to head


def test_reorder_head_emits_sched_trace_event():
    tracer = Tracer(level="full")
    system, ex = _reorder_fixture(tracer=tracer)
    system.scheduler.reorder_head(ex, now=1.0)
    evs = [e for e in tracer.events
           if e.kind == "sched" and e.attrs.get("mode") == "reorder"]
    assert len(evs) == 1
    assert evs[0].attrs["executor"] == ex.id
    assert evs[0].attrs["slot"] == 2
    assert evs[0].name == ex.queue[0].expert_id


def test_reorder_head_no_event_when_nothing_to_reorder():
    tracer = Tracer(level="full")
    system, ex = _reorder_fixture(tracer=tracer)
    ex.queue.pop()                              # only cold experts remain
    system.scheduler.reorder_head(ex, now=1.0)
    assert not [e for e in tracer.events if e.kind == "sched"
                and e.attrs.get("mode") == "reorder"]


# --------------------------------------------------------------------------- #
# delta-scored placement search: exact, never worse
# --------------------------------------------------------------------------- #

def _search_fixture(seed=0):
    import numpy as np
    from repro.core import CoEModel, ExpertSpec, RoutingModule
    rng = np.random.RandomState(seed)
    coe = CoEModel([ExpertSpec(id=f"e{i:03d}", arch="resnet101",
                               mem_bytes=100 * MB,
                               usage_prob=float(rng.rand()))
                    for i in range(14)],
                   RoutingModule(lambda d: "e000"))
    caps = {"g0": 500 * MB, "g1": 500 * MB}
    counts = {e: float(rng.exponential(10.0)) for e in coe.experts}
    trace = trace_from_counts(counts, length=150, exec_s=0.006)
    return coe, caps, trace


@pytest.mark.parametrize("seed", range(4))
def test_delta_search_cost_is_exact_replay_not_estimate(seed):
    coe, caps, trace = _search_fixture(seed)
    cfg = SearchConfig(iterations=120, seed=seed, replication=1)
    assert cfg.scoring == "delta"               # the new default
    res = search_placement(coe, caps, trace, PEER_TIER, links="per-device",
                           config=cfg)
    assert res.scoring == "delta"
    assert res.full_replays >= 1
    # the reported cost must be a full-replay number for the returned plan —
    # estimates may only steer proposals, never be reported as the result
    assert res.cost == replay_cost(coe, caps, res.plan, trace, PEER_TIER,
                                   links="per-device")
    assert res.cost <= res.seed_cost + 1e-9


def test_delta_and_full_scoring_both_beat_seed_on_divergence():
    coe, caps, trace = _search_fixture(1)
    results = {}
    for scoring in ("delta", "full"):
        cfg = SearchConfig(iterations=150, seed=1, replication=1,
                           scoring=scoring)
        results[scoring] = search_placement(coe, caps, trace, PEER_TIER,
                                            links="per-device", config=cfg)
    for scoring, res in results.items():
        assert res.cost <= res.seed_cost + 1e-9, scoring
        assert res.scoring == scoring
        assert res.cost == replay_cost(coe, caps, res.plan, trace, PEER_TIER,
                                       links="per-device")


def test_delta_search_respects_time_budget():
    coe, caps, trace = _search_fixture(2)
    cfg = SearchConfig(iterations=100_000, seed=2, time_budget_s=0.25)
    res = search_placement(coe, caps, trace, PEER_TIER, links="per-device",
                           config=cfg)
    # the budget stops the walk long before 100k proposals; the result is
    # still exact and never worse than the seed
    assert res.proposed < 100_000
    assert res.cost <= res.seed_cost + 1e-9
    assert res.cost == replay_cost(coe, caps, res.plan, trace, PEER_TIER,
                                   links="per-device")
