"""Flight recorder (repro.obs): tracer semantics, trace-off neutrality,
deterministic event streams, per-request latency decomposition, Chrome
trace export round-trip, the stall-attribution report and the telemetry
sample-count markers."""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

from repro.api import (BoardSection, DeploymentSpec, FleetSection, ModelSpec,
                       ObservabilitySection, ServingSection, Session,
                       SpecError, TenantSection, WorkloadSection)
from repro.obs import NULL_TRACER, Event, Tracer
from repro.obs.export import (chrome_trace, load_chrome_trace, save_events,
                              validate_chrome_trace)
from repro.obs.timeline import reconcile, request_timelines, stage_records

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# small thrash-prone board so a 250-request run produces loads, evictions
# and transfers in a couple hundred milliseconds of wall time
BOARD = BoardSection(name="OBS", n_components=40, n_active=24,
                     avg_quantity=2.0, n_detection=6, zipf_s=1.4)


def _spec(trace: str = "off", requests: int = 250, trace_path: str = "",
          **obs_kwargs) -> DeploymentSpec:
    return DeploymentSpec(
        model=ModelSpec(kind="board", board=BOARD.name, boards=(BOARD,)),
        fleet=FleetSection(gpu_per_device=2, cpu=1),
        serving=ServingSection(mode="sim"),
        workload=WorkloadSection(requests=requests),
        observability=ObservabilitySection(trace=trace,
                                           trace_path=trace_path,
                                           **obs_kwargs))


def _run(spec: DeploymentSpec):
    sess = Session(spec)
    out = sess.run()
    return sess, out


# --------------------------------------------------------------------------- #
# tracer core
# --------------------------------------------------------------------------- #

def test_tracer_levels_and_guards():
    assert not NULL_TRACER.enabled and not NULL_TRACER.full
    t = Tracer(level="summary")
    assert t.enabled and not t.full
    t = Tracer(level="full")
    assert t.enabled and t.full
    with pytest.raises(ValueError):
        Tracer(level="loud")


def test_ring_buffer_bounds_and_counts_drops():
    t = Tracer(level="full", capacity=8)
    for i in range(20):
        t.emit(i * 0.1, "exec", "gpu0", f"e{i}", dur=0.05)
    assert len(t.events) == 8
    assert t.dropped == 12
    # the ring keeps the NEWEST events
    assert [e.name for e in t.events] == [f"e{i}" for i in range(12, 20)]
    assert t.snapshot()["dropped"] == 12


def test_event_dict_round_trip():
    e = Event(t=1.25, kind="load", actor="gpu0", name="cls001", dur=0.5,
              attrs={"demand": True, "via": "host", "bytes": 123})
    assert Event.from_dict(e.to_dict()) == e


# --------------------------------------------------------------------------- #
# spec surface
# --------------------------------------------------------------------------- #

def test_observability_section_validation():
    with pytest.raises(SpecError):
        ObservabilitySection(trace="loud")
    with pytest.raises(SpecError):
        ObservabilitySection(trace="full", buffer_events=0)
    with pytest.raises(SpecError):
        ObservabilitySection(trace="off", trace_path="t.json")
    ObservabilitySection(trace="summary", trace_path="t.json")   # valid


def test_save_events_requires_enabled_tracer():
    sess = Session(_spec(trace="off"))
    with pytest.raises(RuntimeError, match="observability.trace"):
        sess.save_events("nowhere.json")


# --------------------------------------------------------------------------- #
# trace-off neutrality + determinism
# --------------------------------------------------------------------------- #

def test_trace_off_metrics_byte_identical():
    """Tracing must be observer-only: a trace=full run's metrics and result
    dict match a trace=off run's exactly (wall_s is real time, excluded)."""
    sess_off, out_off = _run(_spec(trace="off"))
    sess_full, out_full = _run(_spec(trace="full"))
    assert json.dumps(out_off, sort_keys=True, default=str) == \
        json.dumps(out_full, sort_keys=True, default=str)
    def _virtual(m) -> dict:
        # wall-clock-measured overhead fields vary run to run regardless of
        # tracing; everything virtual-clock-derived must match exactly
        d = dataclasses.asdict(m)
        for k in ("wall_s", "sched_time", "mgmt_time"):
            d.pop(k)
        for stats in d["per_executor"].values():
            stats.pop("mgmt_time", None)
        return d

    assert _virtual(sess_off.metrics()) == _virtual(sess_full.metrics())
    assert len(sess_off.system.tracer.events) == 0


def test_event_stream_deterministic_under_fixed_seed():
    streams = []
    for _ in range(2):
        sess, _ = _run(_spec(trace="full"))
        streams.append(sess.system.tracer.to_dicts())
    assert streams[0] == streams[1]
    kinds = {e["kind"] for e in streams[0]}
    assert {"load", "exec", "assign", "sched", "xfer"} <= kinds


def test_tracing_overhead_bounded():
    """Recording must stay cheap: a fully-traced run's wall time within a
    generous constant factor of the untraced run's (CI-noise tolerant)."""
    sess_off, _ = _run(_spec(trace="off"))
    sess_full, _ = _run(_spec(trace="full"))
    off, full = sess_off.metrics().wall_s, sess_full.metrics().wall_s
    assert full < off * 3 + 0.5, f"tracing overhead: {off:.4f}s -> {full:.4f}s"


# --------------------------------------------------------------------------- #
# per-request decomposition
# --------------------------------------------------------------------------- #

def test_decomposition_sums_to_e2e():
    sess, _ = _run(_spec(trace="full"))
    events = list(sess.system.tracer.events)
    timelines = request_timelines(events)
    assert timelines
    for root, tl in timelines.items():
        parts = (tl["queue_wait"] + tl["switch_load_wait"]
                 + tl["peer_copy_wait"] + tl["exec"])
        assert abs(parts - tl["e2e"]) < 1e-6, f"root {root}"
        for s in tl["stages"]:
            stage_parts = (s["queue_wait"] + s["switch_load_wait"]
                           + s["peer_copy_wait"] + s["exec"])
            assert abs(stage_parts - (s["end"] - s["arrival"])) < 1e-9
            assert s["queue_wait"] >= -1e-9


def test_decomposition_reconciles_with_metrics():
    sess, _ = _run(_spec(trace="full"))
    m = sess.metrics()
    rec = reconcile(sess.system.tracer.events, m)
    assert rec["completed_events"] == m.completed
    assert abs(rec["avg_latency_delta"]) < 1e-6
    assert abs(rec["stall_events_s"] - rec["stall_metrics_s"]) < 1e-6


def test_stage_records_survive_assign_falloff():
    """Exec events whose assign fell off the ring buffer are skipped, not
    crashed on (truncated traces are still viewable)."""
    ev = [Event(t=1.0, kind="exec", actor="gpu0", name="cls000", dur=0.1,
                attrs={"requests": [7], "n": 1})]
    assert stage_records(ev) == []


# --------------------------------------------------------------------------- #
# Chrome trace export
# --------------------------------------------------------------------------- #

def test_chrome_trace_round_trip(tmp_path):
    sess, _ = _run(_spec(trace="full"))
    path = tmp_path / "trace.json"
    doc = sess.save_events(str(path))
    loaded = load_chrome_trace(str(path))
    assert loaded == doc
    evs = loaded["traceEvents"]
    # executor and channel tracks are announced via metadata events
    threads = {(e["pid"], e["args"]["name"]) for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"}
    exec_tracks = {n for pid, n in threads if pid == 1}
    chan_tracks = {n for pid, n in threads if pid == 2}
    assert any(n.startswith("gpu") for n in exec_tracks)
    assert chan_tracks, "no transfer-channel tracks"
    cats = {e.get("cat") for e in evs if e["ph"] != "M"}
    assert {"exec", "xfer"} <= cats
    # otherData carries the reconciliation inputs
    other = loaded["otherData"]
    assert other["tracer"]["level"] == "full"
    assert other["metrics"]["completed"] == sess.metrics().completed


def test_chrome_trace_demand_stalls_only_on_executor_tracks():
    t = Tracer(level="full")
    t.emit(0.0, "load", "gpu0", "cls000", dur=0.1, demand=True, via="host")
    t.emit(0.2, "load", "gpu0", "cls001", dur=0.1, demand=False, via="host")
    doc = chrome_trace(t.events)
    loads = [e for e in doc["traceEvents"] if e.get("cat") == "load"]
    assert [e["name"] for e in loads] == ["stall:cls000"]


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "name": "x",
                                                "pid": 1, "tid": 1,
                                                "ts": 0.0, "dur": -1}]})
    validate_chrome_trace({"traceEvents": []})   # empty is fine


def test_run_auto_exports_via_trace_path(tmp_path):
    path = tmp_path / "auto.json"
    _run(_spec(trace="full", trace_path=str(path)))
    doc = load_chrome_trace(str(path))
    assert doc["otherData"]["metrics"]["completed"] == 250


def test_truncated_ring_buffer_still_exports(tmp_path):
    sess, _ = _run(_spec(trace="full", buffer_events=64))
    tracer = sess.system.tracer
    assert tracer.dropped > 0 and len(tracer.events) == 64
    path = tmp_path / "truncated.json"
    save_events(tracer, str(path), metrics=sess.metrics())
    assert load_chrome_trace(str(path))["otherData"]["tracer"]["dropped"] \
        == tracer.dropped


# --------------------------------------------------------------------------- #
# online control-plane events (shed / scale / admit)
# --------------------------------------------------------------------------- #

def test_online_gateway_emits_control_events():
    spec = DeploymentSpec(
        model=ModelSpec(kind="tenants"),
        fleet=FleetSection(gpu_per_device=2, cpu=1),
        serving=ServingSection(mode="online", admission="queue_depth",
                               max_queue=20, autoscale="2,4"),
        workload=WorkloadSection(requests=400, tenants=(
            TenantSection(name="hot", board="A", rate=60.0,
                          slo_seconds=2.0),)),
        observability=ObservabilitySection(trace="full"))
    sess, _ = _run(spec)
    kinds = sess.system.tracer.by_kind()
    assert kinds.get("admit", 0) > 0
    assert kinds.get("shed", 0) > 0, "overloaded queue never shed"
    sheds = [e for e in sess.system.tracer.events if e.kind == "shed"]
    assert all(e.actor == "gateway" for e in sheds)


# --------------------------------------------------------------------------- #
# trace_report CLI
# --------------------------------------------------------------------------- #

def test_trace_report_strict_reconciles(tmp_path):
    path = tmp_path / "report_in.json"
    _run(_spec(trace="full", trace_path=str(path)))
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "trace_report.py"),
         str(path), "--strict", "--top", "3"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "stall reconciliation" in proc.stdout
    assert "top experts by demand-stall time" in proc.stdout


# --------------------------------------------------------------------------- #
# telemetry sample counts
# --------------------------------------------------------------------------- #

def test_latency_tracker_marks_low_confidence_tails():
    from repro.serve.telemetry import LatencyTracker
    lt = LatencyTracker()
    for i in range(20):
        lt.add(0.01 * (i + 1))
    snap = lt.snapshot()
    assert snap["count"] == 20
    # 20 samples: p50 has 10 tail samples (ok), p95/p99 have 1 / 0.2
    assert snap["low_confidence"] == ["p95", "p99"]
    for i in range(2000):
        lt.add(0.01)
    assert lt.snapshot()["low_confidence"] == []
