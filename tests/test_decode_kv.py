"""PR-9 KV-residency property tests: paged KV blocks are first-class pool
residents and must obey the same conservation laws as expert weights.

Seeded-random invariants, checked after EVERY decode-runtime mutation and
every demand load (not just at the end of the run):

  * per-pool capacity: ``used_bytes + kv_bytes <= capacity`` always, and
    ``kv_bytes >= 0`` (no phantom frees);
  * no block leaks: after completion — and after a mid-run executor
    failure — every pool ends at ``kv_bytes == 0``, the host-side ledger is
    empty, and no per-request decode state survives;
  * offloaded-then-reloaded KV rides traced ``xfer`` legs
    (op ``kv_offload``/``kv_reload``) whose event counts and byte totals
    equal the runtime's own counters;
  * the per-request timeline decomposition
    (queue/switch-load/kv-reload/decode) still sums to end-to-end within
    1e-6 with decode on, and ``reconcile`` still matches ``Metrics``.
"""
import dataclasses
import random

import pytest

from conftest import run_board_system, strip_wall_clock
from repro.core import COSERVE, TierSpec
from repro.core.decode import DecodeConfig
from repro.core.workload import BoardSpec, build_board_coe
from repro.obs import Tracer
from repro.obs.timeline import reconcile, request_timelines

MB = 1 << 20

KV_BOARD = BoardSpec(name="KQ", n_components=60, n_active=36,
                     avg_quantity=3.0, n_detection=8, zipf_s=1.6)
KV_TIER = TierSpec(name="kv_numa", disk_bw=530e6, host_to_device_bw=12e9,
                   unified=False, host_cache_bytes=8 << 30,
                   device_bytes=4 << 30)

# large-ish blocks + a tight budget so growth, offload, reload and spill all
# fire within a 250-request run
KV_CFG = DecodeConfig(tokens=12, tokens_dist="geometric", block_tokens=4,
                      token_bytes=4 * MB, kv_budget_fraction=0.25,
                      max_decode_batch=6)


def pressured_pool(pressure, seed=0):
    """gpu_pool_bytes for catalog_bytes / pressure (the bench suites'
    memory-pressure knob)."""
    coe = build_board_coe(KV_BOARD, seed=seed)
    total = sum(coe.spec(e).mem_bytes for e in coe.experts)
    return int(total / pressure)


def install_invariant_checks(sim, system, probes):
    """Assert pool conservation after every decode mutation and demand
    load; ``probes`` counts how often the checks actually ran."""
    dec = system.decode

    def check():
        probes.append(1)
        for g, pool in system.pools.items():
            assert pool.kv_bytes >= 0, g
            assert pool.used_bytes >= 0, g
            assert pool.used_bytes + pool.kv_bytes <= pool.capacity, g
        for g, nbytes in dec._host_kv.items():
            assert nbytes >= 0, g

    def wrap(obj, name):
        orig = getattr(obj, name)

        def wrapped(*a, _orig=orig, **kw):
            out = _orig(*a, **kw)
            check()
            return out

        setattr(obj, name, wrapped)

    for name in ("admit", "start_step", "finish_step", "fail_executor"):
        wrap(dec, name)
    for ex in system.executors:
        wrap(ex, "start_load")


def assert_no_leaks(system):
    dec = system.decode
    for g, pool in system.pools.items():
        assert pool.kv_bytes == 0, g
    assert all(v == 0 for v in dec._host_kv.values())
    assert not dec.states
    assert not dec._inflight
    assert all(not members for members in dec.batch.values())


# --------------------------------------------------------------------------- #
# conservation under pressure, both eviction modes, seeded-random configs
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("kv_evict", ["kv_aware", "weight_only"])
def test_kv_capacity_invariant_and_no_leaks(seed, kv_evict):
    cfg = dataclasses.replace(KV_CFG, kv_evict=kv_evict, seed=seed)
    probes = []
    m, system = run_board_system(
        KV_BOARD, KV_TIER, seed=seed, decode=cfg,
        gpu_pool_bytes=pressured_pool(8.0, seed=seed),
        sim_hook=lambda sim, sys_: install_invariant_checks(sim, sys_,
                                                            probes))
    assert m.completed >= 250
    assert len(probes) > 500             # the checks actually ran
    assert_no_leaks(system)
    assert m.decode["kv"]["peak_kv_bytes"]       # KV was actually resident


@pytest.mark.parametrize("seed", range(5))
def test_random_decode_configs_conserve_blocks(seed):
    """Fuzzed block geometry/budget/batch: conservation must hold for any
    valid config, not just the tuned operating point."""
    rng = random.Random(seed)
    cfg = DecodeConfig(
        tokens=rng.randint(2, 20),
        tokens_dist=rng.choice(["fixed", "geometric"]),
        block_tokens=rng.randint(1, 8),
        token_bytes=rng.choice([256 * 1024, MB, 4 * MB]),
        kv_budget_fraction=rng.uniform(0.1, 0.9),
        kv_evict=rng.choice(["kv_aware", "weight_only"]),
        max_decode_batch=rng.randint(1, 10),
        seed=seed)
    probes = []
    m, system = run_board_system(
        KV_BOARD, KV_TIER, seed=seed, n_requests=150, decode=cfg,
        gpu_pool_bytes=pressured_pool(rng.choice([4.5, 8.0]), seed=seed),
        sim_hook=lambda sim, sys_: install_invariant_checks(sim, sys_,
                                                            probes))
    assert m.completed >= 150
    assert len(probes) > 300
    assert_no_leaks(system)


def test_no_leaks_after_executor_failure():
    """Killing an executor mid-decode must release its members' blocks and
    re-queue the requests: the run still completes everything, leak-free."""
    probes = []

    def hook(sim, system):
        install_invariant_checks(sim, system, probes)
        sim.fail_executor_at(0.25, 0)

    m, system = run_board_system(
        KV_BOARD, KV_TIER, decode=KV_CFG,
        gpu_pool_bytes=pressured_pool(8.0), sim_hook=hook)
    assert m.completed >= 250            # orphans were re-queued and served
    assert_no_leaks(system)
    dead = system.executors[0]
    assert not dead.alive
    assert dead.id not in system.decode.batch \
        or not system.decode.batch[dead.id]


# --------------------------------------------------------------------------- #
# offload/reload ride traced transfer legs
# --------------------------------------------------------------------------- #

def test_offload_and_reload_are_traced_xfer_legs():
    tracer = Tracer(level="full", capacity=500_000)
    m, system = run_board_system(
        KV_BOARD, KV_TIER, decode=KV_CFG, tracer=tracer,
        gpu_pool_bytes=pressured_pool(8.0))
    d = m.decode["kv"]
    assert d["offload_events"] > 0 and d["reload_events"] > 0
    xfers = [e for e in tracer.events if e.kind == "xfer"]
    offs = [e for e in xfers if e.attrs["op"] == "kv_offload"]
    res = [e for e in xfers if e.attrs["op"] == "kv_reload"]
    assert len(offs) == d["offload_events"]
    assert len(res) == d["reload_events"]
    assert sum(e.attrs["bytes"] for e in offs) == d["offload_bytes"]
    assert sum(e.attrs["bytes"] for e in res) == d["reload_bytes"]
    # the legs ride the contended PCIe channels and take real time
    assert all(e.dur > 0.0 for e in offs + res)
    pcie = {ch for ch in (e.actor for e in offs + res)}
    assert all("pcie" in name for name in pcie)


def test_weight_only_mode_never_offloads_kv():
    """weight_only keeps resident KV pinned: no idle-request offloads ever
    fire. Blocks born over budget still spill to host and ride reload legs
    back — spilling is admission-time, not an eviction."""
    cfg = dataclasses.replace(KV_CFG, kv_evict="weight_only")
    m, _ = run_board_system(KV_BOARD, KV_TIER, decode=cfg,
                            gpu_pool_bytes=pressured_pool(8.0))
    assert m.decode["kv"]["offload_events"] == 0
    assert m.decode["kv"]["offload_bytes"] == 0


# --------------------------------------------------------------------------- #
# timeline decomposition stays exact with decode on
# --------------------------------------------------------------------------- #

def test_timeline_decomposition_sums_to_e2e():
    tracer = Tracer(level="full", capacity=500_000)
    m, system = run_board_system(
        KV_BOARD, KV_TIER, decode=KV_CFG, tracer=tracer,
        gpu_pool_bytes=pressured_pool(8.0))
    tls = request_timelines(tracer.events)
    complete = {r: rec for r, rec in tls.items() if rec["complete"]}
    assert len(complete) == m.completed
    for root, rec in complete.items():
        parts = (rec["queue_wait"] + rec["switch_load_wait"]
                 + rec["peer_copy_wait"] + rec["exec"]
                 + rec["decode_wait"] + rec["kv_reload_wait"]
                 + rec["decode_exec"])
        assert abs(parts - rec["e2e"]) < 1e-6, root
    # the decode components are populated, not vacuously zero
    assert any(rec["decode_exec"] > 0 for rec in complete.values())
    assert any(rec["kv_reload_wait"] > 0 for rec in complete.values())


def test_reconcile_matches_metrics_with_decode_on():
    tracer = Tracer(level="full", capacity=500_000)
    m, _ = run_board_system(
        KV_BOARD, KV_TIER, decode=KV_CFG, tracer=tracer,
        gpu_pool_bytes=pressured_pool(8.0))
    rec = reconcile(tracer.events, m)
    assert rec["completed_events"] == m.completed
    assert abs(rec["avg_latency_delta"]) < 1e-6
    stall = rec["stall_metrics_s"]
    assert abs(rec["stall_events_s"] - stall) <= max(1e-6, 0.01 * stall)
