"""Fixture (VIOLATIONS): wall-clock reads and unseeded RNG in a
sim-semantics module — the determinism lint must flag every line marked
below. Never imported; the analyzer reads the source."""
import random
import time


def schedule_deadline(requests):
    t0 = time.time()                 # VIOLATION: wall clock in sim semantics
    rng = random.Random()            # VIOLATION: unseeded RNG
    random.shuffle(requests)         # VIOLATION: hidden global RNG
    return t0, rng
