"""Fixture (VIOLATIONS): iteration over set expressions in a
sim-semantics module — hash order leaks into whatever the loop builds."""


def drain(pending, resident):
    out = []
    for eid in set(pending):                     # VIOLATION: set iteration
        out.append(eid)
    for eid in pending.keys() & resident.keys():  # VIOLATION: view intersection
        out.append(eid)
    return out
