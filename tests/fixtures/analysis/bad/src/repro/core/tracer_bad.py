"""Fixture (VIOLATIONS): an emit with no enabled/full guard and a literal
event kind outside ``EVENT_KINDS`` — the tracer-guard lint must flag both."""


class Decoder:
    def __init__(self, tracer):
        self.tracer = tracer

    def step(self, now):
        self.tracer.emit(now, "exec", "dec0", "step")     # VIOLATION: no guard
        if self.tracer.enabled:
            self.tracer.emit(now, "banana", "dec0", "s")  # VIOLATION: bad kind
