"""Fixture (VIOLATIONS): mutating frozen spec instances — the frozen-spec
lint must flag the ``object.__setattr__`` escape outside ``__post_init__``
and the attribute assignment on a spec-typed variable."""
from repro.api.spec import DeploymentSpec


def force_seed(spec, seed):
    object.__setattr__(spec, "seed", seed)   # VIOLATION: bypasses frozen


def load_and_tweak(d):
    spec = DeploymentSpec.from_dict(d)
    spec.seed = 7                            # VIOLATION: specs are immutable
    return spec
