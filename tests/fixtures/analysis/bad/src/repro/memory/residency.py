"""Fixture (VIOLATIONS): a ``DevicePool`` twin whose ``add`` mutates
epoch-guarded fields without bumping — the epoch-discipline check (part A,
``EPOCH_CLASSES``) must flag it. The module path shadows the real
``repro.memory.residency`` so the registry entry applies.

Source of truth: nothing — fixture file, never imported.
"""


class StateEpoch:
    __slots__ = ("n",)

    def __init__(self):
        self.n = 0

    def bump(self):
        self.n += 1


class DevicePool:
    def __init__(self):
        self.epoch = StateEpoch()
        self.resident = {}
        self.used_bytes = 0

    def add(self, expert_id, nbytes):
        self.resident[expert_id] = nbytes   # VIOLATION: no epoch bump
        self.used_bytes += nbytes

    def remove(self, expert_id):
        self.used_bytes -= self.resident.pop(expert_id)
        self.epoch.bump()

    def touch(self, expert_id):
        pass
