"""Fixture (VIOLATIONS): cross-module mutation of epoch-guarded state
(``EPOCH_FIELDS``) with no bump in the same function — part B of the
epoch-discipline check must flag both functions.

Source of truth: nothing — fixture file, never imported.
"""


def account_kv_offload(pool, nbytes):
    pool.kv_bytes -= nbytes              # VIOLATION: no epoch bump


def splice_group(group, queue, take):
    del group.requests[:take]            # VIOLATION: no queue bump
    return queue
