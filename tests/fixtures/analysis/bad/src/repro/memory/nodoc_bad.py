"""Fixture (VIOLATION): a memory-subsystem module whose docstring never
declares what it owns — the docstring lint requires the ownership line."""

WATERMARK = 0.9
