"""Fixture (CLEAN twin of tracer_bad): direct guard, alias guard, and
registered kinds only — the tracer-guard lint passes all three shapes."""


class Decoder:
    def __init__(self, tracer):
        self.tracer = tracer

    def step(self, now):
        if self.tracer.enabled:
            self.tracer.emit(now, "exec", "dec0", "step")
        traced = self.tracer.full
        if traced:
            self.tracer.emit(now, "decode", "dec0", "tok")
