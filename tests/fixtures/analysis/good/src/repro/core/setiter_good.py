"""Fixture (CLEAN twin of setiter_bad): the same loops under
``sorted(...)``, plus a membership test (never flagged — only iteration
is order-hazardous)."""


def drain(pending, resident):
    out = []
    for eid in sorted(set(pending)):
        out.append(eid)
    for eid in sorted(pending.keys() & resident.keys()):
        out.append(eid)
    if "e0" in set(pending):
        out.append("e0")
    return out
