"""Fixture (CLEAN twin of wallclock_bad): sim time comes in as a
parameter, the RNG is explicitly seeded, and draws go through the owned
instance — the determinism lint must pass this file."""
import random


def schedule_deadline(requests, now, seed):
    rng = random.Random(seed)
    rng.shuffle(requests)
    return now, rng
