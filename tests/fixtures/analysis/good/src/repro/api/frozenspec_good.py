"""Fixture (CLEAN twin of frozenspec_bad): spec derivation through
``dataclasses.replace`` — the frozen-spec lint passes."""
import dataclasses

from repro.api.spec import DeploymentSpec


def load_and_tweak(d, seed):
    spec = DeploymentSpec.from_dict(d)
    return dataclasses.replace(spec, seed=seed)
