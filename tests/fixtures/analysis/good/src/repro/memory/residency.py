"""Fixture (CLEAN twin of bad/.../residency.py): every mutating method of
the ``DevicePool`` twin bumps the epoch, so part A of the epoch-discipline
check passes.

Source of truth: nothing — fixture file, never imported.
"""


class StateEpoch:
    __slots__ = ("n",)

    def __init__(self):
        self.n = 0

    def bump(self):
        self.n += 1


class DevicePool:
    def __init__(self):
        self.epoch = StateEpoch()
        self.resident = {}
        self.used_bytes = 0

    def add(self, expert_id, nbytes):
        self.resident[expert_id] = nbytes
        self.used_bytes += nbytes
        self.epoch.bump()

    def remove(self, expert_id):
        self.used_bytes -= self.resident.pop(expert_id)
        self.epoch.bump()

    def touch(self, expert_id):
        pass
