"""Fixture (CLEAN twin of nodoc_bad).

Source of truth: the eviction watermark constant (fixture only).
"""

WATERMARK = 0.9
