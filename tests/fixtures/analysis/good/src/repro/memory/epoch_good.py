"""Fixture (CLEAN twin of epoch_bad): the same mutations paired with the
bump in the same function — part B of the epoch-discipline check passes.

Source of truth: nothing — fixture file, never imported.
"""


def account_kv_offload(pool, nbytes):
    pool.kv_bytes -= nbytes
    pool.epoch.bump()


def splice_group(group, queue, take):
    del group.requests[:take]
    queue.bump()
    return queue
