"""MoE layer unit tests: virtual-expert EP exactness, capacity, dropless
behaviour for tiny groups (§Perf iterations A3/B4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import moe as moe_lib


def _cfg(**kw):
    base = smoke_config(get_config("mixtral_8x22b"))
    return dataclasses.replace(base, **kw)


def _split_params(p1, e, ff, s):
    """Reshape split=1 weights into the split=s virtual-expert layout."""
    ffv = ff // s
    w_in = p1["w_in"].reshape(e, p1["w_in"].shape[1], 2, s, ffv)
    w_in = jnp.transpose(w_in, (0, 3, 1, 2, 4)).reshape(
        e * s, p1["w_in"].shape[1], 2, ffv)
    w_down = p1["w_down"].reshape(e, s, ffv, p1["w_down"].shape[-1])
    w_down = w_down.reshape(e * s, ffv, p1["w_down"].shape[-1])
    return {"router": p1["router"], "w_in": w_in, "w_down": w_down}


def test_virtual_expert_split_is_exact():
    """split=2 output must equal split=1 bit-for-math: SwiGLU is elementwise
    in ff and the down-projection partial sums add linearly."""
    cfg1 = _cfg(moe_num_experts=4, moe_top_k=2, moe_d_ff=64, moe_ep_split=1)
    cfg2 = dataclasses.replace(cfg1, moe_ep_split=2)
    p1 = moe_lib.init_moe(jax.random.PRNGKey(0), cfg1, jnp.float32)
    p2 = _split_params(p1, 4, 64, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg1.d_model),
                          jnp.float32)
    out1, aux1, load1 = moe_lib.moe_block(p1, x, cfg1, jnp.float32)
    out2, aux2, load2 = moe_lib.moe_block(p2, x, cfg2, jnp.float32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(load1), np.asarray(load2))


def test_virtual_expert_split_larger_batch_with_drops():
    """Exactness must hold through the capacity/drop path too (same tokens
    dropped in both layouts since slots are per-ORIGINAL-expert)."""
    cfg1 = _cfg(moe_num_experts=4, moe_top_k=2, moe_d_ff=64, moe_ep_split=1,
                moe_capacity_factor=1.0)
    cfg2 = dataclasses.replace(cfg1, moe_ep_split=2)
    p1 = moe_lib.init_moe(jax.random.PRNGKey(2), cfg1, jnp.float32)
    p2 = _split_params(p1, 4, 64, 2)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 128, cfg1.d_model),
                          jnp.float32)
    out1, *_ = moe_lib.moe_block(p1, x, cfg1, jnp.float32)
    out2, *_ = moe_lib.moe_block(p2, x, cfg2, jnp.float32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_capacity_dropless_for_tiny_groups():
    cfg = _cfg(moe_num_experts=4, moe_top_k=2)
    assert moe_lib.expert_capacity(32, cfg) == 32        # exactly dropless
    cap = moe_lib.expert_capacity(4096, cfg)
    assert cap == int(np.ceil(4096 * 2 / 4 * cfg.moe_capacity_factor)
                      + 7) // 8 * 8 or cap % 8 == 0
    assert cap < 4096                                    # decode-waste fix A3


def test_capacity_never_exceeds_group():
    cfg = _cfg(moe_num_experts=2, moe_top_k=2, moe_capacity_factor=4.0)
    assert moe_lib.expert_capacity(128, cfg) <= 128


def test_moe_grads_flow_through_split():
    cfg = _cfg(moe_num_experts=4, moe_top_k=2, moe_d_ff=64, moe_ep_split=2)
    p = moe_lib.init_moe(jax.random.PRNGKey(4), cfg, jnp.float32)

    def loss(p, x):
        out, aux, _ = moe_lib.moe_block(p, x, cfg, jnp.float32)
        return jnp.sum(out ** 2) + 0.01 * aux

    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model))
    g = jax.grad(loss)(p, x)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    assert float(jnp.abs(g["w_in"]).sum()) > 0
