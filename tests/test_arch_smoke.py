"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED same-family config — one forward + one train step on CPU, asserting
output shapes and the absence of NaNs. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data import make_batch_for
from repro.models import encdec, transformer
from repro.training import adamw_init
from repro.training.train_loop import make_train_step, make_whisper_train_step


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch, key):
    cfg = smoke_config(get_config(arch))
    b, s = 2, 32
    batch = make_batch_for(cfg, b, s)
    if cfg.is_encoder_decoder:
        params = encdec.init_params(key, cfg)
        logits = encdec.decode_train(
            params, jnp.asarray(batch["tokens"]),
            jnp.asarray(batch["audio_embeds"]), cfg)
    else:
        params = transformer.init_params(key, cfg)
        logits, aux = transformer.forward(
            params, jnp.asarray(batch["tokens"]), cfg,
            positions=jnp.asarray(batch["positions"])
            if "positions" in batch else None)
        assert jnp.isfinite(aux).all()
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch} produced NaNs"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, key):
    cfg = smoke_config(get_config(arch))
    b, s = 2, 16
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, b, s).items()}
    if cfg.is_encoder_decoder:
        params = encdec.init_params(key, cfg)
        step = make_whisper_train_step(cfg)
    else:
        params = transformer.init_params(key, cfg)
        step = make_train_step(cfg)
    opt = adamw_init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch} loss not finite"
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt2.step) == 1
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b_, np.float32))
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, f"{arch}: train step did not update parameters"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, key):
    """Prefill+decode logits must match the teacher-forced forward."""
    cfg = smoke_config(get_config(arch))
    import dataclasses
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    b, s = 2, 16
    batch = make_batch_for(cfg, b, s)
    tokens = jnp.asarray(batch["tokens"])
    if cfg.is_encoder_decoder:
        params = encdec.init_params(key, cfg)
        audio = jnp.asarray(batch["audio_embeds"])
        last, cache = encdec.prefill(params, tokens, audio, cfg, s + 4)
        tok = jnp.argmax(last, -1)[:, None]
        dl, _ = encdec.decode_step(params, tok, s, cache, cfg)
        full = encdec.decode_train(
            params, jnp.concatenate([tokens, tok], 1), audio, cfg)
        np.testing.assert_allclose(np.asarray(dl), np.asarray(full[:, -1]),
                                   rtol=2e-4, atol=2e-4)
        return
    params = transformer.init_params(key, cfg)
    logits, _ = transformer.forward(params, tokens, cfg, mode="eval")
    last, cache = transformer.prefill(params, tokens, cfg, cache_width=s + 4)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)
    tok = jnp.argmax(last, -1)[:, None]
    dl, _ = transformer.decode_step(params, tok, s, cache, cfg)
    full, _ = transformer.forward(
        params, jnp.concatenate([tokens, tok], 1), cfg, mode="eval")
    np.testing.assert_allclose(np.asarray(dl), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
