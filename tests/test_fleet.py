"""Device-fleet topology tests: per-device links, explicit placement /
replication, residency-aware assignment, and the queue-arrival prefetch
trigger (integration with the tiered-memory subsystem)."""
import dataclasses

import numpy as np
import pytest

from conftest import run_board_system
from repro.core import (COSERVE, CoEModel, CoServeSystem, ExpertSpec, Request,
                        RoutingModule, Simulation)
from repro.core.profiler import ArchProfile, DeviceProfile
from repro.core.serving import ExecutorSpec
from repro.core.workload import (BoardSpec, build_board_coe, device_profile,
                                 make_executor_specs, make_task_requests)
from repro.fleet import (FleetSpec, PlacementPlan, build_fleet,
                         validate_pool_groups)
from repro.memory import NUMA, TierSpec, TierTopology

MB = 1 << 20

FLEET_TIER = TierSpec(name="ft", disk_bw=2000e6, host_to_device_bw=3e9,
                      unified=False, host_cache_bytes=8 << 30,
                      device_bytes=2 << 30)


def make_coe(n_experts=12, seed=0, mem_bytes=100 * MB):
    rng = np.random.RandomState(seed)
    experts = [ExpertSpec(id=f"e{i:03d}", arch="resnet101",
                          mem_bytes=mem_bytes,
                          usage_prob=float(rng.rand()))
               for i in range(n_experts)]
    return CoEModel(experts, RoutingModule(lambda d: "e000"))


# --------------------------------------------------------------------------- #
# fleet builder
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("tier", [NUMA, FLEET_TIER], ids=lambda t: t.name)
def test_build_fleet_single_device_matches_seed_layout(tier):
    """One device must reproduce make_executor_specs exactly: the fleet
    subsystem cannot silently move the paper-reproduction trajectory."""
    want_pools, want_specs = make_executor_specs(tier, 3, 1)
    pools, specs = build_fleet(
        tier, FleetSpec(n_devices=1, gpu_per_device=3, n_cpu=1))
    assert pools == want_pools
    assert len(specs) == len(want_specs)
    for got, want in zip(specs, want_specs):
        assert (got.device, got.batch_bytes, got.pool_group) == \
            (want.device, want.batch_bytes, want.pool_group)


def test_build_fleet_multi_device_pools_and_links():
    fleet = FleetSpec(n_devices=4, gpu_per_device=2, n_cpu=0,
                      links="per-device")
    pools, specs = build_fleet(FLEET_TIER, fleet)
    assert sorted(pools) == ["gpu0", "gpu1", "gpu2", "gpu3"]
    assert len(specs) == 8
    # every device owns its own full pool (not a split of one device)
    assert len(set(pools.values())) == 1
    assert pools["gpu0"] == int(FLEET_TIER.device_bytes * 0.75)


def test_fleet_spec_validation():
    with pytest.raises(ValueError):
        FleetSpec(n_devices=0)
    with pytest.raises(ValueError):
        FleetSpec(links="ring")


# --------------------------------------------------------------------------- #
# pool-group device-kind validation (satellite)
# --------------------------------------------------------------------------- #

def test_conflicting_device_kinds_on_one_pool_rejected():
    prof_gpu = device_profile("gpu", NUMA)
    prof_cpu = device_profile("cpu", NUMA)
    specs = [ExecutorSpec("gpu", prof_gpu, 256 * MB, "gpu"),
             ExecutorSpec("cpu", prof_cpu, 256 * MB, "gpu")]
    with pytest.raises(ValueError, match="conflicting"):
        validate_pool_groups(specs)
    coe = make_coe()
    with pytest.raises(ValueError, match="conflicting"):
        CoServeSystem(coe, specs, {"gpu": 1 << 30}, policy=COSERVE, tier=NUMA)


def test_add_executor_validates_pool_membership():
    coe = make_coe()
    prof = device_profile("gpu", NUMA)
    system = CoServeSystem(coe, [ExecutorSpec("gpu", prof, 256 * MB, "gpu")],
                           {"gpu": 1 << 30}, policy=COSERVE, tier=NUMA)
    cpu_prof = device_profile("cpu", NUMA)
    with pytest.raises(ValueError):
        system.add_executor(ExecutorSpec("cpu", cpu_prof, 256 * MB, "gpu"))


def test_pool_membership_surfaced_in_metrics():
    board = BoardSpec(name="T", n_components=20, n_active=12,
                      n_detection=4)
    m, _ = run_board_system(board, NUMA, n_gpu=2, n_cpu=1, n_requests=50,
                            request_seed=1)
    assert m.memory["pool_devices"] == {"gpu": "gpu", "cpu": "cpu"}
    assert "placement" in m.memory
    assert m.memory["placement"]["placed"] > 0


# --------------------------------------------------------------------------- #
# placement plan
# --------------------------------------------------------------------------- #

def test_placement_plan_matches_legacy_round_robin_sweep():
    """replication=0 must reproduce the seed's _initial_placement loop
    bit-for-bit (same pools, same order)."""
    coe = make_coe(n_experts=20, seed=3)
    capacities = {"gpu0": 400 * MB, "gpu1": 350 * MB, "cpu": 250 * MB}
    plan = PlacementPlan.build(coe, capacities)
    # replay the seed's loop
    pools = list(capacities)
    free = dict(capacities)
    want = []
    i = 0
    for spec in coe.by_usage():
        for j in range(len(pools)):
            g = pools[(i + j) % len(pools)]
            if spec.mem_bytes <= free[g]:
                want.append((spec.id, g))
                free[g] -= spec.mem_bytes
                i = (i + j + 1) % len(pools)
                break
    assert plan.layout() == want
    for eid, g in want:
        assert plan.pools_for(eid) == (g,)
        assert plan.replica_count(eid) == 0


def test_system_pools_match_plan_layout():
    """CoServeSystem's warm pools must hold exactly what the plan says."""
    coe = make_coe(n_experts=20, seed=5)
    prof = device_profile("gpu", NUMA)
    pools = {"gpu0": 500 * MB, "gpu1": 500 * MB}
    specs = [ExecutorSpec("gpu", prof, 128 * MB, g) for g in pools]
    system = CoServeSystem(coe, specs, pools, policy=COSERVE, tier=NUMA)
    for g, pool in system.pools.items():
        assert set(pool.resident) == set(system.placement.planned(g))


@pytest.mark.parametrize("seed", range(10))
def test_placement_replicas_respect_capacity_random(seed):
    """Seeded-random invariants: planned bytes never exceed any pool's
    capacity, replicas land on distinct pools, and no expert exceeds its
    replication budget."""
    rng = np.random.RandomState(seed)
    coe = make_coe(n_experts=int(rng.randint(10, 40)), seed=seed,
                   mem_bytes=int(rng.randint(30, 150)) * MB)
    n_pools = int(rng.randint(1, 6))
    capacities = {f"g{p}": int(rng.randint(100, 1200)) * MB
                  for p in range(n_pools)}
    replication = int(rng.randint(0, 4))
    frac = float(rng.uniform(0.05, 0.5))
    plan = PlacementPlan.build(coe, capacities, replication=replication,
                               replica_fraction=frac)
    plan.validate()
    for g, cap in capacities.items():
        assert plan.planned_bytes(g) <= cap
        placed = plan.planned(g)
        assert len(placed) == len(set(placed))       # no dup copies per pool
    for eid in coe.experts:
        pools_ = plan.pools_for(eid)
        assert len(set(pools_)) == len(pools_)
        assert plan.replica_count(eid) <= replication
    # rebalance must keep every invariant too
    plan.rebalance({g: float(rng.rand()) for g in capacities})
    plan.validate()
    for g, cap in capacities.items():
        assert plan.planned_bytes(g) <= cap


def test_replication_places_hottest_first():
    coe = make_coe(n_experts=10, seed=1)
    capacities = {"a": 300 * MB, "b": 300 * MB}
    plan = PlacementPlan.build(coe, capacities, replication=1,
                               replica_fraction=0.5)
    hottest = coe.by_usage()[0].id
    assert plan.replica_count(hottest) == 1
    assert len(plan.pools_for(hottest)) == 2


# --------------------------------------------------------------------------- #
# per-device links
# --------------------------------------------------------------------------- #

def test_topology_link_modes():
    t_shared = TierTopology.from_spec(NUMA, groups=["gpu0", "gpu1"],
                                      links="shared")
    assert t_shared.pcie_for("gpu0") is t_shared.pcie_for("gpu1")
    t_per = TierTopology.from_spec(NUMA, groups=["gpu0", "gpu1"],
                                   links="per-device")
    assert t_per.pcie_for("gpu0") is not t_per.pcie_for("gpu1")
    # seed-compat single-link view still answers
    assert t_per.pcie_channel is not None
    with pytest.raises(ValueError):
        TierTopology.from_spec(NUMA, links="mesh")


def test_per_device_links_reduce_pcie_wait():
    """Same fleet + workload: splitting the PCIe link per device must not
    increase total host->device queueing, and under contention reduces it."""
    board = BoardSpec(name="T", n_components=60, n_active=40,
                      avg_quantity=2.0, n_detection=8, zipf_s=1.4)

    def run(links):
        coe = build_board_coe(board)
        fleet = FleetSpec(n_devices=2, gpu_per_device=2, n_cpu=0, links=links)
        pools, specs = build_fleet(FLEET_TIER, fleet)
        system = CoServeSystem(coe, specs, pools, policy=COSERVE,
                               tier=FLEET_TIER, links=links)
        sim = Simulation(system)
        sim.submit(make_task_requests(board, 300))
        return sim.run()

    shared = run("shared")
    per_dev = run("per-device")
    w_shared = shared.memory["channels"]["pcie_channel"]["wait_time_s"]
    w_per = per_dev.memory["channels"]["pcie_channel"]["wait_time_s"]
    assert w_shared > 0.0               # the workload contends at all
    assert w_per < w_shared
    # per-link breakdown is reported, one channel per device pool
    assert len(per_dev.memory["channels"]["pcie_channels"]) == 2
    assert len(shared.memory["channels"]["pcie_channels"]) == 1


# --------------------------------------------------------------------------- #
# residency-aware assignment
# --------------------------------------------------------------------------- #

def _two_device_system():
    """Two single-executor devices with per-device links and a tiny CoE."""
    experts = [
        ExpertSpec(id="hot", arch="a", mem_bytes=100 * MB, usage_prob=0.9),
        ExpertSpec(id="warm", arch="a", mem_bytes=100 * MB, usage_prob=0.5),
        ExpertSpec(id="filler", arch="a", mem_bytes=100 * MB, usage_prob=0.1),
    ]
    coe = CoEModel(experts, RoutingModule(lambda d: "hot"))
    arch = ArchProfile(arch="a", k=0.005, b=0.02, max_batch=8,
                       mem_bytes=100 * MB, act_bytes_per_item=MB,
                       load_latency_host=0.05, load_latency_disk=0.3)
    prof = DeviceProfile(device="gpu", tier=FLEET_TIER,
                         arch_profiles={"a": arch})
    pools = {"gpu0": 220 * MB, "gpu1": 220 * MB}
    specs = [ExecutorSpec("gpu", prof, 64 * MB, "gpu0"),
             ExecutorSpec("gpu", prof, 64 * MB, "gpu1")]
    system = CoServeSystem(coe, specs, pools, policy=COSERVE,
                           tier=FLEET_TIER, links="per-device")
    return system, coe


def test_scheduler_prefers_replica_holder_over_backlogged_link():
    """The satellite acceptance scenario: executor B holds the expert;
    executor A has the shorter queue but would have to load over a
    backlogged link. Residency-aware assignment must pick B once the link
    backlog makes the load dominate — and A when the links are idle."""
    system, coe = _two_device_system()
    ex_a, ex_b = system.executors
    # place the expert on B's pool only
    for pool in system.pools.values():
        for eid in list(pool.resident):
            pool.remove(eid)
    ex_b.pool.add("hot")
    ex_b.pool.ready.add("hot")
    # B has queued work; A is empty (cheaper queue)
    from repro.core.scheduler import Group
    ex_b.queue.append(Group("warm", [Request(id=1, expert_id="warm",
                                             arrival_time=0.0)]))

    # idle links: A pays one load but no queueing — the makespan argmin
    # takes the empty executor
    req = Request(id=2, expert_id="hot", arrival_time=0.0)
    assert system.scheduler._assign_makespan(req, 0.0) is ex_a

    # congest A's own link well past the load cost: the backlog now
    # dominates and the replica holder wins despite its deeper queue
    system.hierarchy.topology.pcie_for("gpu0").busy_until = 30.0
    system.hierarchy.topology.disk_channel.busy_until = 30.0
    req2 = Request(id=3, expert_id="hot", arrival_time=0.0)
    assert system.scheduler._assign_makespan(req2, 0.0) is ex_b


def test_switch_cost_charges_remaining_inflight_load():
    system, coe = _two_device_system()
    ex_a = system.executors[0]
    pool = ex_a.pool
    for eid in list(pool.resident):
        pool.remove(eid)
    pool.add("hot")
    pool.loading["hot"] = 2.0           # transfer lands at t=2
    sched = system.scheduler
    assert sched.switch_cost(ex_a, "hot", now=1.5) == pytest.approx(0.5)
    pool.loading.pop("hot")
    pool.ready.add("hot")
    assert sched.switch_cost(ex_a, "hot", now=1.5) == 0.0


# --------------------------------------------------------------------------- #
# prefetch trigger (satellite)
# --------------------------------------------------------------------------- #

def _chain_coe():
    experts = [
        ExpertSpec(id="up", arch="a", mem_bytes=50 * MB, usage_prob=0.9),
        ExpertSpec(id="down", arch="a", mem_bytes=50 * MB,
                   depends_on=("up",), usage_prob=0.5),
    ]
    routing = RoutingModule(lambda d: "up",
                            chain_prob={"up": {"down": 0.9}})
    return CoEModel(experts, routing)


def test_queue_trigger_promotes_on_enqueue():
    from repro.memory import MemoryHierarchy, PrefetchConfig, Residency
    coe = _chain_coe()
    h = MemoryHierarchy(coe, NUMA, pools={"gpu": 200 * MB},
                        prefetch=PrefetchConfig(enabled=True,
                                                trigger="queue"))
    h.on_enqueue("up", now=0.0)
    assert h.residency("down") is Residency.HOST
    assert h.prefetcher.promotions == 1
    assert h.prefetcher.promoted_bytes == coe.spec("down").mem_bytes


def test_exec_trigger_ignores_enqueue():
    from repro.memory import MemoryHierarchy, PrefetchConfig, Residency
    coe = _chain_coe()
    h = MemoryHierarchy(coe, NUMA, pools={"gpu": 200 * MB},
                        prefetch=PrefetchConfig(enabled=True, trigger="exec"))
    h.on_enqueue("up", now=0.0)
    assert h.residency("down") is Residency.DISK
    h.on_execute("up", now=0.0)
    assert h.residency("down") is Residency.HOST


def test_unknown_trigger_rejected():
    from repro.memory import MemoryHierarchy, PrefetchConfig
    with pytest.raises(ValueError, match="trigger"):
        MemoryHierarchy(_chain_coe(), NUMA, pools={},
                        prefetch=PrefetchConfig(enabled=True,
                                                trigger="arrival"))


def test_queue_trigger_end_to_end_widens_promotion_window():
    """On the detector-spill workload the queue-arrival trigger issues at
    least as much speculative promotion traffic as execution-start (it opens
    the same window earlier), and the delta is observable."""
    board = BoardSpec(name="T", n_components=60, n_active=16,
                      avg_quantity=4.0, n_detection=16,
                      detection_fraction=1.0, ok_prob=0.98, zipf_s=0.8)
    tier = TierSpec(name="t", disk_bw=530e6, host_to_device_bw=12e9,
                    unified=False, host_cache_bytes=4 << 30,
                    device_bytes=4 << 30)

    def run(trigger):
        policy = dataclasses.replace(COSERVE, prefetch_trigger=trigger)
        m, _ = run_board_system(board, tier, n_gpu=2, n_cpu=0, policy=policy,
                                n_requests=400, request_seed=1)
        return m

    m_exec = run("exec")
    m_queue = run("queue")
    b_exec = m_exec.memory["prefetch"]["promoted_bytes"]
    b_queue = m_queue.memory["prefetch"]["promoted_bytes"]
    assert b_queue >= b_exec
    assert m_queue.memory["prefetch"]["trigger"] == "queue"


# --------------------------------------------------------------------------- #
# real engine topology agreement
# --------------------------------------------------------------------------- #

def test_real_engine_one_transfer_thread_per_pcie_channel():
    from repro.core.engines import HostStore, RealEngine

    coe = make_coe(n_experts=4)
    engine = RealEngine(coe, HostStore(), apply_fns={})
    topo = TierTopology.from_spec(FLEET_TIER, groups=["gpu0", "gpu1"],
                                  links="per-device")
    engine.bind_topology(topo)

    class _Pool:
        def __init__(self, group):
            self.group = group

    class _Ex:
        def __init__(self, group):
            self.pool = _Pool(group)

        @property
        def link_group(self):
            return self.pool.group

    a, b = _Ex("gpu0"), _Ex("gpu1")
    assert engine._channel_name(a) != engine._channel_name(b)
    assert engine._worker_for(engine._channel_name(a)) \
        is not engine._worker_for(engine._channel_name(b))
    # shared mode: both executors serialize on one worker (the seed thread)
    shared = TierTopology.from_spec(FLEET_TIER, groups=["gpu0", "gpu1"],
                                    links="shared")
    engine2 = RealEngine(coe, HostStore(), apply_fns={})
    engine2.bind_topology(shared)
    assert engine2._channel_name(a) == engine2._channel_name(b)
    # unified tiers ride the one storage link regardless of pool
    uni = TierTopology.from_spec(
        TierSpec(name="u", unified=True), groups=["gpu0", "gpu1"],
        links="per-device")
    engine3 = RealEngine(coe, HostStore(), apply_fns={})
    engine3.bind_topology(uni)
    assert engine3._channel_name(a) == engine3._channel_name(b)


# --------------------------------------------------------------------------- #
# autoscaler placement rebalance
# --------------------------------------------------------------------------- #

def test_scale_event_rebalances_placement():
    """A scale-up must re-plan replication (rebalances counter) and pull
    planned-but-missing replicas through the contended load path."""
    from repro.serve import Autoscaler, AutoscalerConfig

    board = BoardSpec(name="T", n_components=40, n_active=24,
                      avg_quantity=2.0, n_detection=6, zipf_s=1.8)
    coe = build_board_coe(board)
    fleet = FleetSpec(n_devices=2, gpu_per_device=1, n_cpu=0,
                      links="per-device")
    pools, specs = build_fleet(FLEET_TIER, fleet)
    system = CoServeSystem(coe, specs, pools, policy=COSERVE,
                           tier=FLEET_TIER, links="per-device",
                           replication=1)
    asc = Autoscaler(AutoscalerConfig(
        spec=specs[0], min_executors=2, max_executors=4,
        up_queue_per_executor=1.0, cooldown_s=0.0))
    sim = Simulation(system)
    sim.submit(make_task_requests(board, 400, interval=0.001))
    sim.add_ticker(0.25, asc.step)
    m = sim.run()
    assert m.completed >= 400
    ups = [e for e in asc.events if e.action == "up"]
    assert ups, "the overloaded queue must trigger a scale-up"
    assert system.placement.rebalances >= len(asc.events)


def test_cpu_speculation_gates_on_disk_not_phantom_pcie():
    """CPU executors load disk -> DRAM: their backlog gate must read the SSD
    link, and must not conjure an unused per-device 'pcie[cpu]' channel."""
    from repro.memory import MemoryHierarchy

    coe = make_coe(n_experts=4)
    h = MemoryHierarchy(coe, FLEET_TIER, pools={"gpu0": 1 << 30,
                                                "cpu": 1 << 30},
                        links="per-device")
    h.host.insert("e000")               # host hit: a GPU load would ride PCIe
    h.topology.disk_channel.busy_until = 50.0
    assert h.load_backlog("e000", now=0.0, group="cpu", device="cpu") \
        == pytest.approx(50.0)
    assert not h.speculation_ok("e000", 0.0, "cpu", "cpu")
    # the GPU path still prices its own (idle) link for the host hit
    assert h.load_backlog("e000", now=0.0, group="gpu0") == 0.0
    # and a full system never conjures a 'pcie[cpu]' channel: only device
    # pools own links
    board = BoardSpec(name="T", n_components=20, n_active=12, n_detection=4)
    m, _ = run_board_system(board, FLEET_TIER, n_gpu=2, n_cpu=1,
                            links="per-device", n_requests=60,
                            request_seed=1)
    names = set(m.memory["channels"]["pcie_channels"])
    assert names == {"ft/pcie[gpu]"}


def test_fleet_aware_scale_up_tie_prefers_spec_group():
    """Equal queue pressure everywhere: the scale-up must land on the
    spec's own pool group, not an arbitrary other device."""
    from repro.serve import Autoscaler, AutoscalerConfig

    system, coe = _two_device_system()
    asc = Autoscaler(AutoscalerConfig(
        spec=ExecutorSpec("gpu", system.executors[0].device_profile,
                          64 * MB, "gpu1")))

    class _Sim:
        pass
    sim = _Sim()
    sim.system = system
    assert asc._target_group(sim) == "gpu1"


def test_fleet_aware_scale_up_targets_hottest_pool():
    """With one pool drowning and the other idle, the fleet-aware scale-up
    must land its executor on the drowning pool."""
    from repro.serve import Autoscaler, AutoscalerConfig

    system, coe = _two_device_system()
    ex_a, ex_b = system.executors
    asc = Autoscaler(AutoscalerConfig(
        spec=ExecutorSpec("gpu", ex_a.device_profile, 64 * MB, "gpu0"),
        min_executors=2, max_executors=3,
        up_queue_per_executor=0.5, cooldown_s=0.0))
    sim = Simulation(system)
    # drown B's queue (expert resident there), leave A idle
    for i in range(40):
        sim.push(0.0, 0, Request(id=i, expert_id="hot", arrival_time=0.0))
    sim.add_ticker(0.05, asc.step)
    sim.run()
    ups = [e for e in asc.events if e.action == "up"]
    assert ups
    scaled = next(e for e in system.executors if e.id == ups[0].executor_id)
    assert scaled.pool.group == "gpu1"   # the drowning device, not the
    #                                      spec's default gpu0
