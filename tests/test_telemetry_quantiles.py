"""P2QuantileBank is numerically identical to per-q P2Quantile estimators.

The bank is a pure performance rewrite (flattened rows, unrolled marker
loops, folded constants) of the scalar P-square estimator — it must produce
bit-identical marker heights for any stream. These tests feed both through
the same seeded streams across several distributions and stream lengths,
including the exact-phase (< 5 observations) edge, and pin LatencyTracker's
snapshot on top of the bank.
"""
from __future__ import annotations

import random

import pytest

from repro.serve.telemetry import LatencyTracker, P2Quantile, P2QuantileBank

QS = (0.50, 0.95, 0.99)


def _streams():
    """(name, values) pairs spanning shapes the P-square markers react to."""
    rng = random.Random(1234)
    yield "uniform", [rng.random() for _ in range(3000)]
    yield "lognormal", [rng.lognormvariate(0.0, 1.0) for _ in range(3000)]
    yield "exponential", [rng.expovariate(3.0) for _ in range(3000)]
    yield "bimodal", [rng.gauss(1.0, 0.05) if rng.random() < 0.9
                      else rng.gauss(20.0, 2.0) for _ in range(3000)]
    yield "sorted_ascending", [i * 0.001 for i in range(2000)]
    yield "sorted_descending", [(2000 - i) * 0.001 for i in range(2000)]
    yield "constant", [0.25] * 500
    yield "tiny", [rng.random() for _ in range(4)]        # exact phase only
    yield "five", [rng.random() for _ in range(5)]        # markers just born
    yield "six", [rng.random() for _ in range(6)]         # first adjustment


@pytest.mark.parametrize("name,stream", list(_streams()),
                         ids=[n for n, _ in _streams()])
def test_bank_matches_scalar_estimators_exactly(name, stream):
    bank = P2QuantileBank(QS)
    refs = [P2Quantile(q) for q in QS]
    for i, x in enumerate(stream):
        bank.add(x)
        for ref in refs:
            ref.add(x)
        if i % 97 == 0:  # identity must hold mid-stream, not just at the end
            assert bank.values() == [r.value() for r in refs], \
                f"{name}: diverged at observation {i + 1}"
    assert bank.values() == [r.value() for r in refs]
    assert bank.n == refs[0].n == len(stream)


def test_bank_internal_markers_match_scalar_markers():
    """Stronger than value equality: every marker height and position must
    match, or later observations could diverge after a passing values()."""
    rng = random.Random(7)
    bank = P2QuantileBank(QS)
    refs = [P2Quantile(q) for q in QS]
    for _ in range(1500):
        x = rng.lognormvariate(0.0, 0.8)
        bank.add(x)
        for ref in refs:
            ref.add(x)
    for row, ref in zip(bank._rows, refs):
        assert row[0:5] == ref._h
        assert row[5:9] == ref._pos[1:]          # pos[0] is pinned at 1.0
        assert row[9:13] == ref._des[1:]         # des[0] is pinned at 1.0


def test_bank_empty_returns_zeros():
    bank = P2QuantileBank(QS)
    assert bank.values() == [0.0, 0.0, 0.0]
    assert bank.n == 0


def test_bank_exact_below_five_observations():
    """Below 5 observations both implementations fall back to exact
    nearest-rank over the sorted sample."""
    bank = P2QuantileBank(QS)
    for x in (3.0, 1.0, 2.0):
        bank.add(x)
    refs = [P2Quantile(q) for q in QS]
    for ref in refs:
        for x in (3.0, 1.0, 2.0):
            ref.add(x)
    vals = bank.values()
    assert vals == [r.value() for r in refs]
    assert vals[0] == 2.0          # exact median of {1, 2, 3}
    assert vals[1] == vals[2] == 3.0


def test_tracker_snapshot_rides_the_bank():
    rng = random.Random(42)
    tracker = LatencyTracker()
    refs = [P2Quantile(q) for q in LatencyTracker.QS]
    xs = [rng.expovariate(2.0) for _ in range(2000)]
    for x in xs:
        tracker.add(x)
        for ref in refs:
            ref.add(x)
    snap = tracker.snapshot()
    assert snap["count"] == len(xs)
    assert snap["mean"] == pytest.approx(sum(xs) / len(xs))
    assert snap["max"] == max(xs)
    # snapshot applies the monotonicity clamp on top of the raw estimates
    raw = [r.value() for r in refs]
    hi, clamped = 0.0, []
    for v in raw:
        hi = max(hi, v)
        clamped.append(hi)
    assert [snap["p50"], snap["p95"], snap["p99"]] == clamped
    assert snap["p50"] <= snap["p95"] <= snap["p99"]


def test_tracker_quantiles_land_near_truth():
    """Sanity that the streaming estimate tracks the true quantiles on a
    well-behaved stream (P-square accuracy, not identity)."""
    rng = random.Random(9)
    xs = [rng.random() for _ in range(20000)]
    tracker = LatencyTracker()
    for x in xs:
        tracker.add(x)
    snap = tracker.snapshot()
    assert snap["p50"] == pytest.approx(0.50, abs=0.03)
    assert snap["p95"] == pytest.approx(0.95, abs=0.03)
    assert snap["p99"] == pytest.approx(0.99, abs=0.03)
