"""Online serving subsystem (repro.serve): arrivals, telemetry, SLO
scheduling, admission control and autoscaling, all on deterministic seeds."""
import itertools

import numpy as np
import pytest

from repro.core import COSERVE, CoServeSystem, Request
from repro.core.memory import NUMA
from repro.core.workload import BoardSpec, make_executor_specs
from repro.serve import (AdmissionConfig, AdmissionController, Autoscaler,
                         AutoscalerConfig, OnlineGateway, P2Quantile,
                         TenantSpec, make_gaps, merge_board_coe,
                         multi_tenant_stream, tenant_stream)

SMALL_A = BoardSpec(name="A", n_components=40, n_active=20, n_detection=4)
SMALL_B = BoardSpec(name="B", n_components=36, n_active=18, n_detection=4)


def build_system(boards, n_gpu=2, n_cpu=1, weights=None):
    coe = merge_board_coe(boards, weights)
    pools, specs = make_executor_specs(NUMA, n_gpu, n_cpu)
    return CoServeSystem(coe, specs, pools, policy=COSERVE, tier=NUMA), specs


def make_tenants(rate_a=30.0, rate_b=15.0, slo_a=2.0, slo_b=4.0,
                 process="poisson"):
    return [
        TenantSpec(name="gold", board=SMALL_A, rate=rate_a, process=process,
                   slo_seconds=slo_a, seed=1),
        TenantSpec(name="batch", board=SMALL_B, rate=rate_b, process=process,
                   slo_seconds=slo_b, seed=2),
    ]


# --------------------------------------------------------------------------- #
# arrival processes
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("process", ["poisson", "bursty", "diurnal"])
def test_arrival_processes_hit_requested_rate(process):
    rng = np.random.RandomState(0)
    # short diurnal period so the sample spans many full cycles (a partial
    # cycle over-weights the daytime peak)
    kw = {"period_s": 5.0} if process == "diurnal" else {}
    gaps = list(itertools.islice(make_gaps(process, 50.0, rng, **kw), 4000))
    rate = len(gaps) / sum(gaps)
    assert 35.0 < rate < 70.0, f"{process} mean rate {rate}"
    assert all(g >= 0.0 for g in gaps)


def test_step_process_rate_changes_at_step():
    rng = np.random.RandomState(0)
    gaps = make_gaps("step", 10.0, rng, rate_after=100.0, t_step=10.0)
    times = list(itertools.islice(itertools.accumulate(gaps), 3000))
    before = sum(1 for t in times if t < 10.0) / 10.0
    after_times = [t for t in times if t >= 10.0]
    span = after_times[-1] - 10.0
    after = len(after_times) / span
    assert after > 4.0 * before


def test_tenant_stream_is_deterministic_and_monotone():
    t1 = list(itertools.islice(
        tenant_stream(make_tenants()[0], itertools.count()), 200))
    t2 = list(itertools.islice(
        tenant_stream(make_tenants()[0], itertools.count()), 200))
    assert [r.arrival_time for r in t1] == [r.arrival_time for r in t2]
    assert [r.expert_id for r in t1] == [r.expert_id for r in t2]
    times = [r.arrival_time for r in t1]
    assert times == sorted(times)
    assert all(r.deadline == pytest.approx(r.arrival_time + 2.0) for r in t1)


def test_multi_tenant_stream_merges_in_time_order():
    reqs = list(multi_tenant_stream(make_tenants(), max_requests=300))
    times = [r.arrival_time for r in reqs]
    assert times == sorted(times)
    assert {r.tenant for r in reqs} == {"gold", "batch"}
    assert len({r.id for r in reqs}) == 300          # globally unique ids


# --------------------------------------------------------------------------- #
# P2 quantile estimator
# --------------------------------------------------------------------------- #

def test_p2_quantile_tracks_exact_percentiles():
    rng = np.random.RandomState(3)
    xs = rng.lognormal(0.0, 0.6, 5000)
    for q in (0.5, 0.95, 0.99):
        est = P2Quantile(q)
        for x in xs:
            est.add(float(x))
        exact = float(np.percentile(xs, 100 * q))
        assert est.value() == pytest.approx(exact, rel=0.15), q


# --------------------------------------------------------------------------- #
# gateway + telemetry
# --------------------------------------------------------------------------- #

def run_gateway(tenants, n_requests, system=None, specs=None, **kw):
    if system is None:
        system, specs = build_system([SMALL_A, SMALL_B],
                                     weights=[t.rate for t in tenants])
    gw = OnlineGateway(system, tenants, **kw)
    return gw.run(max_requests=n_requests)


def test_online_percentiles_ordered_and_all_complete():
    tenants = make_tenants()
    report = run_gateway(tenants, 600)
    assert report.metrics.completed == 600
    assert report.telemetry["shed"] == 0
    for t in ("gold", "batch"):
        snap = report.telemetry["per_tenant"][t]
        assert snap["count"] > 0
        assert snap["p50"] <= snap["p95"] <= snap["p99"]
        assert snap["p99"] >= snap["mean"] * 0.5
    m = report.metrics
    assert m.p50_latency <= m.p95_latency <= m.p99_latency
    assert set(m.per_tenant) == {"gold", "batch"}


def test_per_expert_breakdown_covers_both_archs():
    report = run_gateway(make_tenants(), 500)
    per_expert = report.telemetry["per_expert"]
    assert "resnet101" in per_expert
    assert any(a.startswith("yolov5") for a in per_expert)


def test_slo_violations_monotone_in_offered_load():
    counts = []
    for rate in (10.0, 60.0, 200.0):
        tenants = [TenantSpec(name="gold", board=SMALL_A, rate=rate,
                              slo_seconds=1.5, seed=1)]
        system, _ = build_system([SMALL_A])
        report = run_gateway(tenants, 500, system=system)
        assert report.metrics.completed == 500
        counts.append(sum(report.telemetry["per_tenant"][t]["slo"]["violations"]
                          for t in report.telemetry["per_tenant"]))
    assert counts[0] <= counts[1] <= counts[2]
    assert counts[2] > counts[0]            # overload really violates more


def test_deadline_priority_reduces_tight_tenant_latency():
    """EDF insertion should cut the tight-SLO tenant's tail vs FIFO order."""
    def tail(slo_priority):
        tenants = [
            TenantSpec(name="tight", board=SMALL_A, rate=20.0,
                       slo_seconds=0.8, seed=1),
            TenantSpec(name="slack", board=SMALL_B, rate=40.0,
                       slo_seconds=30.0, seed=2),
        ]
        system, _ = build_system([SMALL_A, SMALL_B])
        report = run_gateway(tenants, 800, system=system,
                             slo_priority=slo_priority)
        return report.telemetry["per_tenant"]["tight"]["p95"]

    assert tail(True) <= tail(False) * 1.05


# --------------------------------------------------------------------------- #
# admission control
# --------------------------------------------------------------------------- #

def test_admission_bounds_queue_growth_under_overload():
    tenants = [TenantSpec(name="gold", board=SMALL_A, rate=400.0,
                          slo_seconds=2.0, seed=1)]

    system, _ = build_system([SMALL_A])
    unbounded = run_gateway(tenants, 1200, system=system)
    system, _ = build_system([SMALL_A])
    admission = AdmissionController(AdmissionConfig(policy="queue_depth",
                                                    max_queue=40))
    bounded = run_gateway(tenants, 1200, system=system, admission=admission)

    q_unbounded = unbounded.telemetry["queue"]["max_depth"]
    q_bounded = bounded.telemetry["queue"]["max_depth"]
    # bound holds up to the in-flight batches admitted before the gate closes
    assert q_bounded <= 40 + 16
    assert q_unbounded > 3 * q_bounded      # baseline queue grows without bound
    assert bounded.telemetry["shed"] > 0
    assert admission.stats()["rejected"] == bounded.telemetry["shed"]
    # everything admitted still completes
    assert bounded.metrics.completed + bounded.telemetry["shed"] == 1200


def test_deadline_admission_sheds_doomed_requests():
    tenants = [TenantSpec(name="gold", board=SMALL_A, rate=300.0,
                          slo_seconds=0.5, seed=1)]

    system, _ = build_system([SMALL_A])
    baseline = run_gateway(tenants, 600, system=system)
    system, _ = build_system([SMALL_A])
    admission = AdmissionController(AdmissionConfig(policy="deadline"))
    report = run_gateway(tenants, 600, system=system, admission=admission)

    assert report.telemetry["shed"] > 0
    # shedding guaranteed-late work leaves the admitted set far better off
    vr_base = baseline.telemetry["per_tenant"]["gold"]["slo"]["violation_rate"]
    vr_adm = report.telemetry["per_tenant"]["gold"]["slo"]["violation_rate"]
    assert vr_adm < vr_base * 0.9


def test_token_bucket_caps_one_tenant_without_starving_other():
    tenants = [
        TenantSpec(name="greedy", board=SMALL_A, rate=200.0, seed=1),
        TenantSpec(name="modest", board=SMALL_B, rate=10.0, seed=2),
    ]
    system, _ = build_system([SMALL_A, SMALL_B])
    admission = AdmissionController(AdmissionConfig(
        policy="token_bucket", bucket_rate=30.0, bucket_burst=10.0))
    report = run_gateway(tenants, 800, system=system, admission=admission)
    shed = report.telemetry["per_tenant"]
    greedy_shed = shed["greedy"]["slo"]["shed"]
    modest_shed = shed["modest"]["slo"].get("shed", 0)
    assert greedy_shed > 0
    assert modest_shed <= greedy_shed * 0.1


# --------------------------------------------------------------------------- #
# autoscaler
# --------------------------------------------------------------------------- #

def test_autoscaler_scales_up_on_load_step_and_back_down():
    tenants = [TenantSpec(
        name="gold", board=SMALL_A, rate=150.0, process="step",
        slo_seconds=3.0, seed=1,
        process_kwargs=(("rate_after", 5.0), ("t_step", 6.0)))]
    system, specs = build_system([SMALL_A], n_gpu=1, n_cpu=0)
    asc = Autoscaler(AutoscalerConfig(
        spec=specs[0], min_executors=1, max_executors=5,
        up_queue_per_executor=8.0, down_queue_per_executor=1.0,
        cooldown_s=1.0))
    gw = OnlineGateway(system, tenants, autoscaler=asc, tick_interval=0.25)
    report = gw.run(max_requests=1100)

    summary = report.autoscaler
    assert summary["scale_ups"] >= 1, summary
    assert summary["scale_downs"] >= 1, summary
    ups = [e for e in summary["events"] if e["action"] == "up"]
    downs = [e for e in summary["events"] if e["action"] == "down"]
    assert min(u["t"] for u in ups) < min(d["t"] for d in downs)
    # no work lost across scale-downs (orphans re-queued at-most-once)
    assert report.metrics.completed == 1100
    # fleet returns toward the floor after the step down
    assert report.timeline[-1]["executors"] <= report.timeline[0]["executors"] + 1


def test_autoscaler_respects_max_executors():
    tenants = [TenantSpec(name="gold", board=SMALL_A, rate=500.0, seed=1)]
    system, specs = build_system([SMALL_A], n_gpu=1, n_cpu=0)
    asc = Autoscaler(AutoscalerConfig(
        spec=specs[0], min_executors=1, max_executors=3,
        up_queue_per_executor=4.0, cooldown_s=0.5))
    gw = OnlineGateway(system, tenants, autoscaler=asc, tick_interval=0.25)
    report = gw.run(max_requests=600)
    assert max(p["executors"] for p in report.timeline) <= 3
    assert report.metrics.completed == 600


# --------------------------------------------------------------------------- #
# incremental source plumbing
# --------------------------------------------------------------------------- #

def test_source_is_pulled_lazily():
    pulled = []

    def counting_stream():
        for i, r in enumerate(multi_tenant_stream(make_tenants(), 100)):
            pulled.append(i)
            yield r

    system, _ = build_system([SMALL_A, SMALL_B])
    gw = OnlineGateway(system, make_tenants())
    stream = counting_stream()
    gw.sim.set_source(stream)
    # before run(), exactly one arrival has been materialized
    assert len(pulled) == 1
    m = gw.sim.run()
    assert m.completed == 100
    assert len(pulled) == 100


def test_offline_submit_path_unchanged():
    """The pre-materialized offline path coexists with online hooks."""
    from repro.core import Simulation
    from repro.core.workload import build_board_coe, make_task_requests
    coe = build_board_coe(SMALL_A)
    pools, specs = make_executor_specs(NUMA, 2, 1)
    system = CoServeSystem(coe, specs, pools, policy=COSERVE, tier=NUMA)
    sim = Simulation(system)
    sim.submit(make_task_requests(SMALL_A, 300))
    m = sim.run()
    assert m.completed == 300
    assert m.p50_latency <= m.p99_latency
    assert "" in m.per_tenant          # untagged tenant bucket
