"""Unit + property tests for the unified tiered-memory subsystem
(``repro.memory``): residency invariants, shared-channel contention,
the deduplicated load-latency formula, and cross-tier prefetch."""
import dataclasses

import numpy as np
import pytest

from repro.core import (COSERVE, CoEModel, CoServeSystem, ExpertSpec, Request,
                        RoutingModule, Simulation, SystemPolicy)
from repro.core.engines import SimEngine
from repro.core.expert_manager import ExpertManager
from repro.core.serving import ExecutorSpec
from repro.core.workload import (BoardSpec, build_board_coe, device_profile,
                                 make_executor_specs, make_task_requests)
from repro.memory import (NUMA, TPU_V5E, UMA, DevicePool, HostTier,
                          MemoryHierarchy, PrefetchConfig, Residency, TierSpec,
                          TransferChannel, make_policy)
from repro.memory.transfer import predicted_load_latency

MB = 1 << 20


def make_coe(n_experts: int = 12, seed: int = 0,
             mem_bytes: int = 100 * MB) -> CoEModel:
    rng = np.random.RandomState(seed)
    experts = []
    for i in range(n_experts):
        deps = ()
        if i >= n_experts // 2 and rng.rand() < 0.5:
            deps = (f"e{rng.randint(0, n_experts // 2):03d}",)
        experts.append(ExpertSpec(
            id=f"e{i:03d}", arch="resnet101", mem_bytes=mem_bytes,
            depends_on=deps, usage_prob=float(rng.rand())))
    return CoEModel(experts, RoutingModule(lambda d: "e000"))


# --------------------------------------------------------------------------- #
# load-latency deduplication: one formula, three consumers
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("tier", [NUMA, UMA, TPU_V5E], ids=lambda t: t.name)
@pytest.mark.parametrize("in_host", [True, False])
def test_formula_matches_seed_semantics(tier, in_host):
    """Regression-pin the closed form for every shipped tier: the shim
    ``repro.core.memory.load_latency`` and the TransferEngine agree."""
    from repro.core.memory import load_latency
    mem = 178 * MB
    want = predicted_load_latency(tier, mem, in_host)
    assert load_latency(tier, mem, in_host) == want
    if tier.unified or not in_host:
        expect = tier.disk_overhead + tier.host_overhead + mem / tier.disk_bw
        if not tier.unified:
            expect += mem / tier.host_to_device_bw
    else:
        expect = tier.host_overhead + mem / tier.host_to_device_bw
    assert want == pytest.approx(expect)


@pytest.mark.parametrize("tier", [NUMA, UMA, TPU_V5E], ids=lambda t: t.name)
def test_sim_load_on_idle_channels_matches_formula(tier):
    """An uncontended simulated load must cost exactly the predicted formula
    (the contention model adds latency only when links are shared)."""
    coe = make_coe()
    h = MemoryHierarchy(coe, tier, pools={"gpu": 1 << 30})
    engine = SimEngine(coe, tier, hierarchy=h)
    mem = coe.spec("e000").mem_bytes
    # disk-sourced load on idle channels
    assert engine.load(None, "e000", now=0.0) == \
        pytest.approx(predicted_load_latency(tier, mem, in_host_cache=False))
    if h.host is not None:
        # the load populated the host tier: a later load pays the PCIe leg
        t2 = h.topology.pcie_channel.busy_until + h.topology.disk_channel.busy_until
        assert engine.load(None, "e000", now=t2 + 1.0) == \
            pytest.approx(predicted_load_latency(tier, mem, in_host_cache=True))


def test_profiler_load_latencies_come_from_transfer_engine():
    prof = device_profile("gpu", NUMA).arch_profiles["resnet101"]
    mem = prof.mem_bytes
    assert prof.load_latency_disk == \
        pytest.approx(predicted_load_latency(NUMA, mem, in_host_cache=False))
    assert prof.load_latency_host == \
        pytest.approx(predicted_load_latency(NUMA, mem, in_host_cache=True))


# --------------------------------------------------------------------------- #
# shared-channel contention
# --------------------------------------------------------------------------- #

def test_two_concurrent_loads_take_twice_one_load():
    """Two same-instant transfers on one link finish in ~2x one transfer."""
    ch = TransferChannel("ssd", bandwidth=500e6)
    one = ch.duration(500_000_000)
    a = ch.begin(0.0, 500_000_000)
    b = ch.begin(0.0, 500_000_000)
    assert a.latency == pytest.approx(one)
    assert b.latency == pytest.approx(2 * one)
    assert b.start == pytest.approx(a.done)


def test_two_executor_contention_raises_per_load_latency():
    """Acceptance: a 2-executor shared-SSD sim pays more per load than the
    1-executor case (the seed gave every executor a private SSD)."""
    board = BoardSpec(name="T", n_components=80, n_active=48,
                      avg_quantity=3.0, n_detection=10, zipf_s=1.6)
    tier = TierSpec(name="t", disk_bw=530e6, host_to_device_bw=12e9,
                    unified=False, host_cache_bytes=2 << 30,
                    device_bytes=4 << 30)

    def per_load(n_gpu):
        coe = build_board_coe(board)
        pools, specs = make_executor_specs(tier, n_gpu, 0)
        system = CoServeSystem(coe, specs, pools, policy=COSERVE, tier=tier)
        sim = Simulation(system)
        sim.submit(make_task_requests(board, 400))
        m = sim.run()
        total = sum(s["load_time"] for s in m.per_executor.values())
        return total / max(1, m.switches), m

    solo, m1 = per_load(1)
    duo, m2 = per_load(2)
    assert duo > solo * 1.2, (solo, duo)
    assert m2.memory["channels"]["disk_channel"]["wait_time_s"] > 0.0
    assert m1.memory["channels"]["disk_channel"]["wait_time_s"] == 0.0


# --------------------------------------------------------------------------- #
# residency-state invariants under random load/evict/pin sequences
# (seeded-random property tests: hypothesis is optional in this image)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(8))
def test_pool_invariants_random_sequences(seed):
    rng = np.random.RandomState(seed)
    coe = make_coe(n_experts=16, seed=seed,
                   mem_bytes=int(rng.randint(40, 140)) * MB)
    pool = DevicePool(600 * MB, coe, group="gpu")
    mgr = ExpertManager(coe, policy=["dependency_prob", "lru", "fifo",
                                     "prob"][seed % 4])
    ids = list(coe.experts)
    for _ in range(300):
        op = rng.randint(5)
        eid = ids[rng.randint(len(ids))]
        if op == 0 and pool.fits(eid) and eid not in pool:
            if mgr.ensure_loadable(pool, eid) is not None:
                pool.add(eid)
                pool.ready.add(eid)
        elif op == 1 and eid in pool.ready and eid not in pool.pinned:
            pool.pin(eid)
        elif op == 2 and eid in pool.pinned:
            pool.unpin(eid)
        elif op == 3:
            pool.touch(eid)
        elif op == 4:
            victims = pool.evictable()
            if victims:
                pool.remove(victims[rng.randint(len(victims))])
        # --- invariants hold after every step ------------------------- #
        assert 0 <= pool.used_bytes <= pool.capacity
        assert pool.used_bytes == sum(coe.spec(e).mem_bytes
                                      for e in pool.resident)
        assert set(pool.pinned) <= set(pool.resident)
        assert pool.ready <= set(pool.resident)
        assert set(pool.insert_seq) == set(pool.resident)
        for e in pool.pinned:
            assert e not in pool.evictable()      # pinned never evictable


def test_manager_never_evicts_pinned_random_sequences():
    rng = np.random.RandomState(7)
    coe = make_coe(n_experts=14, seed=7)
    pool = DevicePool(500 * MB, coe, group="gpu")
    mgr = ExpertManager(coe, policy="dependency_prob")
    ids = list(coe.experts)
    for step in range(200):
        eid = ids[rng.randint(len(ids))]
        pinned_before = set(pool.pinned)
        victims = mgr.pick_victims(pool, eid)
        if victims is not None:
            assert not (set(victims) & pinned_before)
            for v in victims:
                pool.remove(v)
            if eid not in pool and pool.fits(eid):
                pool.add(eid)
                pool.ready.add(eid)
        if rng.rand() < 0.3 and pool.resident:
            pool.pin(list(pool.resident)[rng.randint(len(pool.resident))])
        if rng.rand() < 0.2 and pool.pinned:
            pool.unpin(list(pool.pinned)[0])


def test_fifo_order_unperturbed_by_touch():
    """The executor touch()es an expert on every batch; FIFO eviction order
    must still follow *insertion* order (the seed degraded FIFO to LRU)."""
    coe = make_coe(n_experts=8, seed=1)
    pool = DevicePool(1 << 62, coe, group="gpu")
    ids = list(coe.experts)[:5]
    for eid in ids:
        pool.add(eid)
        pool.ready.add(eid)
    for _ in range(3):
        pool.touch(ids[0])     # hammer the oldest insertion
    order = make_policy("fifo").order(pool.eviction_view())
    assert order == ids        # insertion order, not use order
    lru = make_policy("lru").order(pool.eviction_view())
    assert lru[-1] == ids[0]   # LRU *does* see the touches


# --------------------------------------------------------------------------- #
# host tier
# --------------------------------------------------------------------------- #

def test_host_insert_oversized_is_non_destructive():
    """Satellite fix: an expert larger than the whole cache must not evict
    every resident on its way to failing."""
    coe = CoEModel([
        ExpertSpec(id="small", arch="a", mem_bytes=10 * MB, usage_prob=0.5),
        ExpertSpec(id="small2", arch="a", mem_bytes=10 * MB, usage_prob=0.4),
        ExpertSpec(id="huge", arch="a", mem_bytes=500 * MB, usage_prob=0.9),
    ], RoutingModule(lambda d: "small"))
    cache = HostTier(64 * MB, coe, policy="prob")
    assert cache.insert("small") == []
    assert cache.insert("small2") == []
    evicted = cache.insert("huge")
    assert evicted == []                      # no destructive eviction pass
    assert "small" in cache and "small2" in cache
    assert "huge" not in cache


def test_host_reinsert_does_not_double_count():
    """Seed bug: re-inserting a resident expert inflated used_bytes."""
    coe = make_coe(n_experts=4, seed=3, mem_bytes=50 * MB)
    cache = HostTier(500 * MB, coe)
    cache.insert("e000")
    used = cache.used_bytes
    cache.insert("e000")
    assert cache.used_bytes == used


# --------------------------------------------------------------------------- #
# residency state machine
# --------------------------------------------------------------------------- #

def test_residency_state_transitions():
    coe = make_coe(n_experts=6, seed=2, mem_bytes=50 * MB)
    h = MemoryHierarchy(coe, NUMA, pools={"gpu": 200 * MB})
    pool = h.pools["gpu"]
    eid = "e000"
    assert h.residency(eid) is Residency.DISK
    tr = h.begin_device_load(eid, now=0.0)
    pool.add(eid)
    pool.loading[eid] = tr.done
    assert h.residency(eid) is Residency.LOADING
    pool.loading.pop(eid)
    pool.ready.add(eid)
    assert h.residency(eid) is Residency.DEVICE
    pool.pin(eid)
    assert h.residency(eid) is Residency.PINNED
    pool.unpin(eid)
    pool.remove(eid)
    h.note_evicted(eid)
    assert h.residency(eid) is Residency.HOST   # demoted, not dropped
    counts = h.residency_counts()
    assert counts["host"] == 1 and counts["disk"] == len(coe) - 1


# --------------------------------------------------------------------------- #
# dependency-aware cross-tier prefetch
# --------------------------------------------------------------------------- #

def _chain_coe():
    experts = [
        ExpertSpec(id="up", arch="a", mem_bytes=50 * MB, usage_prob=0.9),
        ExpertSpec(id="down", arch="a", mem_bytes=50 * MB,
                   depends_on=("up",), usage_prob=0.5),
        ExpertSpec(id="cold", arch="a", mem_bytes=50 * MB,
                   depends_on=("up",), usage_prob=0.001),
    ]
    routing = RoutingModule(lambda d: "up",
                            chain_prob={"up": {"down": 0.9, "cold": 0.001}})
    return CoEModel(experts, routing)


def test_prefetch_promotes_likely_downstream_to_host():
    coe = _chain_coe()
    h = MemoryHierarchy(coe, NUMA, pools={"gpu": 200 * MB},
                        prefetch=PrefetchConfig(enabled=True))
    h.on_execute("up", now=0.0)
    assert h.residency("down") is Residency.HOST
    # in flight until the SSD leg lands, then a settled host resident
    assert not h.host.is_ready("down", now=0.0)
    assert h.host.is_ready("down", now=h.host.ready_time("down"))
    # the cold edge (below min_weight) stays on disk
    assert h.residency("cold") is Residency.DISK
    assert h.prefetcher.promotions == 1


def test_prefetch_disabled_config_is_inert():
    coe = _chain_coe()
    h = MemoryHierarchy(coe, NUMA, pools={"gpu": 200 * MB},
                        prefetch=PrefetchConfig(enabled=False))
    h.on_execute("up", now=0.0)
    assert h.residency("down") is Residency.DISK
    assert h.prefetcher.promotions == 0


def test_promoted_expert_costs_pcie_not_disk():
    coe = _chain_coe()
    h = MemoryHierarchy(coe, NUMA, pools={"gpu": 200 * MB},
                        prefetch=PrefetchConfig(enabled=True))
    h.on_execute("up", now=0.0)
    settle = h.host.ready_time("down") + 1.0
    tr = h.begin_device_load("down", now=settle)
    mem = coe.spec("down").mem_bytes
    assert tr.latency == pytest.approx(
        predicted_load_latency(NUMA, mem, in_host_cache=True))
    assert h.prefetcher.hits == 1


def test_cross_tier_prefetch_reduces_stall_end_to_end():
    """Acceptance: prefetch (device overlap + disk->host promotion) cuts
    total expert-switch stall time vs --prefetch off."""
    board = BoardSpec(name="T", n_components=80, n_active=20,
                      avg_quantity=4.0, n_detection=20,
                      detection_fraction=1.0, ok_prob=0.98, zipf_s=0.8)
    tier = TierSpec(name="t", disk_bw=530e6, host_to_device_bw=12e9,
                    unified=False, host_cache_bytes=2 << 30,
                    device_bytes=4 << 30)

    def run(policy):
        coe = build_board_coe(board)
        pools, specs = make_executor_specs(tier, 2, 0)
        system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier)
        sim = Simulation(system)
        sim.submit(make_task_requests(board, 600))
        return sim.run()

    on = run(COSERVE)
    off = run(dataclasses.replace(COSERVE, prefetch=False,
                                  host_prefetch=False))
    assert on.stall_time < off.stall_time
    assert on.memory["prefetch"]["promotions"] > 0


# --------------------------------------------------------------------------- #
# autoscaler device-budget accounting rides the hierarchy
# --------------------------------------------------------------------------- #

def test_hierarchy_tracks_construction_batch_budget():
    coe = make_coe(n_experts=8, seed=4)
    prof = device_profile("gpu", NUMA)
    specs = [ExecutorSpec("gpu", prof, 256 * MB, "gpu"),
             ExecutorSpec("gpu", prof, 256 * MB, "gpu")]
    system = CoServeSystem(coe, specs, {"gpu": 1 << 30}, policy=COSERVE,
                           tier=NUMA)
    assert system.hierarchy.batch_budget("gpu") == 512 * MB
