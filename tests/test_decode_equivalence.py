"""PR-9 decode-equivalence pinning: token-level continuous batching must be
strictly additive.

  * decode=off is bit-identical to the retained naive reference — final
    ``Metrics`` AND the recorded assign/arrange decision streams — across
    seeds x link layouts x host_exec on/off. Every decode branch on the hot
    paths degrades to one ``is None`` check, and this suite is the proof
    (the PR-7/8 reference-pinning discipline);
  * decode=on is *also* bit-identical fast-vs-reference: the KV reload-debt
    pricing arm lives in both ``assignment_cost`` and
    ``assignment_cost_ref``, and the token sampler is keyed by (seed,
    request), not draw order;
  * decode=on actually changes behaviour (guard against the config wiring
    silently dropping the runtime), completes every request, and reports
    the telemetry block; decode=off reports none.
"""
import dataclasses

import pytest

from conftest import run_board_system, strip_wall_clock
from repro.core import COSERVE, TierSpec
from repro.core.decode import DecodeConfig
from repro.core.workload import BoardSpec

MB = 1 << 20

HOST_EXEC = dataclasses.replace(COSERVE, host_exec=True)

# the simperf/hetero operating point: small pools, modest disk, Zipf-hot
# catalog — thrashy enough that loads/evictions/peer copies all fire
DEC_BOARD = BoardSpec(name="DQ", n_components=60, n_active=36,
                      avg_quantity=3.0, n_detection=8, zipf_s=1.6)
DEC_TIER = TierSpec(name="dec_numa", disk_bw=530e6, host_to_device_bw=12e9,
                    unified=False, host_cache_bytes=8 << 30,
                    device_bytes=4 << 30)

# decode config for the decode-on pairs: geometric lengths and small blocks
# so admission, growth, offload and reload all happen within 250 requests
DEC_CFG = DecodeConfig(tokens=10, tokens_dist="geometric", block_tokens=4,
                       token_bytes=4 * MB, kv_budget_fraction=0.3,
                       max_decode_batch=4)


def run_pair(seed, **kw):
    """(fast, reference) runs with recorded decision streams."""
    fast_log, ref_log = [], []
    fast, _ = run_board_system(DEC_BOARD, DEC_TIER, seed=seed,
                               decisions=fast_log, **kw)
    ref, _ = run_board_system(DEC_BOARD, DEC_TIER, seed=seed,
                              decisions=ref_log, reference=True, **kw)
    return fast, ref, fast_log, ref_log


# --------------------------------------------------------------------------- #
# decode=off: the stage-level simulation is untouched
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("links", ["shared", "per-device"])
@pytest.mark.parametrize("policy", [COSERVE, HOST_EXEC],
                         ids=["host_exec_off", "host_exec_on"])
def test_decode_off_bit_identical_to_reference(seed, links, policy):
    fast, ref, fast_log, ref_log = run_pair(seed, links=links, policy=policy)
    assert strip_wall_clock(fast) == strip_wall_clock(ref)
    assert fast_log == ref_log
    assert len(fast_log) >= 250          # every arrival was recorded
    # no decode telemetry exists on the stage-level path
    assert fast.decode == {} and ref.decode == {}


def test_decode_off_system_carries_no_runtime():
    _, system = run_board_system(DEC_BOARD, DEC_TIER, n_requests=20)
    assert system.decode is None
    assert system.hierarchy.kv is None
    assert all(ex.decode is None for ex in system.executors)
    assert all(p.kv_bytes == 0 for p in system.pools.values())


# --------------------------------------------------------------------------- #
# decode=on: the fast paths still equal the naive reference
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("links", ["shared", "per-device"])
def test_decode_on_bit_identical_to_reference(seed, links):
    cfg = dataclasses.replace(DEC_CFG, seed=seed)
    fast, ref, fast_log, ref_log = run_pair(seed, links=links, decode=cfg)
    assert strip_wall_clock(fast) == strip_wall_clock(ref)
    assert fast_log == ref_log
    assert fast.decode and fast.decode == ref.decode


@pytest.mark.parametrize("kv_evict", ["kv_aware", "weight_only"])
def test_decode_on_bit_identical_both_eviction_modes(kv_evict):
    cfg = dataclasses.replace(DEC_CFG, kv_evict=kv_evict)
    fast, ref, fast_log, ref_log = run_pair(0, decode=cfg)
    assert strip_wall_clock(fast) == strip_wall_clock(ref)
    assert fast_log == ref_log


def test_decode_on_with_host_exec_bit_identical():
    fast, ref, fast_log, ref_log = run_pair(1, policy=HOST_EXEC,
                                            decode=DEC_CFG)
    assert strip_wall_clock(fast) == strip_wall_clock(ref)
    assert fast_log == ref_log


# --------------------------------------------------------------------------- #
# decode=on semantics: additive, complete, and observable
# --------------------------------------------------------------------------- #

def test_decode_changes_metrics_at_all():
    """Guard against the config silently wiring to nothing: per-token
    completion must move latency/makespan."""
    off, _ = run_board_system(DEC_BOARD, DEC_TIER)
    on, _ = run_board_system(DEC_BOARD, DEC_TIER, decode=DEC_CFG)
    assert strip_wall_clock(off) != strip_wall_clock(on)
    assert on.avg_latency > off.avg_latency      # tokens take time


def test_decode_completes_every_request_and_counts_tokens():
    m, system = run_board_system(DEC_BOARD, DEC_TIER, decode=DEC_CFG)
    assert m.completed >= 250
    d = m.decode
    # geometric draws have mean cfg.tokens; every request emits >= 1 token
    assert d["tokens_out"] >= m.completed
    assert d["active"] == 0
    assert d["ttft"]["count"] == m.completed
    assert d["token"]["count"] == d["tokens_out"] - m.completed
    assert d["ttft"]["p99"] >= d["ttft"]["p50"] > 0.0


def test_fixed_token_count_is_exact():
    cfg = dataclasses.replace(DEC_CFG, tokens=7, tokens_dist="fixed")
    m, _ = run_board_system(DEC_BOARD, DEC_TIER, n_requests=100, decode=cfg)
    assert m.decode["tokens_out"] == 7 * m.completed


def test_token_draws_are_order_independent():
    """The per-request length comes from a (seed, request-id)-keyed stream,
    so two runs with different interleavings (shared vs per-device links)
    emit identical token totals."""
    a, _ = run_board_system(DEC_BOARD, DEC_TIER, links="shared",
                            decode=DEC_CFG)
    b, _ = run_board_system(DEC_BOARD, DEC_TIER, links="per-device",
                            decode=DEC_CFG)
    assert a.decode["tokens_out"] == b.decode["tokens_out"]
