"""DeploymentSpec API: round-trip property, CLI-flags-vs-spec equivalence
against the pre-refactor wiring, trace/plan artifact reuse, suite-registry
filename validation, the observed eviction policy and deprecation shims."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.api import (BoardSection, DeploymentSpec, FleetSection,
                       MemorySection, ModelSpec, PolicySection, Session,
                       ServingSection, SpecError, TenantSection,
                       WorkloadSection, build_catalog, build_layout,
                       build_system, load_plan, load_trace, make_requests,
                       resolve_policy, resolve_tier, save_plan, save_trace)
from repro.core import COSERVE, CoServeSystem, Simulation
from repro.fleet import (PlacementPlan, SearchConfig, WorkloadTrace,
                         replay_cost, search_placement, trace_from_requests,
                         validate_pool_groups)
from repro.launch.serve import build_parser, spec_from_args
from repro.memory import POLICY_NAMES, EvictionView, make_policy

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# seeded-random round-trip property
# --------------------------------------------------------------------------- #

def _random_spec(rng: np.random.RandomState) -> DeploymentSpec:
    """A random VALID spec: mode-consistent model kind + tenants."""
    mode = ("sim", "real", "online")[rng.randint(3)]
    engine = ("sim", "real")[rng.randint(2)] if mode == "online" else "sim"
    boards = ()
    if rng.rand() < 0.5:
        boards = (BoardSection(
            name=f"R{rng.randint(100)}", n_components=int(rng.randint(8, 80)),
            n_active=int(rng.randint(1, 8)), zipf_s=float(rng.rand() * 2)),)
    names = [b.name for b in boards] + ["A", "B"]
    tenants = ()
    real_exec = mode == "real" or engine == "real"
    if mode == "online" and engine == "sim":
        kind = "tenants"
        tenants = tuple(
            TenantSection(
                name=f"t{i}", board=names[rng.randint(len(names))],
                rate=float(1 + rng.rand() * 40),
                arrival=("poisson", "bursty", "diurnal",
                         "step")[rng.randint(4)],
                request_class=("scan", "random")[rng.randint(2)],
                slo_seconds=float(0.5 + rng.rand() * 5),
                seed=int(rng.randint(10)) if rng.rand() < 0.5 else None)
            for i in range(rng.randint(1, 4)))
    elif real_exec:
        kind = "tiny"
        if engine == "real":
            tenants = (TenantSection(name="local", rate=20.0),)
    else:
        kind = "board"
    fleet = FleetSection() if real_exec else FleetSection(
        devices=int(rng.randint(1, 5)),
        gpu_per_device=int(rng.randint(1, 4)), cpu=int(rng.randint(3)),
        links=("shared", "per-device")[rng.randint(2)],
        replication=int(rng.randint(3)),
        peer_bw_gbps=float(rng.choice([0.0, 25.0, 50.0])),
        placement=("greedy", "search")[rng.randint(2)])
    return DeploymentSpec(
        model=ModelSpec(kind=kind,
                        board=names[rng.randint(len(names))]
                        if kind == "board" else "A",
                        boards=boards),
        fleet=fleet,
        memory=MemorySection(
            tier=("numa", "uma", "tpu_v5e")[rng.randint(3)],
            prefetch=(None, "off", "device", "all")[rng.randint(4)],
            prefetch_trigger=(None, "exec", "queue")[rng.randint(3)],
            device_bytes=int(rng.randint(1, 32)) << 30
            if rng.rand() < 0.5 else None),
        policy=PolicySection(
            name=("coserve", "coserve_none", "samba")[rng.randint(3)],
            evict=(None, *POLICY_NAMES)[rng.randint(
                1 + len(POLICY_NAMES))]),
        serving=ServingSection(
            mode=mode, engine=engine,
            admission=("none", "queue_depth", "deadline",
                       "token_bucket")[rng.randint(4)],
            autoscale=("auto", "none", "2,6")[rng.randint(3)],
            slo_priority=bool(rng.rand() < 0.5),
            tick=float(0.1 + rng.rand())),
        workload=WorkloadSection(requests=int(rng.randint(1, 3000)),
                                 interval_s=float(0.001 + rng.rand() * 0.01),
                                 tenants=tenants),
        seed=int(rng.randint(100)))


@pytest.mark.parametrize("seed", range(25))
def test_spec_round_trip_property(seed, tmp_path):
    spec = _random_spec(np.random.RandomState(seed))
    assert DeploymentSpec.from_dict(spec.to_dict()) == spec
    path = str(tmp_path / "s.json")
    spec.save(path)
    assert DeploymentSpec.load(path) == spec
    # canonical serialization is byte-stable
    DeploymentSpec.load(path).save(str(tmp_path / "s2.json"))
    assert open(path).read() == open(str(tmp_path / "s2.json")).read()


def test_example_specs_round_trip_and_are_canonical():
    specs_dir = os.path.join(ROOT, "examples", "specs")
    files = sorted(f for f in os.listdir(specs_dir) if f.endswith(".json"))
    assert {"sim.json", "online_fleet.json", "real.json"} <= set(files)
    for f in files:
        path = os.path.join(specs_dir, f)
        spec = DeploymentSpec.load(path)
        assert DeploymentSpec.from_dict(spec.to_dict()) == spec
        canonical = json.dumps(spec.to_dict(), indent=2, sort_keys=True) + "\n"
        assert open(path).read() == canonical, f"{f} not canonical"


# --------------------------------------------------------------------------- #
# eager validation with actionable errors
# --------------------------------------------------------------------------- #

def test_unknown_key_rejected_with_known_keys():
    with pytest.raises(SpecError, match="unknown key.*devcies.*known keys"):
        DeploymentSpec.from_dict({"fleet": {"devcies": 2}})


def test_real_mode_rejects_fleet_shape():
    with pytest.raises(SpecError, match="single-device shared-link"):
        DeploymentSpec(model=ModelSpec(kind="tiny"),
                       fleet=FleetSection(devices=2),
                       serving=ServingSection(mode="real"))


def test_mode_kind_mismatch_is_actionable():
    with pytest.raises(SpecError, match='kind="tiny"'):
        DeploymentSpec(model=ModelSpec(kind="board"),
                       serving=ServingSection(mode="real"))


def test_plan_placement_requires_path_and_vice_versa():
    with pytest.raises(SpecError, match="plan_path"):
        FleetSection(placement="plan")
    with pytest.raises(SpecError, match="plan_path"):
        FleetSection(placement="greedy", plan_path="x.json")
    with pytest.raises(SpecError, match="trace_path"):
        FleetSection(placement="greedy", trace_path="x.json")


def test_unknown_board_and_duplicate_tenants_rejected():
    with pytest.raises(SpecError, match="unknown board"):
        DeploymentSpec(model=ModelSpec(kind="tenants"),
                       serving=ServingSection(mode="online"),
                       workload=WorkloadSection(tenants=(
                           TenantSection(name="t", board="Z"),)))
    with pytest.raises(SpecError, match="duplicate tenant names"):
        WorkloadSection(tenants=(TenantSection(name="t"),
                                 TenantSection(name="t")))


def test_bad_autoscale_and_tick_rejected():
    with pytest.raises(SpecError, match="autoscale"):
        ServingSection(autoscale="lots")
    with pytest.raises(SpecError, match="tick"):
        ServingSection(tick=0.0)


def test_tenant_weights_must_match_tenant_count():
    with pytest.raises(SpecError, match="tenant_weights"):
        DeploymentSpec(model=ModelSpec(kind="tenants",
                                       tenant_weights=(1.0, 2.0)),
                       serving=ServingSection(mode="online"),
                       workload=WorkloadSection(tenants=(
                           TenantSection(name="a"),)))


# --------------------------------------------------------------------------- #
# CLI flags -> spec -> system equivalence (every mode), pinned against the
# pre-refactor wiring (inlined below exactly as launch/serve.py had it)
# --------------------------------------------------------------------------- #

def _legacy_sim(board_name, n_requests, n_gpu, n_cpu, policy=COSERVE):
    """run_sim's wiring before DeploymentSpec, verbatim."""
    from repro.core.workload import (BOARD_A, BOARD_B, build_board_coe,
                                     make_executor_specs, make_task_requests)
    from repro.memory import NUMA

    board = BOARD_A if board_name == "A" else BOARD_B
    coe = build_board_coe(board)
    pools, specs = make_executor_specs(NUMA, n_gpu, n_cpu)
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=NUMA)
    sim = Simulation(system)
    sim.submit(make_task_requests(board, n_requests))
    return sim.run()


def test_sim_flags_vs_spec_equivalence():
    from repro.launch.serve import main
    legacy = _legacy_sim("A", 150, 2, 0)
    res = main(["--mode", "sim", "--requests", "150", "--executors", "2,0"])
    assert res["completed"] == legacy.completed
    assert res["switches"] == legacy.switches
    assert res["throughput"] == round(legacy.throughput, 2)
    assert res["makespan_s"] == round(legacy.makespan, 2)
    assert res["avg_latency_s"] == round(legacy.avg_latency, 4)


def _legacy_online(n_requests, rates, n_gpu=3, n_cpu=1, seed=0):
    """run_online's wiring before DeploymentSpec, verbatim (no admission,
    no autoscaling, default EDF + tick)."""
    from repro.core.workload import make_executor_specs
    from repro.memory import NUMA
    from repro.serve import (BOARDS, OnlineGateway, TenantSpec,
                             merge_board_coe)

    tenants = [TenantSpec(name=n, board=BOARDS[n], rate=r,
                          process="poisson", request_class="scan",
                          slo_seconds=2.0, seed=seed + i)
               for i, (n, r) in enumerate(zip("AB", rates))]
    coe = merge_board_coe([t.board for t in tenants],
                          weights=[t.rate for t in tenants])
    pools, specs = make_executor_specs(NUMA, n_gpu, n_cpu)
    system = CoServeSystem(coe, specs, pools, policy=COSERVE, tier=NUMA)
    gw = OnlineGateway(system, tenants)
    return gw.run(max_requests=n_requests)


def test_online_flags_vs_spec_equivalence():
    from repro.launch.serve import main
    legacy = _legacy_online(150, (20.0, 10.0))
    res = main(["--mode", "online", "--requests", "150",
                "--rates", "20,10", "--slos", "2.0", "--autoscale", "none"])
    # identical streams, identical system: the whole report matches
    assert res["completed"] == legacy.metrics.completed
    assert res["switches"] == legacy.metrics.switches
    assert res["latency_s"]["p99"] == round(legacy.metrics.p99_latency, 4)
    assert res["throughput"] == round(legacy.metrics.throughput, 3)


def test_real_mode_spec_equivalence_structure():
    """Real-engine timings are wall-clock; equivalence is structural: same
    catalog, same request stream, all requests served."""
    from repro.launch.serve import main
    res = main(["--mode", "real", "--requests", "20"])
    assert res["mode"] == "real" and res["completed"] == 20
    assert sorted(res) == ["completed", "makespan_s", "mode", "policy",
                           "switches", "throughput"]


def test_online_real_spec_equivalence_structure():
    from repro.launch.serve import main
    res = main(["--mode", "online", "--engine", "real", "--requests", "15",
                "--rates", "30", "--autoscale", "none"])
    assert res["mode"] == "online" and res["engine"] == "real"
    assert res["tenants"]["local"]["request_class"] == "random"
    assert res["completed"] + res["shed"] == 15


@pytest.mark.parametrize("argv,mode,kind", [
    (["--mode", "sim", "--board", "B"], "sim", "board"),
    (["--mode", "real"], "real", "tiny"),
    (["--mode", "online", "--rates", "25,12", "--slos", "2,4"],
     "online", "tenants"),
    (["--mode", "online", "--engine", "real", "--rates", "30"],
     "online", "tiny"),
])
def test_spec_from_args_every_mode(argv, mode, kind):
    args = build_parser().parse_args(argv)
    spec = spec_from_args(args)
    assert spec.serving.mode == mode and spec.model.kind == kind
    assert DeploymentSpec.from_dict(spec.to_dict()) == spec


def test_dump_config_then_config_reproduces_run(tmp_path, capsys):
    from repro.launch.serve import main
    flags = ["--mode", "sim", "--requests", "120", "--executors", "2,0"]
    cfg = str(tmp_path / "spec.json")
    main(flags + ["--dump-config", cfg])
    direct = main(flags)
    via_config = main(["--config", cfg])
    assert direct == via_config


def test_config_merges_flag_overrides(tmp_path):
    """--config + config flags deep-merge: flag > file > default."""
    from repro.launch.serve import main
    cfg = str(tmp_path / "spec.json")
    main(["--mode", "sim", "--requests", "60", "--executors", "1,0",
          "--dump-config", cfg])
    merged = main(["--config", cfg, "--requests", "10",
                   "--dump-config", "-"])
    spec = DeploymentSpec.from_dict(merged)
    assert spec.workload.requests == 10          # flag wins
    assert spec.fleet.gpu_per_device == 1        # file wins over default
    assert spec.fleet.cpu == 0
    # no overrides -> the file verbatim
    verbatim = main(["--config", cfg, "--dump-config", "-"])
    assert DeploymentSpec.from_dict(verbatim) == DeploymentSpec.load(cfg)


def test_config_merge_validates_eagerly(tmp_path):
    """A bad flag/file combination fails loudly at merge time, naming the
    overriding flags."""
    from repro.launch.serve import main
    cfg = str(tmp_path / "spec.json")
    main(["--mode", "sim", "--requests", "60", "--dump-config", cfg])
    with pytest.raises(SystemExit, match="--host-place"):
        # host_place needs placement="search"; the file says greedy
        main(["--config", cfg, "--host-exec", "--host-place"])


# --------------------------------------------------------------------------- #
# trace / plan artifacts: save -> load -> search reuse
# --------------------------------------------------------------------------- #

SMALL_BOARD = BoardSection(name="S", n_components=24, n_active=16,
                           avg_quantity=2.0, n_detection=4, zipf_s=1.8)


def _fleet_spec(n_requests=120, **fleet_kw):
    fleet_kw.setdefault("devices", 2)
    fleet_kw.setdefault("gpu_per_device", 2)
    fleet_kw.setdefault("cpu", 0)
    fleet_kw.setdefault("links", "per-device")
    return DeploymentSpec(
        model=ModelSpec(kind="board", board="S", boards=(SMALL_BOARD,)),
        fleet=FleetSection(**fleet_kw),
        memory=MemorySection(tier="numa", device_bytes=2 << 30,
                             host_cache_bytes=8 << 30),
        serving=ServingSection(mode="sim"),
        workload=WorkloadSection(requests=n_requests))


def test_trace_artifact_round_trip(tmp_path):
    trace = WorkloadTrace(("a", "b", "a"), gap_s=0.01, exec_s=0.03)
    path = str(tmp_path / "t.json")
    save_trace(trace, path)
    assert load_trace(path) == trace


def test_artifact_kind_mismatch_is_actionable(tmp_path):
    trace_path = str(tmp_path / "t.json")
    save_trace(WorkloadTrace(("a",)), trace_path)
    with pytest.raises(ValueError, match="not a 'coserve.placement_plan'"):
        load_plan(trace_path, None)


def test_plan_artifact_round_trip_and_capacity_guard(tmp_path):
    spec = _fleet_spec()
    coe = build_catalog(spec)
    pools, _ = build_layout(spec, resolve_tier(spec))
    plan = PlacementPlan.build(coe, pools, replication=1)
    path = str(tmp_path / "p.json")
    save_plan(plan, path)
    reloaded = load_plan(path, coe, capacities=pools)
    assert reloaded.layout() == plan.layout()
    assert reloaded.assignments == plan.assignments
    with pytest.raises(ValueError, match="re-run the placement search"):
        load_plan(path, coe, capacities={"gpu0": 123})


def test_saved_trace_drives_search_and_saved_plan_skips_it(tmp_path):
    """ISSUE acceptance: dump trace -> search over it -> save plan ->
    reload via the spec -> identical system placement, no re-search."""
    spec = _fleet_spec()
    tier = resolve_tier(spec)
    coe = build_catalog(spec)
    pools, especs = build_layout(spec, tier)
    requests = make_requests(spec)
    trace = trace_from_requests(coe, requests[:128])
    trace_path = str(tmp_path / "trace.json")
    save_trace(trace, trace_path)

    # search over the SAVED trace through the spec
    searched_spec = dataclasses.replace(
        spec, fleet=dataclasses.replace(spec.fleet, placement="search",
                                        trace_path=trace_path,
                                        replication=1))
    sess = Session(searched_spec)
    report = sess.ctx.search_report
    assert report is not None
    assert report["cost_s"] <= report["seed_cost_s"] + 1e-9

    # the searched plan scores exactly like a direct search over the trace
    greedy = PlacementPlan.build(coe, pools, replication=1)
    direct = search_placement(
        coe, pools, load_trace(trace_path), tier, links="per-device",
        pool_devices=validate_pool_groups(especs), seed_plan=greedy,
        config=SearchConfig(seed=spec.seed, replication=1))
    assert sess.system.placement.assignments == direct.plan.assignments

    # save the served plan; a placement="plan" spec applies it verbatim
    plan_path = str(tmp_path / "plan.json")
    sess.save_plan(plan_path)
    plan_spec = dataclasses.replace(
        spec, fleet=dataclasses.replace(spec.fleet, placement="plan",
                                        plan_path=plan_path,
                                        replication=1))
    system2 = build_system(plan_spec)
    assert system2.placement.assignments == direct.plan.assignments
    # and it prices identically on the trace — the win is reproduced
    # without re-searching
    cost = replay_cost(coe, pools, system2.placement, trace, tier,
                       links="per-device",
                       pool_devices=validate_pool_groups(especs))
    assert cost == pytest.approx(direct.cost)


def test_session_dump_trace_roundtrips_observed_load(tmp_path):
    spec = _fleet_spec(n_requests=80)
    sess = Session(spec)
    sess.run()
    path = str(tmp_path / "obs.json")
    sess.save_trace(path)
    trace = load_trace(path)
    assert trace.events
    served = {e for e in sess.system.expert_load}
    assert set(trace.events) <= served


def test_session_single_shot_and_submit_guard():
    spec = _fleet_spec(n_requests=40)
    sess = Session(spec)
    sess.run()
    with pytest.raises(RuntimeError, match="single-shot"):
        sess.run()
    online = DeploymentSpec(
        model=ModelSpec(kind="tenants"),
        serving=ServingSection(mode="online"),
        workload=WorkloadSection(requests=10, tenants=(
            TenantSection(name="A", board="A"),)))
    with pytest.raises(ValueError, match="online"):
        Session(online).submit([])


# --------------------------------------------------------------------------- #
# benchmark suite registry: artifact filenames follow the registered key
# --------------------------------------------------------------------------- #

def test_suite_registry_outpaths_match_keys():
    import sys
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import suite_out_paths, validate_registry
    finally:
        sys.path.pop(0)
    validate_registry()   # must not raise on the real registry
    outs = suite_out_paths()
    for key in ("online", "memory", "fleet", "placement"):
        assert outs[key] == f"BENCH_{key}.json"


def test_suite_registry_detects_mismatch(monkeypatch):
    import sys
    sys.path.insert(0, ROOT)
    try:
        from benchmarks import bench_fleet, run as bench_run
    finally:
        sys.path.pop(0)
    monkeypatch.setattr(bench_fleet, "OUT_PATH", "BENCH_wrong.json")
    with pytest.raises(RuntimeError, match="fleet.*BENCH_fleet.json"):
        bench_run.validate_registry()


# --------------------------------------------------------------------------- #
# observed eviction policy
# --------------------------------------------------------------------------- #

def _view(coe, candidates, observed=None):
    order = {e: i for i, e in enumerate(candidates)}
    return EvictionView(coe=coe, candidates=list(candidates),
                        use_order=order, insert_order=order,
                        resident=set(candidates), observed_load=observed)


def test_observed_policy_registered_and_in_sweep_names():
    assert "observed" in POLICY_NAMES
    assert make_policy("observed").name == "observed"


def test_observed_policy_protects_hot_experts():
    spec = _fleet_spec()
    coe = build_catalog(spec)
    cands = sorted(coe.experts)[:6]
    observed = {cands[0]: 50, cands[1]: 3}   # cands[2:] never ran
    order = make_policy("observed").order(_view(coe, cands, observed))
    # never-observed experts go first, the hottest observed expert last
    assert order[-1] == cands[0] and order[-2] == cands[1]
    assert set(order[:4]) == set(cands[2:])


def test_observed_policy_cold_start_falls_back_to_dependency_prob():
    spec = _fleet_spec()
    coe = build_catalog(spec)
    cands = sorted(coe.experts)[:8]
    dep = make_policy("dependency_prob").order(_view(coe, cands))
    assert make_policy("observed").order(_view(coe, cands, None)) == dep
    assert make_policy("observed").order(_view(coe, cands, {})) == dep
    # all-equal observations tie-break by the dependency_prob order too
    assert make_policy("observed").order(
        _view(coe, cands, {e: 1 for e in cands})) == dep


def test_system_wires_observed_load_into_manager_and_host():
    spec = dataclasses.replace(_fleet_spec(n_requests=60),
                               policy=PolicySection(evict="observed"))
    sess = Session(spec)
    system = sess.system
    assert system.manager.observed_load is system.expert_load
    assert system.hierarchy.host.observed_load is system.expert_load
    res = sess.run()
    assert res["completed"] == 60
    assert system.expert_load      # counts accumulated during the run


def test_observed_evict_via_cli_flag():
    from repro.launch.serve import main
    res = main(["--mode", "sim", "--requests", "80", "--executors", "1,0",
                "--evict", "observed"])
    assert res["completed"] == 80


# --------------------------------------------------------------------------- #
# deprecation shims
# --------------------------------------------------------------------------- #

def test_build_multi_board_coe_shim_warns_and_matches():
    from repro.core.workload import BOARD_A
    from repro.serve import build_multi_board_coe, merge_board_coe

    with pytest.warns(DeprecationWarning, match="DeploymentSpec"):
        old = build_multi_board_coe([BOARD_A], weights=[1.0])
    new = merge_board_coe([BOARD_A], weights=[1.0])
    assert sorted(old.experts) == sorted(new.experts)


def test_run_online_shim_warns_and_runs():
    from repro.launch import serve

    args = build_parser().parse_args(
        ["--mode", "online", "--requests", "40", "--rates", "30",
         "--autoscale", "none"])
    with pytest.warns(DeprecationWarning, match="Session"):
        res = serve.run_online(args)
    assert res["mode"] == "online" and res["completed"] > 0
