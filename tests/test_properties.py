"""Property-based tests (hypothesis) on the system's invariants."""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (COSERVE, CoServeSystem, ExpertSpec, Request,
                        RoutingModule, Simulation, SystemPolicy, TierSpec)
from repro.core.coe import CoEModel
from repro.core.expert_manager import ExpertManager
from repro.core.memory import ModelPool
from repro.core.profiler import fit_latency_line
from repro.core.scheduler import Group, split_batch
from repro.core.workload import device_profile
from repro.core.serving import ExecutorSpec

MB = 1 << 20
TIER = TierSpec(name="prop", unified=False, host_cache_bytes=1 << 30,
                device_bytes=2 << 30)


# --------------------------------------------------------------------------- #
# CoE model construction helpers (drawn by hypothesis)
# --------------------------------------------------------------------------- #

def make_coe(n_experts: int, seed: int) -> CoEModel:
    rng = np.random.RandomState(seed)
    experts = []
    arches = ["resnet101", "yolov5m", "yolov5l"]
    for i in range(n_experts):
        deps = ()
        if i >= n_experts // 2 and rng.rand() < 0.5:
            deps = (f"e{rng.randint(0, n_experts // 2):03d}",)
        experts.append(ExpertSpec(
            id=f"e{i:03d}", arch=arches[i % 3],
            mem_bytes=int(rng.randint(50, 250)) * MB,
            depends_on=deps, usage_prob=float(rng.rand())))
    routing = RoutingModule(lambda d: f"e{d % n_experts:03d}")
    return CoEModel(experts, routing)


def make_requests(coe: CoEModel, n: int, seed: int):
    rng = np.random.RandomState(seed)
    ids = list(coe.experts)
    return [Request(id=i, expert_id=ids[rng.randint(len(ids))],
                    arrival_time=i * 0.004) for i in range(n)]


# --------------------------------------------------------------------------- #
# scheduler invariants
# --------------------------------------------------------------------------- #

@settings(max_examples=30, deadline=None)
@given(st.integers(8, 40), st.integers(20, 150), st.integers(0, 10_000),
       st.sampled_from(["makespan", "round_robin"]),
       st.booleans())
def test_no_request_lost_or_duplicated(n_experts, n_requests, seed, assign,
                                       arrange):
    """Every submitted request completes exactly once under any policy."""
    coe = make_coe(n_experts, seed)
    policy = SystemPolicy(name="p", assign=assign, arrange=arrange)
    prof = device_profile("gpu", TIER)
    specs = [ExecutorSpec("gpu", prof, 512 * MB, "gpu"),
             ExecutorSpec("gpu", prof, 512 * MB, "gpu")]
    system = CoServeSystem(coe, specs, {"gpu": 1 << 30}, policy=policy,
                           tier=TIER)
    sim = Simulation(system)
    reqs = make_requests(coe, n_requests, seed)
    sim.submit(reqs)
    m = sim.run()
    assert m.completed == n_requests
    done_ids = sorted(r.id for r in sim.completed)
    assert done_ids == sorted(r.id for r in reqs)


@settings(max_examples=30, deadline=None)
@given(st.integers(8, 40), st.integers(20, 120), st.integers(0, 10_000))
def test_arranging_groups_unique_experts(n_experts, n_requests, seed):
    """With arranging ON, a queue never holds two groups of the same expert
    (the paper's 'expert loads at most once per group' guarantee)."""
    coe = make_coe(n_experts, seed)
    prof = device_profile("gpu", TIER)
    specs = [ExecutorSpec("gpu", prof, 512 * MB, "gpu")]
    system = CoServeSystem(coe, specs, {"gpu": 1 << 30}, policy=COSERVE,
                           tier=TIER)
    for r in make_requests(coe, n_requests, seed):
        ex = system.scheduler.assign(r, 0.0)
        seen = [g.expert_id for g in ex.queue]
        assert len(seen) == len(set(seen)), "duplicate same-expert groups"


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16))
def test_split_batch_caps_and_preserves_order(n, cap):
    reqs = [Request(id=i, expert_id="e") for i in range(n)]
    group = Group(expert_id="e", requests=list(reqs))
    batches = []
    while group.requests:
        batches.append(split_batch(group, cap))
    assert all(len(b) <= max(1, cap) for b in batches)
    flat = [r.id for b in batches for r in b]
    assert flat == [r.id for r in reqs]            # order preserved, no loss


# --------------------------------------------------------------------------- #
# expert-manager invariants
# --------------------------------------------------------------------------- #

@settings(max_examples=50, deadline=None)
@given(st.integers(6, 30), st.integers(0, 10_000),
       st.sampled_from(["dependency_prob", "prob", "lru", "fifo",
                        "cost_benefit"]))
def test_eviction_frees_enough_and_never_incoming(n_experts, seed, policy):
    coe = make_coe(n_experts, seed)
    rng = np.random.RandomState(seed)
    pool = ModelPool(1 << 30, coe, group="gpu")
    for eid in list(coe.experts)[: n_experts // 2]:
        if coe.spec(eid).mem_bytes <= pool.free_bytes():
            pool.add(eid)
            pool.ready.add(eid)
    mgr = ExpertManager(coe, policy=policy)
    incoming = list(coe.experts)[-1]
    free_before = pool.free_bytes()
    victims = mgr.pick_victims(pool, incoming,
                               load_cost_fn=lambda e: 1.0)
    if victims is None:
        return  # impossible to fit: acceptable outcome
    assert incoming not in victims
    freed = sum(coe.spec(v).mem_bytes for v in victims)
    assert free_before + freed >= coe.spec(incoming).mem_bytes
    # minimality-ish: removing the last victim must leave a shortfall
    if victims:
        assert (free_before + freed - coe.spec(victims[-1]).mem_bytes
                < coe.spec(incoming).mem_bytes)


@settings(max_examples=50, deadline=None)
@given(st.integers(6, 30), st.integers(0, 10_000))
def test_strict_mode_never_evicts_protected(n_experts, seed):
    coe = make_coe(n_experts, seed)
    pool = ModelPool(1 << 30, coe, group="gpu")
    resident = []
    for eid in list(coe.experts)[: n_experts // 2]:
        if coe.spec(eid).mem_bytes <= pool.free_bytes():
            pool.add(eid)
            pool.ready.add(eid)
            resident.append(eid)
    mgr = ExpertManager(coe, policy="dependency_prob")
    protected = set(resident[: len(resident) // 2])
    incoming = list(coe.experts)[-1]
    victims = mgr.pick_victims(pool, incoming, protected=protected,
                               strict=True)
    if victims is not None:
        assert not (set(victims) & protected)


@settings(max_examples=30, deadline=None)
@given(st.integers(6, 30), st.integers(0, 10_000))
def test_two_stage_order_stage1_before_stage2(n_experts, seed):
    """Dependency-stage victims (blocked downstream experts) always precede
    probability-stage victims in the eviction order."""
    coe = make_coe(n_experts, seed)
    pool = ModelPool(1 << 62, coe, group="gpu")
    for eid in coe.experts:
        pool.add(eid)
        pool.ready.add(eid)
    mgr = ExpertManager(coe, policy="dependency_prob")
    incoming = list(coe.experts)[0]
    order = mgr._eviction_order(pool, incoming)
    resident = set(pool.resident) | {incoming}
    def blocked(eid):
        s = coe.spec(eid)
        return s.is_dependent and not any(u in resident for u in s.depends_on)
    flags = [blocked(e) for e in order]
    # all True flags must come before any False flag
    assert flags == sorted(flags, reverse=True)


# --------------------------------------------------------------------------- #
# profiler invariants
# --------------------------------------------------------------------------- #

@settings(max_examples=50, deadline=None)
@given(st.floats(1e-4, 1.0), st.floats(0.0, 1.0),
       st.lists(st.integers(1, 64), min_size=2, max_size=10, unique=True))
def test_fit_latency_line_recovers_kb(k, b, batches):
    lats = [k * n + b for n in batches]
    k2, b2 = fit_latency_line(batches, lats)
    assert abs(k2 - k) < 1e-6 + 1e-3 * k
    assert abs(b2 - b) < 1e-6 + 1e-3 * max(b, k)


# --------------------------------------------------------------------------- #
# CoE probability assessment
# --------------------------------------------------------------------------- #

@settings(max_examples=30, deadline=None)
@given(st.integers(4, 24), st.integers(0, 10_000))
def test_usage_probabilities_conserve_mass(n_experts, seed):
    """First-expert probabilities sum to the input-distribution mass (1.0);
    chained probabilities are each <= their upstream's probability."""
    coe = make_coe(n_experts, seed)
    dist = {i: 1.0 / n_experts for i in range(n_experts)}
    coe2 = coe.assess_usage_probabilities(dist)
    firsts = [coe2.spec(f"e{i:03d}").usage_prob for i in range(n_experts)]
    assert all(p >= 0 for p in firsts)
    total_first = sum(dist.values())
    assert sum(firsts) >= total_first - 1e-9     # chains only add mass


def test_dependency_cycle_detected():
    a = ExpertSpec(id="a", arch="resnet101", mem_bytes=MB, depends_on=("b",))
    b = ExpertSpec(id="b", arch="resnet101", mem_bytes=MB, depends_on=("a",))
    coe = CoEModel([a, b], RoutingModule(lambda d: "a",
                                         chain_prob={"a": {"b": 1.0},
                                                     "b": {"a": 1.0}}))
    with pytest.raises(ValueError, match="cycle"):
        coe.assess_usage_probabilities({0: 1.0})


# --------------------------------------------------------------------------- #
# sharding: divisibility fallback
# --------------------------------------------------------------------------- #

class _FakeMesh:
    """resolve_spec only reads axis_names + devices.shape — emulate the
    production 16x16 (and 2x16x16) meshes without 512 devices."""
    def __init__(self, shape, names):
        self.devices = np.empty(shape)
        self.axis_names = names


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 4096), st.booleans(),
       st.booleans())
def test_resolve_spec_only_divisible(dim0, dim1, use_model, multi_pod):
    from repro.sharding.logical import resolve_spec
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model")) if multi_pod \
        else _FakeMesh((16, 16), ("data", "model"))
    rules = {"a": ("pod", "data"), "b": ("model",) if use_model else ("data",)}
    spec = resolve_spec((dim0, dim1), ("a", "b"), mesh, rules)
    # every named axis in the spec must divide its dim, each axis used once
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = []
    for dim, entry in zip((dim0, dim1), tuple(spec) + (None,) * 2):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        shard = 1
        for ax in axes:
            shard *= sizes[ax]
            used.append(ax)
        assert dim % shard == 0
    assert len(used) == len(set(used))


@settings(max_examples=40, deadline=None)
@given(st.sampled_from([24, 32, 48, 16, 12, 8]), st.booleans())
def test_head_dim_fallback_consistency(heads, multi_pod):
    """Heads that 16 does not divide must fall back to replication (not
    crash, not mis-shard) — the starcoder2 (24H) / qwen2 (12H) cases."""
    from repro.sharding.logical import resolve_spec
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model")) if multi_pod \
        else _FakeMesh((16, 16), ("data", "model"))
    spec = resolve_spec((heads, 128), ("heads", None), mesh,
                        {"heads": ("model",)})
    entry = tuple(spec)[0] if len(tuple(spec)) else None
    if heads % 16 == 0:
        assert entry == "model"
    else:
        assert entry is None
