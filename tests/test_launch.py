"""Launch-layer tests: train driver resume path, real-engine serving,
elastic autoscaling (deliverables b/e substrate)."""
import numpy as np
import pytest

from repro.core import COSERVE, CoServeSystem, Request, Simulation, TierSpec
from repro.core.workload import (BoardSpec, build_board_coe,
                                 make_executor_specs, make_task_requests)
from repro.launch.elastic import ElasticController, ElasticPolicy


# --------------------------------------------------------------------------- #
# train driver
# --------------------------------------------------------------------------- #

def test_train_driver_runs_and_resumes(tmp_path):
    from repro.launch.train import main
    ck = str(tmp_path / "ckpt")
    h1 = main(["--preset", "smoke", "--steps", "6", "--batch", "2",
               "--seq", "32", "--ckpt-dir", ck, "--ckpt-every", "3",
               "--log-every", "2"])
    assert h1 and np.isfinite(h1[-1]["loss"])
    # restart continues from step 6 checkpoint
    h2 = main(["--preset", "smoke", "--steps", "8", "--batch", "2",
               "--seq", "32", "--ckpt-dir", ck, "--ckpt-every", "3",
               "--log-every", "1", "--resume"])
    assert h2[0]["step"] == 7


def test_train_driver_compressed_grads(tmp_path):
    from repro.launch.train import main
    h = main(["--preset", "smoke", "--steps", "4", "--batch", "2",
              "--seq", "32", "--ckpt-dir", str(tmp_path / "c"),
              "--ckpt-every", "100", "--log-every", "1", "--compress"])
    assert np.isfinite(h[-1]["loss"])


# --------------------------------------------------------------------------- #
# real-engine serving (actual JAX experts across host/disk tiers)
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def real_system():
    from repro.launch.serve import build_real_system
    return build_real_system(n_components=12, n_detection=2, pool_experts=4,
                             n_executors=2)


def test_real_engine_completes_all(real_system):
    from repro.core import run_real
    system, coe = real_system
    rng = np.random.RandomState(0)
    reqs = [Request(id=i, expert_id=f"cls{rng.randint(12):03d}",
                    data={"component": 0, "x": rng.randn(64).astype(np.float32),
                          "needs_detection": False, "det_expert": 0})
            for i in range(60)]
    m = run_real(system, reqs)
    assert m.completed == 60
    assert m.switches > 0          # the pool is smaller than the expert set
    assert all(r.result in ("ok", "defect") for r in reqs)


def test_real_engine_coserve_switches_less_than_fcfs():
    """Through the REAL execution path too, dependency-aware scheduling +
    eviction must cut expert switches vs the Samba-style FCFS+LRU baseline
    (wall-clock jitter shifts event order run-to-run, so we compare policies,
    not exact counts)."""
    from repro.core import SAMBA_PARALLEL, run_real
    from repro.launch.serve import build_real_system

    def run(policy):
        system, _ = build_real_system(n_components=12, n_detection=2,
                                      pool_experts=4, n_executors=2,
                                      policy=policy)
        rng = np.random.RandomState(3)
        reqs = [Request(id=i, expert_id=f"cls{rng.randint(12):03d}",
                        data={"component": 0,
                              "x": rng.randn(64).astype(np.float32),
                              "needs_detection": False, "det_expert": 0})
                for i in range(80)]
        return run_real(system, reqs)

    co, fcfs = run(COSERVE), run(SAMBA_PARALLEL)
    assert co.completed == fcfs.completed == 80
    assert co.switches < fcfs.switches


# --------------------------------------------------------------------------- #
# elastic autoscaling
# --------------------------------------------------------------------------- #

BOARD = BoardSpec(name="T", n_components=60, n_active=36, n_detection=8)
TIER = TierSpec(name="t", unified=False, host_cache_bytes=2 << 30,
                device_bytes=4 << 30)


def _system(n_gpu):
    coe = build_board_coe(BOARD)
    pools, specs = make_executor_specs(TIER, n_gpu, 0)
    return CoServeSystem(coe, specs, pools, policy=COSERVE, tier=TIER), specs


def test_elastic_scales_up_under_load():
    system, specs = _system(1)
    ctl = ElasticController(system, specs[0],
                            ElasticPolicy(max_executors=4,
                                          scale_up_pending_s=0.5))
    sim = Simulation(system)
    sim.submit(make_task_requests(BOARD, 500, interval=0.001))  # burst
    ctl.install(sim, horizon_s=30.0)
    m = sim.run()
    assert m.completed == 500
    assert any(a["action"] == "add" for a in ctl.actions), "never scaled up"


def test_elastic_drain_loses_nothing():
    system, specs = _system(3)
    ctl = ElasticController(system, specs[0],
                            ElasticPolicy(min_executors=1,
                                          scale_down_pending_s=10.0,
                                          scale_up_pending_s=1e9))
    sim = Simulation(system)
    sim.submit(make_task_requests(BOARD, 300))
    ctl.install(sim, horizon_s=5.0)   # aggressive shrink while work remains
    m = sim.run()
    assert m.completed == 300
    assert any(a["action"] == "remove" for a in ctl.actions)


def test_elastic_respects_bounds():
    system, specs = _system(2)
    pol = ElasticPolicy(min_executors=2, max_executors=3,
                        scale_up_pending_s=0.1, scale_down_pending_s=0.0)
    ctl = ElasticController(system, specs[0], pol)
    sim = Simulation(system)
    sim.submit(make_task_requests(BOARD, 400, interval=0.001))
    ctl.install(sim, horizon_s=20.0)
    sim.run()
    assert len(system.live_executors()) <= pol.max_executors
    assert len(system.live_executors()) >= pol.min_executors
