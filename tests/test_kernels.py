"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in kernels/ref.py (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOL[jnp.bfloat16 if jnp.dtype(dtype) == jnp.bfloat16 else jnp.float32]


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,s,t,d", [
    (1, 4, 4, 128, 128, 64),     # MHA, square
    (2, 4, 2, 128, 128, 64),     # GQA group 2
    (1, 8, 2, 256, 256, 64),     # GQA group 4, two q blocks
    (1, 4, 1, 128, 256, 64),     # MQA, cached prefix (t > s)
    (2, 4, 4, 128, 128, 128),    # head_dim 128 (MXU width)
])
def test_flash_attention_matches_ref(b, h, hkv, s, t, d, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(keys[0], (b, h, s, d), dtype)
    k = rand(keys[1], (b, hkv, t, d), dtype)
    v = rand(keys[2], (b, hkv, t, d), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    b, h, s, d = 1, 4, 256, 64
    q = rand(keys[0], (b, h, s, d), jnp.float32)
    k = rand(keys[1], (b, h, s, d), jnp.float32)
    v = rand(keys[2], (b, h, s, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(block_q, block_k):
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    b, h, s, d = 1, 2, 256, 64
    q = rand(keys[0], (b, h, s, d), jnp.float32)
    k = rand(keys[1], (b, h, s, d), jnp.float32)
    v = rand(keys[2], (b, h, s, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=block_q,
                          block_k=block_k, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(keys[0], (1, 2, 128, 64), jnp.float32)
    k = rand(keys[1], (1, 2, 128, 64), jnp.float32)
    v = rand(keys[2], (1, 2, 128, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# decode attention (flash-decoding style, ring cache)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,w,d,pos", [
    (1, 4, 4, 128, 64, 64),      # partially-filled cache
    (2, 4, 2, 128, 64, 127),     # cache exactly full
    (1, 8, 2, 256, 64, 300),     # ring wrap-around (pos > W)
    (2, 4, 1, 128, 128, 100),    # MQA, wide head
])
def test_decode_attention_matches_ref(b, h, hkv, w, d, pos, dtype):
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(keys[0], (b, h, d), dtype)
    k = rand(keys[1], (b, hkv, w, d), dtype)
    v = rand(keys[2], (b, hkv, w, d), dtype)
    out = decode_attention(q, k, v, pos, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 96])
def test_decode_attention_window(window):
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    b, h, w, d, pos = 1, 4, 128, 64, 500
    q = rand(keys[0], (b, h, d), jnp.float32)
    k = rand(keys[1], (b, h, w, d), jnp.float32)
    v = rand(keys[2], (b, h, w, d), jnp.float32)
    out = decode_attention(q, k, v, pos, window=window, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_block_sweep():
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    b, h, w, d, pos = 1, 2, 512, 64, 511
    q = rand(keys[0], (b, h, d), jnp.float32)
    k = rand(keys[1], (b, h, w, d), jnp.float32)
    v = rand(keys[2], (b, h, w, d), jnp.float32)
    want = ref.decode_attention_ref(q, k, v, pos)
    for block_k in (128, 256, 512):
        out = decode_attention(q, k, v, pos, block_k=block_k, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"block_k={block_k}")


# --------------------------------------------------------------------------- #
# mamba chunked scan
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,d,n,block_s,block_d", [
    (1, 128, 128, 16, 64, 128),   # two sequence chunks
    (2, 256, 256, 16, 128, 128),  # two channel blocks
    (1, 64, 128, 8, 64, 64),      # narrow state / small blocks
])
def test_mamba_scan_matches_ref(b, s, d, n, block_s, block_d, dtype):
    keys = jax.random.split(jax.random.PRNGKey(7), 6)
    x = rand(keys[0], (b, s, d), dtype)
    dt = jax.nn.softplus(rand(keys[1], (b, s, d), jnp.float32)).astype(dtype)
    b_mat = rand(keys[2], (b, s, n), dtype)
    c_mat = rand(keys[3], (b, s, n), dtype)
    a = -jnp.exp(rand(keys[4], (d, n), jnp.float32))  # stable (negative) A
    d_vec = rand(keys[5], (d,), jnp.float32)
    y, h = mamba_scan(x, dt, b_mat, c_mat, a, d_vec,
                      block_d=block_d, block_s=block_s, interpret=True)
    y_ref, h_ref = ref.mamba_scan_ref(x, dt, b_mat, c_mat, a, d_vec)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_ref, np.float32), **tol)


def test_mamba_scan_state_carry_chunk_boundary():
    """The carried state across chunk boundaries must equal the sequential
    scan's state — run one long scan vs. the same data with tiny chunks."""
    keys = jax.random.split(jax.random.PRNGKey(8), 6)
    b, s, d, n = 1, 96, 64, 16
    x = rand(keys[0], (b, s, d), jnp.float32)
    dt = jax.nn.softplus(rand(keys[1], (b, s, d), jnp.float32))
    b_mat = rand(keys[2], (b, s, n), jnp.float32)
    c_mat = rand(keys[3], (b, s, n), jnp.float32)
    a = -jnp.exp(rand(keys[4], (d, n), jnp.float32))
    d_vec = rand(keys[5], (d,), jnp.float32)
    y32, h32 = mamba_scan(x, dt, b_mat, c_mat, a, d_vec,
                          block_d=64, block_s=32, interpret=True)
    y96, h96 = mamba_scan(x, dt, b_mat, c_mat, a, d_vec,
                          block_d=64, block_s=96, interpret=True)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y96),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h32), np.asarray(h96),
                               rtol=1e-5, atol=1e-5)
