"""Analysis-layer tests: HLO collective parser, roofline math, config
bookkeeping (param counts, block patterns, applicable shapes)."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config
from repro.launch.hlo import count_collectives, parse_collective_bytes
from repro.models.config import ModelConfig


HLO_SAMPLE = """
HloModule jit_step
ENTRY %main {
  %p0 = bf16[16,1024,512]{2,1,0} parameter(0)
  %ag = bf16[16,1024,512]{2,1,0} all-gather(%p0), replica_groups={}
  %ar = f32[8,128]{1,0} all-reduce(%x), to_apply=%add
  ROOT %t = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(%a, %b), to_apply=%add
  %rs = bf16[2,64]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = u32[4]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = s32[16,16]{1,0} all-to-all(%w), dimensions={0}
}
"""


def test_parse_collective_bytes_kinds_and_sizes():
    out = parse_collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 16 * 1024 * 512 * 2
    assert out["all-reduce"] == 8 * 128 * 4 * 3          # single + tuple pair
    assert out["reduce-scatter"] == 2 * 64 * 2
    assert out["collective-permute"] == 4 * 4
    assert out["all-to-all"] == 16 * 16 * 4


def test_count_collectives():
    c = count_collectives(HLO_SAMPLE)
    assert c["all-reduce"] == 2
    assert c["all-gather"] == 1


def test_parser_ignores_non_collectives():
    assert parse_collective_bytes("%d = f32[4]{0} dot(%a, %b)") == {}


# --------------------------------------------------------------------------- #
# roofline math
# --------------------------------------------------------------------------- #

def test_roofline_analysis_terms():
    from benchmarks.roofline import analyse_cell
    rec = {"arch": "starcoder2_3b", "shape": "train_4k", "mesh": [16, 16],
           "roofline": {"flops": 1.97e14, "bytes_accessed": 819e9,
                        "collective_bytes": {"all-gather": 50e9}}}
    row = analyse_cell(rec)
    assert row["t_compute_s"] == pytest.approx(1.0)
    assert row["t_memory_s"] == pytest.approx(1.0)
    assert row["t_collective_s"] == pytest.approx(1.0)
    assert row["chips"] == 256
    assert 0 < row["useful_ratio"] < 1


def test_model_flops_decode_vs_train():
    from benchmarks.roofline import model_flops
    train = model_flops("starcoder2_3b", "train_4k")
    decode = model_flops("starcoder2_3b", "decode_32k")
    # train: 6N x 1M tokens; decode: 2N x 128 tokens
    assert train / decode == pytest.approx(
        (6 * 4096 * 256) / (2 * 128), rel=1e-6)


# --------------------------------------------------------------------------- #
# config bookkeeping
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch,expected_params_b", [
    ("starcoder2_3b", (2.5, 3.5)),
    ("mixtral_8x22b", (125, 150)),       # total (all experts)
    ("falcon_mamba_7b", (6.5, 8.0)),
    ("minitron_8b", (7.5, 9.5)),
])
def test_param_counts_in_published_range(arch, expected_params_b):
    n = get_config(arch).param_count() / 1e9
    lo, hi = expected_params_b
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_mixtral_active_params_much_smaller():
    cfg = get_config("mixtral_8x22b")
    assert cfg.param_count(active_only=True) < cfg.param_count() * 0.4


def test_jamba_block_pattern():
    cfg = get_config("jamba_v0_1_52b")
    pat = cfg.block_pattern()
    assert len(pat) == 8
    assert sum(1 for s in pat if s.mixer == "attn") == 1      # 1:7 interleave
    assert sum(1 for s in pat if s.ffn == "moe") == 4         # every other


def test_applicable_shapes_long_context_gating():
    longs = {a for a in ARCH_IDS
             if "long_500k" in applicable_shapes(get_config(a))}
    assert longs == {"jamba_v0_1_52b", "mixtral_8x22b", "falcon_mamba_7b"}


def test_all_archs_have_all_base_shapes():
    for a in ARCH_IDS:
        shapes = applicable_shapes(get_config(a))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_layers_divisible_by_period():
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.num_layers % cfg.period() == 0


def test_ep_split_helper():
    import os
    from repro.launch.specs import _ep_split

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    mix = get_config("mixtral_8x22b")
    moon = get_config("moonshot_v1_16b_a3b")
    dense = get_config("starcoder2_3b")
    assert _ep_split(dense, FakeMesh()) == 1
    assert _ep_split(moon, FakeMesh()) == 1       # 64 % 16 == 0: true EP
    assert _ep_split(mix, FakeMesh()) == 1        # default OFF (GSPMD regress)
    os.environ["REPRO_EP_SPLIT"] = "1"
    try:
        assert _ep_split(mix, FakeMesh()) == 2    # 8e x split 2 = 16
    finally:
        del os.environ["REPRO_EP_SPLIT"]


# --------------------------------------------------------------------------- #
# invariant analyzer (repro.analysis): each check fires on its fixture
# violation, passes on the corrected twin, the allowlist is honored, and
# the real tree is clean
# --------------------------------------------------------------------------- #
import dataclasses
import os as _os
import subprocess
import sys

from repro.analysis import CHECK_NAMES, module_name, run_checks
from repro.analysis.cachesan import (CacheDivergence, CacheSanitizer,
                                     sanitizer_self_test)

HERE = _os.path.dirname(_os.path.abspath(__file__))
ROOT = _os.path.dirname(HERE)
FIX = _os.path.join(HERE, "fixtures", "analysis")
BAD = _os.path.join(FIX, "bad")
GOOD = _os.path.join(FIX, "good")


def _bad(*rel):
    return _os.path.join(BAD, "src", "repro", *rel)


def _good(*rel):
    return _os.path.join(GOOD, "src", "repro", *rel)


def _checks_of(paths, checks=CHECK_NAMES):
    return [v.check for v in run_checks([paths] if isinstance(paths, str)
                                        else paths, checks).violations]


def test_module_name_derivation():
    assert module_name("src/repro/core/executor.py") == "repro.core.executor"
    assert module_name(_bad("core", "wallclock_bad.py")) \
        == "repro.core.wallclock_bad"
    assert module_name("src/repro/memory/__init__.py") == "repro.memory"
    assert module_name("benchmarks/run.py") == ""


def test_wallclock_fixture_fires_and_twin_passes():
    assert _checks_of(_bad("core", "wallclock_bad.py")) \
        == ["wallclock", "wallclock", "wallclock"]
    assert _checks_of(_good("core", "wallclock_good.py")) == []


def test_setiter_fixture_fires_and_twin_passes():
    assert _checks_of(_bad("core", "setiter_bad.py")) \
        == ["wallclock", "wallclock"]
    assert _checks_of(_good("core", "setiter_good.py")) == []


def test_epoch_part_a_fixture_fires_and_twin_passes():
    viols = run_checks([_bad("memory", "residency.py")]).violations
    assert [v.check for v in viols] == ["epoch"]
    assert "DevicePool.add" in viols[0].message
    assert _checks_of(_good("memory", "residency.py")) == []


def test_epoch_part_b_fixture_fires_and_twin_passes():
    assert _checks_of(_bad("memory", "epoch_bad.py")) == ["epoch", "epoch"]
    assert _checks_of(_good("memory", "epoch_good.py")) == []


def test_tracer_fixture_fires_and_twin_passes():
    viols = run_checks([_bad("core", "tracer_bad.py")]).violations
    assert [v.check for v in viols] == ["tracer", "tracer"]
    assert "banana" in viols[1].message
    assert _checks_of(_good("core", "tracer_good.py")) == []


def test_frozenspec_fixture_fires_and_twin_passes():
    assert sorted(_checks_of(_bad("api", "frozenspec_bad.py"))) \
        == ["frozenspec", "frozenspec"]
    assert _checks_of(_good("api", "frozenspec_good.py")) == []


def test_docstring_fixture_fires_and_twin_passes():
    assert _checks_of(_bad("memory", "nodoc_bad.py")) \
        == ["epoch", "docstring"] or \
        _checks_of(_bad("memory", "nodoc_bad.py")) == ["docstring"]
    assert _checks_of(_good("memory", "nodoc_good.py")) == []


def test_allowlist_exemptions_honored():
    # simulator and serving read perf_counter for wall_s / sched_time —
    # declared measurement sites, so the wallclock check stays silent
    rep = run_checks([_os.path.join(ROOT, "src", "repro", "core",
                                    "simulator.py"),
                      _os.path.join(ROOT, "src", "repro", "core",
                                    "serving.py")], ("wallclock",))
    assert rep.violations == []


def test_real_tree_is_clean_and_strict():
    rep = run_checks([_os.path.join(ROOT, "src")])
    assert rep.violations == [], [v.render() for v in rep.violations]
    assert rep.warnings == [], [w.render() for w in rep.warnings]


def test_cli_exit_codes():
    env = dict(_os.environ, PYTHONPATH=_os.path.join(ROOT, "src"))
    bad = subprocess.run([sys.executable, "-m", "repro.analysis", BAD],
                         cwd=ROOT, env=env, capture_output=True)
    assert bad.returncode == 1, bad.stdout
    good = subprocess.run([sys.executable, "-m", "repro.analysis", GOOD],
                          cwd=ROOT, env=env, capture_output=True)
    assert good.returncode == 0, good.stdout


# --------------------------------------------------------------------------- #
# cachesan: silent on a clean run, raises on a corrupted cache entry,
# detects the injected stale-epoch fault, and installs from env/spec
# --------------------------------------------------------------------------- #
from repro.core import Simulation  # noqa: E402
from repro.core.workload import make_task_requests  # noqa: E402
from repro.memory import NUMA  # noqa: E402
from conftest import SMALL_BOARD, build_board_system  # noqa: E402

PEER = dataclasses.replace(NUMA, name="peer", peer_bw=300e9)


def test_cachesan_silent_on_clean_run():
    system = build_board_system(SMALL_BOARD, NUMA, n_gpu=2, n_cpu=1)
    san = CacheSanitizer(probe_rate=1.0, seed=0).install(system)
    sim = Simulation(system)
    sim.submit(make_task_requests(SMALL_BOARD, 120, interval=0.004, seed=0))
    m = sim.run()
    assert m.completed == 120
    assert san.probes > 100          # the caches were actually validated
    san.uninstall()


def test_cachesan_raises_on_corrupted_holders_cache():
    system = build_board_system(SMALL_BOARD, PEER, n_gpu=2, n_cpu=1)
    h = system.hierarchy
    assert h.topology.has_peer
    group = sorted(h.link_groups)[0]
    eid = sorted(system.coe.experts)[0]
    CacheSanitizer(probe_rate=1.0, seed=0).install(system)
    # a stale-epoch bug in miniature: a holders entry claiming a settled
    # sibling copy that no pool has (epoch stamp valid, value wrong)
    h._holders_cache[eid] = (h.epoch.n, ("phantom-pool",))
    with pytest.raises(CacheDivergence) as exc:
        h.assignment_cost(eid, 0.0, group)
    assert exc.value.epoch == h.epoch.n
    assert eid in str(exc.value)


def test_cachesan_raises_on_corrupted_work_cache():
    system = build_board_system(SMALL_BOARD, NUMA, n_gpu=2, n_cpu=1)
    ex = next(e for e in system.executors
              if e._residency_epoch() is not None)
    CacheSanitizer(probe_rate=1.0, seed=0).install(system)
    good = ex.queue_work()
    qv, en, _ = ex._work_cache
    ex._work_cache = (qv, en, good + 0.5)
    with pytest.raises(CacheDivergence):
        ex.queue_work()


def test_cachesan_self_test_detects_injected_fault():
    system = build_board_system(SMALL_BOARD, NUMA, n_gpu=2, n_cpu=1)
    assert sanitizer_self_test(system) is True
    # methods restored: a corrupted entry now goes undetected (no probes)
    assert getattr(system, "_cachesan", None) is None


def test_cachesan_env_var_installs(monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_SANITIZE", "1")
    system = build_board_system(SMALL_BOARD, NUMA, n_gpu=2, n_cpu=1)
    assert getattr(system, "_cachesan", None) is not None
    monkeypatch.delenv("REPRO_CACHE_SANITIZE")
    system2 = build_board_system(SMALL_BOARD, NUMA, n_gpu=2, n_cpu=1)
    assert getattr(system2, "_cachesan", None) is None


def test_cachesan_spec_flag_installs():
    from repro.api import DeploymentSpec
    from repro.api.build import build_context
    spec = DeploymentSpec.load(_os.path.join(ROOT, "examples", "specs",
                                             "sim.json"))
    spec = dataclasses.replace(
        spec, observability=dataclasses.replace(spec.observability,
                                                sanitize=True))
    ctx = build_context(spec)
    assert getattr(ctx.system, "_cachesan", None) is not None


def test_cachesan_install_is_idempotent():
    system = build_board_system(SMALL_BOARD, NUMA, n_gpu=2, n_cpu=1)
    a = CacheSanitizer(probe_rate=0.5, seed=1).install(system)
    b = CacheSanitizer(probe_rate=0.9, seed=2).install(system)
    assert a is b and system._cachesan is a
