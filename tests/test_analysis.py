"""Analysis-layer tests: HLO collective parser, roofline math, config
bookkeeping (param counts, block patterns, applicable shapes)."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config
from repro.launch.hlo import count_collectives, parse_collective_bytes
from repro.models.config import ModelConfig


HLO_SAMPLE = """
HloModule jit_step
ENTRY %main {
  %p0 = bf16[16,1024,512]{2,1,0} parameter(0)
  %ag = bf16[16,1024,512]{2,1,0} all-gather(%p0), replica_groups={}
  %ar = f32[8,128]{1,0} all-reduce(%x), to_apply=%add
  ROOT %t = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(%a, %b), to_apply=%add
  %rs = bf16[2,64]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = u32[4]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = s32[16,16]{1,0} all-to-all(%w), dimensions={0}
}
"""


def test_parse_collective_bytes_kinds_and_sizes():
    out = parse_collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 16 * 1024 * 512 * 2
    assert out["all-reduce"] == 8 * 128 * 4 * 3          # single + tuple pair
    assert out["reduce-scatter"] == 2 * 64 * 2
    assert out["collective-permute"] == 4 * 4
    assert out["all-to-all"] == 16 * 16 * 4


def test_count_collectives():
    c = count_collectives(HLO_SAMPLE)
    assert c["all-reduce"] == 2
    assert c["all-gather"] == 1


def test_parser_ignores_non_collectives():
    assert parse_collective_bytes("%d = f32[4]{0} dot(%a, %b)") == {}


# --------------------------------------------------------------------------- #
# roofline math
# --------------------------------------------------------------------------- #

def test_roofline_analysis_terms():
    from benchmarks.roofline import analyse_cell
    rec = {"arch": "starcoder2_3b", "shape": "train_4k", "mesh": [16, 16],
           "roofline": {"flops": 1.97e14, "bytes_accessed": 819e9,
                        "collective_bytes": {"all-gather": 50e9}}}
    row = analyse_cell(rec)
    assert row["t_compute_s"] == pytest.approx(1.0)
    assert row["t_memory_s"] == pytest.approx(1.0)
    assert row["t_collective_s"] == pytest.approx(1.0)
    assert row["chips"] == 256
    assert 0 < row["useful_ratio"] < 1


def test_model_flops_decode_vs_train():
    from benchmarks.roofline import model_flops
    train = model_flops("starcoder2_3b", "train_4k")
    decode = model_flops("starcoder2_3b", "decode_32k")
    # train: 6N x 1M tokens; decode: 2N x 128 tokens
    assert train / decode == pytest.approx(
        (6 * 4096 * 256) / (2 * 128), rel=1e-6)


# --------------------------------------------------------------------------- #
# config bookkeeping
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch,expected_params_b", [
    ("starcoder2_3b", (2.5, 3.5)),
    ("mixtral_8x22b", (125, 150)),       # total (all experts)
    ("falcon_mamba_7b", (6.5, 8.0)),
    ("minitron_8b", (7.5, 9.5)),
])
def test_param_counts_in_published_range(arch, expected_params_b):
    n = get_config(arch).param_count() / 1e9
    lo, hi = expected_params_b
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_mixtral_active_params_much_smaller():
    cfg = get_config("mixtral_8x22b")
    assert cfg.param_count(active_only=True) < cfg.param_count() * 0.4


def test_jamba_block_pattern():
    cfg = get_config("jamba_v0_1_52b")
    pat = cfg.block_pattern()
    assert len(pat) == 8
    assert sum(1 for s in pat if s.mixer == "attn") == 1      # 1:7 interleave
    assert sum(1 for s in pat if s.ffn == "moe") == 4         # every other


def test_applicable_shapes_long_context_gating():
    longs = {a for a in ARCH_IDS
             if "long_500k" in applicable_shapes(get_config(a))}
    assert longs == {"jamba_v0_1_52b", "mixtral_8x22b", "falcon_mamba_7b"}


def test_all_archs_have_all_base_shapes():
    for a in ARCH_IDS:
        shapes = applicable_shapes(get_config(a))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_layers_divisible_by_period():
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.num_layers % cfg.period() == 0


def test_ep_split_helper():
    import os
    from repro.launch.specs import _ep_split

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    mix = get_config("mixtral_8x22b")
    moon = get_config("moonshot_v1_16b_a3b")
    dense = get_config("starcoder2_3b")
    assert _ep_split(dense, FakeMesh()) == 1
    assert _ep_split(moon, FakeMesh()) == 1       # 64 % 16 == 0: true EP
    assert _ep_split(mix, FakeMesh()) == 1        # default OFF (GSPMD regress)
    os.environ["REPRO_EP_SPLIT"] = "1"
    try:
        assert _ep_split(mix, FakeMesh()) == 2    # 8e x split 2 = 16
    finally:
        del os.environ["REPRO_EP_SPLIT"]
