"""Training substrate tests: checkpoint fault-tolerance, grad compression,
data pipeline determinism, and a short loss-goes-down run."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.data import make_batch_for
from repro.data.pipeline import SyntheticLMDataset
from repro.models import transformer
from repro.training import adamw_init
from repro.training.checkpoint import (AsyncCheckpointer, restore_latest,
                                       save_checkpoint)
from repro.training.compression import (compress_grads, compressed_bytes,
                                        ef_init)
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_config(get_config("starcoder2_3b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# --------------------------------------------------------------------------- #
# checkpointing (fault tolerance)
# --------------------------------------------------------------------------- #

def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    opt = adamw_init(params)
    save_checkpoint(str(tmp_path), 7, params, opt, extra={"lr": 3e-4})
    out = restore_latest(str(tmp_path), params, opt)
    assert out is not None
    step, p2, o2, extra = out
    assert step == 7
    assert extra == {"lr": 3e-4}
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2.step) == int(opt.step)


def test_restore_latest_picks_newest(tmp_path, tiny):
    cfg, params = tiny
    opt = adamw_init(params)
    for step in (3, 12, 8):
        save_checkpoint(str(tmp_path), step, params, opt)
    step, *_ = restore_latest(str(tmp_path), params, opt)
    assert step == 12


def test_partial_write_never_corrupts(tmp_path, tiny):
    """A stale .tmp directory (simulated crash mid-write) must be invisible
    to restore_latest — the atomic-rename commit protocol."""
    cfg, params = tiny
    opt = adamw_init(params)
    save_checkpoint(str(tmp_path), 1, params, opt)
    crash = tmp_path / "step_00000009.tmp"
    crash.mkdir()
    (crash / "garbage").write_text("partial")
    step, *_ = restore_latest(str(tmp_path), params, opt)
    assert step == 1


def test_restore_empty_dir_returns_none(tmp_path, tiny):
    cfg, params = tiny
    assert restore_latest(str(tmp_path / "nope"), params, adamw_init(params)) \
        is None


def test_async_checkpointer(tmp_path, tiny):
    cfg, params = tiny
    opt = adamw_init(params)
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(1, params, opt)
    ck.save(2, params, opt)   # waits for the in-flight write first
    ck.wait()
    step, *_ = restore_latest(str(tmp_path), params, opt)
    assert step == 2
    assert ck.last_committed.endswith("step_00000002")


def test_restart_resumes_training(tmp_path, tiny):
    """Kill-and-restart: training continues from the latest checkpoint with
    bit-identical state to an uninterrupted run."""
    cfg, params = tiny
    step_fn = jax.jit(make_train_step(cfg))
    opt = adamw_init(params)
    batches = [
        {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 2, 16, step=i).items()}
        for i in range(4)]

    p, o = params, opt
    for i in range(2):
        p, o, _ = step_fn(p, o, batches[i])
    save_checkpoint(str(tmp_path), 2, p, o)
    for i in range(2, 4):
        p, o, _ = step_fn(p, o, batches[i])   # uninterrupted reference

    _, rp, ro, _ = restore_latest(str(tmp_path), params, opt)
    for i in range(2, 4):
        rp, ro, _ = step_fn(rp, ro, batches[i])
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(rp)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------- #
# gradient compression (int8 + error feedback)
# --------------------------------------------------------------------------- #

def test_compression_bounded_error():
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(64, 64), jnp.float32)}
    res = ef_init(g)
    comp, new_res = compress_grads(g, res)
    err = np.abs(np.asarray(comp["w"]) - np.asarray(g["w"]))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert err.max() <= scale * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """Sum of compressed gradients converges to the sum of raw gradients —
    the EF residual carries quantisation error forward."""
    rng = np.random.RandomState(1)
    g_raw = [jnp.asarray(rng.randn(32, 32) * (i + 1), jnp.float32)
             for i in range(20)]
    res = ef_init({"w": g_raw[0]})
    total_comp = np.zeros((32, 32), np.float32)
    for g in g_raw:
        comp, res = compress_grads({"w": g}, res)
        total_comp += np.asarray(comp["w"])
    total_raw = sum(np.asarray(g) for g in g_raw)
    # residual bounds the cumulative discrepancy
    resid = np.abs(np.asarray(res["w"]))
    np.testing.assert_allclose(total_comp + np.asarray(res["w"]), total_raw,
                               rtol=1e-4, atol=1e-4)
    assert resid.max() < np.abs(total_raw).max()


def test_compressed_traffic_is_quarter():
    g = {"w": jnp.zeros((128, 128), jnp.float32)}
    assert compressed_bytes(g) < 128 * 128 * 4 / 3.9


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #

def test_pipeline_deterministic():
    ds = SyntheticLMDataset(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    a, b = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = ds.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_label_shift():
    ds = SyntheticLMDataset(vocab_size=512, seq_len=32, global_batch=4)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_host_sharding_partitions():
    full = SyntheticLMDataset(vocab_size=512, seq_len=16, global_batch=8)
    shards = [SyntheticLMDataset(vocab_size=512, seq_len=16, global_batch=8,
                                 host_index=i, host_count=4) for i in range(4)]
    assert all(s.local_batch == 2 for s in shards)
    for s in shards:
        assert s.batch(0)["tokens"].shape == (2, 17 - 1)


def test_pipeline_rejects_indivisible_batch():
    with pytest.raises(ValueError):
        SyntheticLMDataset(vocab_size=512, seq_len=16, global_batch=7,
                           host_count=4)


# --------------------------------------------------------------------------- #
# loss goes down (micro-scale e2e)
# --------------------------------------------------------------------------- #

def test_loss_decreases_30_steps(tiny):
    cfg, params = tiny
    cfg = dataclasses.replace(cfg, remat=False)
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=8, seed=0, branching=2)
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=1e-3, warmup_steps=10)))
    p, o = transformer.init_params(jax.random.PRNGKey(1), cfg), None
    o = adamw_init(p)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        p, o, m = step_fn(p, o, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"
    assert np.isfinite(losses).all()


# --------------------------------------------------------------------------- #
# gradient accumulation (microbatching)
# --------------------------------------------------------------------------- #

def test_grad_accum_matches_full_batch(tiny):
    """accum_steps=4 must produce the same update as the full-batch step
    (same mean gradient; scan-accumulated in fp32)."""
    cfg, params = tiny
    cfg = dataclasses.replace(cfg, remat=False)
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 8, 16).items()}
    opt = adamw_init(params)
    full = jax.jit(make_train_step(cfg))
    accum = jax.jit(make_train_step(cfg, accum_steps=4))
    p1, o1, m1 = full(params, opt, batch)
    p2, o2, m2 = accum(params, adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-4, atol=5e-4)


def test_grad_accum_rejects_indivisible(tiny):
    cfg, params = tiny
    cfg = dataclasses.replace(cfg, remat=False)
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, 6, 16).items()}
    step = make_train_step(cfg, accum_steps=4)
    with pytest.raises(ValueError, match="not divisible"):
        step(params, adamw_init(params), batch)
