"""Quickstart: build a small Collaboration-of-Experts model, serve it with
CoServe, and compare against the Samba-CoE (FCFS + LRU) baseline.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (COSERVE, SAMBA, CoEModel, CoServeSystem, ExpertSpec,
                        Request, RoutingModule, Simulation, TierSpec)
from repro.core.workload import device_profile
from repro.core.serving import ExecutorSpec

MB = 1 << 20

# --- 1. define the experts and their dependency graph ----------------------- #
# 12 classification experts; cls00..cls05 chain into a shared detection expert
# (paper Fig. 2: multiple classifiers share one object-detection expert).
experts = [ExpertSpec(id=f"cls{i:02d}", arch="resnet101", mem_bytes=178 * MB)
           for i in range(12)]
experts.append(ExpertSpec(id="det00", arch="yolov5m", mem_bytes=85 * MB,
                          depends_on=tuple(f"cls{i:02d}" for i in range(6))))

# --- 2. routing rules (user-defined, so usage probabilities are knowable) --- #
def _component(data) -> int:
    return data["component"] if isinstance(data, dict) else int(data)


routing = RoutingModule(
    first_expert_fn=lambda data: f"cls{_component(data):02d}",
    next_expert_fn=lambda req, eid, out: (
        "det00" if eid < "cls06" and out == "ok" else None),
    chain_prob={f"cls{i:02d}": {"det00": 0.95} for i in range(6)},
)
coe = CoEModel(experts, routing)
coe = coe.assess_usage_probabilities({i: 1 / 12 for i in range(12)})

# --- 3. a request stream that sweeps the component types -------------------- #
reqs = [Request(id=i, expert_id=f"cls{(i // 4) % 12:02d}",
                arrival_time=i * 0.004,
                data={"component": (i // 4) % 12, "outcome": "ok"})
        for i in range(240)]

# --- 4. serve under CoServe and under Samba-CoE ----------------------------- #
tier = TierSpec(name="edge", unified=False, host_cache_bytes=1 << 30,
                device_bytes=1 << 30)   # pool fits only ~4 of 13 experts
prof = device_profile("gpu", tier)

for policy, n_exec in ((COSERVE, 2), (SAMBA, 1)):
    pools = {"gpu": 800 * MB}
    specs = [ExecutorSpec("gpu", prof, 300 * MB, "gpu")] * n_exec
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier)
    sim = Simulation(system)
    sim.submit([Request(**{**r.__dict__}) for r in reqs])
    m = sim.run()
    print(f"{policy.name:10s}: {m.completed} done | "
          f"{m.throughput:6.1f} req/s | {m.switches:3d} expert switches | "
          f"makespan {m.makespan:.2f}s")
