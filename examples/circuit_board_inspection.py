"""The paper's production workload end to end: circuit-board defect
inspection with 350+ experts on a memory-constrained edge device.

Runs Task A1 (2,500 component images, one every 4 ms) on the NUMA device
profile under every system from the paper's evaluation, prints the Fig. 13/14
comparison, and shows the offline decay-window memory search (Fig. 18).

  PYTHONPATH=src python examples/circuit_board_inspection.py [--fast]
"""
import argparse

from repro.core import (COSERVE, COSERVE_EM, COSERVE_EM_RA, COSERVE_NONE,
                        SAMBA, SAMBA_FIFO, SAMBA_PARALLEL, CoServeSystem,
                        Simulation)
from repro.core.memory import NUMA
from repro.core.profiler import (decay_window_search,
                                 pool_split_from_expert_count)
from repro.core.workload import (BOARD_A, build_board_coe,
                                 make_executor_specs, make_task_requests)

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true", help="1,000-request variant")
args = ap.parse_args()
N = 1000 if args.fast else 2500

coe = build_board_coe(BOARD_A)
print(f"CoE model: {len(coe)} experts, "
      f"{coe.total_bytes() / 2**30:.1f} GiB of parameters; device pool "
      f"{NUMA.device_bytes / 2**30:.0f} GiB -> experts must switch\n")

def run(policy, gpu_pool_bytes=None):
    n_gpu, n_cpu = (1, 0) if policy.assign == "single" else (3, 1)
    pools, specs = make_executor_specs(NUMA, n_gpu, n_cpu,
                                       gpu_pool_bytes=gpu_pool_bytes)
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=NUMA)
    sim = Simulation(system)
    sim.submit(make_task_requests(BOARD_A, N))
    return sim.run()

print(f"=== Task A1 ({N} requests), NUMA device (Fig. 13/14) ===")
rows = [("Samba-CoE", SAMBA), ("Samba-CoE FIFO", SAMBA_FIFO),
        ("Samba-CoE Parallel", SAMBA_PARALLEL),
        ("CoServe None", COSERVE_NONE), ("CoServe EM", COSERVE_EM),
        ("CoServe EM+RA", COSERVE_EM_RA), ("CoServe (casual)", COSERVE)]
base = None
for name, pol in rows:
    m = run(pol)
    if name == "Samba-CoE":
        base = m.throughput
    print(f"  {name:20s} {m.throughput:7.1f} req/s "
          f"({m.throughput / base:4.1f}x) | {m.switches:4d} switches")

print("\n=== Offline decay-window memory search (Fig. 18) ===")
def throughput_fn(n_experts):
    pool, _ = pool_split_from_expert_count(coe, n_experts, NUMA.device_bytes)
    return run(COSERVE, gpu_pool_bytes=pool).throughput

res = decay_window_search(throughput_fn, max_experts=len(coe),
                          initial_window=15, error_margin=0.05)
for n, thr in res.history:
    print(f"  {n:3d} experts loaded -> {thr:7.1f} req/s")
print(f"  window {res.window}, chosen n={res.n_experts} "
      f"(linear error {res.linear_error:.1%})")
pool, _ = pool_split_from_expert_count(coe, res.n_experts, NUMA.device_bytes)
m = run(COSERVE, gpu_pool_bytes=pool)
print(f"\nCoServe Best: {m.throughput:7.1f} req/s "
      f"({m.throughput / base:4.1f}x Samba-CoE) | {m.switches} switches")
