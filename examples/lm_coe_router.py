"""LM Collaboration-of-Experts (the paper's §2.1 Qihoo-360 scenario): a
domain router dispatches prompts to specialised LM experts — real tiny
transformer checkpoints served through CoServe with actual device loads.

Chained dependency: every draft expert's output is verified by a shared
"safety" expert (the CoE dependency CoServe exploits).

  PYTHONPATH=src python examples/lm_coe_router.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import (COSERVE, SAMBA_PARALLEL, CoEModel, CoServeSystem,
                        DeviceProfile, ExecutorSpec, ExpertSpec, HostStore,
                        RealEngine, Request, RoutingModule, TierSpec,
                        microbenchmark_arch, run_real)
from repro.models import transformer

DOMAINS = ["code", "math", "law", "chat", "bio", "finance"]
N_REQS = 90

cfg = dataclasses.replace(smoke_config(get_config("starcoder2_3b")),
                          remat=False)


@jax.jit
def lm_apply(params, tokens):
    logits, _ = transformer.forward(params, tokens, cfg, mode="eval")
    return jnp.argmax(logits[:, -1], -1)          # next-token per prompt


def main():
    store = HostStore(root="/tmp/lm_coe_store")
    payload = {
        "make_batch": lambda reqs: np.stack([r.data["tokens"] for r in reqs]),
        "interpret": lambda out: ["ok" if int(t) % 7 else "flag" for t in out],
    }
    mem = sum(int(np.prod(p.shape)) * 4 for p in jax.tree.leaves(
        transformer.init_params(jax.random.PRNGKey(0), cfg)))

    experts = []
    for i, dom in enumerate(DOMAINS):           # one fine-tune per domain
        params = transformer.init_params(jax.random.PRNGKey(i), cfg)
        (store.put_disk if i % 2 else store.put_host)(f"lm_{dom}", params)
        experts.append(ExpertSpec(
            id=f"lm_{dom}", arch="tiny_lm", mem_bytes=mem, payload=payload,
            usage_prob=1.0 / len(DOMAINS)))
    safety = transformer.init_params(jax.random.PRNGKey(99), cfg)
    store.put_disk("lm_safety", safety)
    experts.append(ExpertSpec(
        id="lm_safety", arch="tiny_lm", mem_bytes=mem, payload=payload,
        depends_on=tuple(f"lm_{d}" for d in DOMAINS), usage_prob=0.9))

    routing = RoutingModule(
        first_expert_fn=lambda data: f"lm_{data['domain']}",
        next_expert_fn=lambda req, eid, out: (
            "lm_safety" if eid != "lm_safety" else None),
        chain_prob={f"lm_{d}": {"lm_safety": 1.0} for d in DOMAINS})
    coe = CoEModel(experts, routing)
    engine = RealEngine(coe, store, {"tiny_lm": lm_apply})

    # offline profiling (paper §4.5) with the real jitted runner
    sample = transformer.init_params(jax.random.PRNGKey(7), cfg)

    def run_batch(n):
        x = np.zeros((n, 16), np.int32)
        lm_apply(sample, x)
        t0 = time.perf_counter()
        jax.block_until_ready(lm_apply(sample, x))
        return time.perf_counter() - t0

    tier = TierSpec(name="lm", unified=True, host_cache_bytes=0,
                    device_bytes=4 * mem)
    prof = microbenchmark_arch("tiny_lm", run_batch, mem, 16 * 4, tier,
                               batch_sizes=(1, 2, 4, 8), repeats=2)
    dev = DeviceProfile("gpu", tier, {"tiny_lm": prof})

    rng = np.random.RandomState(0)
    def requests():
        out = []
        for i in range(N_REQS):
            dom = DOMAINS[rng.randint(len(DOMAINS))]
            out.append(Request(
                id=i, expert_id=f"lm_{dom}",
                data={"domain": dom,
                      "tokens": rng.randint(0, cfg.vocab_size,
                                            16).astype(np.int32)}))
        return out

    for policy in (COSERVE, SAMBA_PARALLEL):
        system = CoServeSystem(
            coe, [ExecutorSpec("gpu", dev, 2 * mem, "gpu")] * 2,
            {"gpu": 3 * mem},                    # pool: 3 of 7 LM experts fit
            policy=policy, tier=tier, engine=RealEngine(
                coe, store, {"tiny_lm": lm_apply}))
        m = run_real(system, requests())
        print(f"{policy.name:18s}: {m.completed} prompts | "
              f"{m.switches:3d} expert loads | makespan {m.makespan:.2f}s")


if __name__ == "__main__":
    main()
