"""Real-backend serving example: actual JAX expert parameters move across
disk -> host -> device tiers and jitted forwards execute, driven by the same
dependency-aware scheduler the simulator uses.

  PYTHONPATH=src python examples/serve_real_experts.py
"""
import numpy as np

from repro.core import COSERVE, SAMBA_PARALLEL, Request, run_real
from repro.launch.serve import build_real_system

rng = np.random.RandomState(7)
N_COMPONENTS, N_REQS = 16, 150


def make_requests():
    needs_det = np.random.RandomState(0).rand(N_COMPONENTS) < 0.5
    det_assign = np.random.RandomState(0).randint(0, 3, N_COMPONENTS)
    local = np.random.RandomState(7)
    out = []
    for i in range(N_REQS):
        c = int(local.randint(N_COMPONENTS))
        out.append(Request(
            id=i, expert_id=f"cls{c:03d}",
            data={"component": c, "x": local.randn(64).astype(np.float32),
                  "needs_detection": bool(needs_det[c]),
                  "det_expert": int(det_assign[c])}))
    return out


for policy in (COSERVE, SAMBA_PARALLEL):
    system, coe = build_real_system(
        n_components=N_COMPONENTS, n_detection=3, pool_experts=5,
        n_executors=2, policy=policy)
    m = run_real(system, make_requests())
    outcomes = {}
    print(f"{policy.name:20s}: {m.completed} requests | "
          f"{m.throughput:8.0f} req/s (wall) | {m.switches:3d} real "
          f"device loads | makespan {m.makespan * 1e3:.0f} ms")
