"""End-to-end training example (deliverable b): a ~100M-parameter dense LM
(starcoder2-family reduction) for a few hundred steps with fault-tolerant
checkpointing. The loss falls on the synthetic Markov-chain corpus.

  PYTHONPATH=src python examples/train_100m.py            # ~300 steps
  PYTHONPATH=src python examples/train_100m.py --fast     # 20M model, 60 steps

Restart behaviour: re-running the same command resumes from the newest
committed checkpoint (kill it mid-run to see the fault-tolerance path).
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
args = ap.parse_args()

if args.fast:
    argv = ["--preset", "20m", "--steps", "60", "--batch", "8",
            "--seq", "128", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "20", "--log-every", "10", "--resume"]
else:
    argv = ["--preset", "100m", "--steps", "300", "--batch", "8",
            "--seq", "256", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "50", "--log-every", "10", "--resume"]

history = train_main(argv)
if len(history) >= 2 and history[-1]["loss"] < history[0]["loss"]:
    print("OK: loss decreased")
else:
    print("WARNING: loss did not decrease", file=sys.stderr)
