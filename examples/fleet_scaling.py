"""Fleet scaling: 4 accelerator devices behind one shared SSD.

Walks the device-fleet topology subsystem end to end on a scaled-down
circuit-board workload, with the whole deployment declared as one
``DeploymentSpec`` (custom board and tier included — the spec could be
``save()``d and re-run via ``serve --config``):

  1. describe the fleet (4 devices x 3 executors, per-device PCIe links)
  2. inspect the explicit PlacementPlan (primaries + replicated hot head)
  3. serve the same workload on the PR 2 baseline topology (one shared
     host->device link, single-copy placement) and on the fleet topology,
     and compare throughput, stalls and per-link queueing

  PYTHONPATH=src python examples/fleet_scaling.py
"""
from __future__ import annotations

from repro.api import (BoardSection, DeploymentSpec, FleetSection,
                       MemorySection, ModelSpec, Session, ServingSection,
                       WorkloadSection, build_catalog, build_layout,
                       resolve_tier)
from repro.fleet import PlacementPlan

GB = 1 << 30

# a board whose active expert set (~21 GB) dwarfs one device pool (3 GB):
# serving is dominated by expert switches, which is where topology matters.
# (Same shape as benchmarks/bench_fleet.py, so numbers track BENCH_fleet.)
BOARD = BoardSection(name="X", n_components=160, n_active=120,
                     avg_quantity=1.5, n_detection=16, zipf_s=2.0)

N_REQUESTS = 800


def fleet_spec(links: str, replication: int) -> DeploymentSpec:
    """Each accelerator: 4 GB of device memory behind a 3 GB/s host link;
    all four share one NVMe SSD, and host DRAM holds the whole catalog."""
    return DeploymentSpec(
        model=ModelSpec(kind="board", board="X", boards=(BOARD,)),
        fleet=FleetSection(devices=4, gpu_per_device=3, cpu=0, links=links,
                           replication=replication),
        memory=MemorySection(tier="numa", name="fleet_demo", disk_bw=2000e6,
                             host_to_device_bw=3e9,
                             host_cache_bytes=40 * GB, device_bytes=4 * GB),
        serving=ServingSection(mode="sim"),
        workload=WorkloadSection(requests=N_REQUESTS, interval_s=0.002))


# --- 1+2: the explicit placement plan --------------------------------------- #
spec = fleet_spec("per-device", 1)
coe = build_catalog(spec)
pools, _ = build_layout(spec, resolve_tier(spec))
plan = PlacementPlan.build(coe, pools, replication=1)
print("fleet pools:", {g: f"{b / GB:.1f} GB" for g, b in pools.items()})
print("plan:", plan.snapshot())
hottest = coe.by_usage()[0]
print(f"hottest expert {hottest.id} (P(use)={hottest.usage_prob:.3f}) "
      f"planned on pools: {plan.pools_for(hottest.id)}")

# --- 3: baseline topology vs fleet topology --------------------------------- #
print(f"\nserving {N_REQUESTS} requests on 4 devices x 3 executors:")
for links, repl, label in (
        ("shared", 0, "shared link, no replication (PR 2 baseline)"),
        ("per-device", 0, "per-device links"),
        ("per-device", 1, "per-device links + replication")):
    sess = Session(fleet_spec(links, repl))
    sess.run()
    m = sess.metrics()
    chans = m.memory["channels"]
    print(f"\n  [{label}]")
    print(f"    throughput {m.throughput:7.2f} req/s   "
          f"switches {m.switches}   stall {m.stall_time:.2f}s")
    print(f"    PCIe wait total {chans['pcie_channel']['wait_time_s']:.2f}s "
          f"across {len(chans['pcie_channels'])} link(s); "
          f"SSD wait {chans['disk_channel']['wait_time_s']:.2f}s")
    for name, ch in sorted(chans["pcie_channels"].items()):
        print(f"      {name:24s} wait {ch['wait_time_s']:8.2f}s  "
              f"moved {ch['bytes_moved'] / GB:6.2f} GB")
