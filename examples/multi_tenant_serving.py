"""Multi-tenant online serving walkthrough (repro.api + repro.serve).

Two product lines share one CoServe deployment: a latency-sensitive "gold"
tenant inspecting BOARD_A under a tight 1.5 s SLO, and a bursty "batch"
tenant sweeping BOARD_B with a relaxed 6 s SLO. The demo declares the same
traffic three ways as ``DeploymentSpec``s — each one line of diff away from
the last — runs each through a ``Session`` and prints a comparison:

  1. static fleet, FIFO queues (no SLO awareness)
  2. + deadline-EDF scheduling and queue-depth admission control
  3. + load-driven autoscaling

Any of the three specs could be ``save()``d and re-run verbatim with
``python -m repro.launch.serve --config spec.json``.

  PYTHONPATH=src python examples/multi_tenant_serving.py
"""
from __future__ import annotations

import dataclasses
import json

from repro.api import (DeploymentSpec, ModelSpec, Session, ServingSection,
                       TenantSection, WorkloadSection)

N_REQUESTS = 1500

BASE = DeploymentSpec(
    model=ModelSpec(kind="tenants"),
    serving=ServingSection(mode="online", slo_priority=False,
                           autoscale="none"),
    workload=WorkloadSection(requests=N_REQUESTS, tenants=(
        TenantSection(name="gold", board="A", rate=30.0, arrival="poisson",
                      slo_seconds=1.5, seed=1),
        TenantSection(name="batch", board="B", rate=25.0, arrival="bursty",
                      request_class="random", slo_seconds=6.0, seed=2))))

CONFIGS = [
    ("static FIFO", BASE),
    ("EDF + admission", dataclasses.replace(BASE, serving=ServingSection(
        mode="online", slo_priority=True, admission="queue_depth",
        max_queue=250, autoscale="none"))),
    ("EDF + admission + autoscale", dataclasses.replace(
        BASE, serving=ServingSection(
            mode="online", slo_priority=True, admission="queue_depth",
            max_queue=250, autoscale="4,8"))),
]


def describe(label: str, report) -> dict:
    row = {"label": label}
    for name in ("gold", "batch"):
        snap = report.telemetry["per_tenant"][name]
        row[name] = {"p50_s": round(snap["p50"], 3),
                     "p99_s": round(snap["p99"], 3),
                     "violation_rate": snap["slo"]["violation_rate"],
                     "shed": snap["slo"]["shed"]}
    row["throughput_rps"] = round(report.metrics.throughput, 2)
    row["max_queue"] = report.telemetry["queue"]["max_depth"]
    if report.autoscaler:
        row["scaling"] = (f"{report.autoscaler['scale_ups']} up / "
                          f"{report.autoscaler['scale_downs']} down")
    return row


def main():
    rows = []
    for label, spec in CONFIGS:
        sess = Session(spec)
        sess.run()
        rows.append(describe(label, sess.report))

    print(json.dumps(rows, indent=1))
    gold = {r["label"]: r["gold"]["violation_rate"] for r in rows}
    print("\ngold-tenant SLO violation rate by configuration:")
    for label, vr in gold.items():
        print(f"  {label:30s} {vr:.3f}")


if __name__ == "__main__":
    main()
