"""Multi-tenant online serving walkthrough (repro.serve).

Two product lines share one CoServe deployment: a latency-sensitive "gold"
tenant inspecting BOARD_A under a tight 1.5 s SLO, and a bursty "batch"
tenant sweeping BOARD_B with a relaxed 6 s SLO. The demo runs the same
traffic three ways and prints a comparison:

  1. static fleet, FIFO queues (no SLO awareness)
  2. + deadline-EDF scheduling and queue-depth admission control
  3. + load-driven autoscaling

  PYTHONPATH=src python examples/multi_tenant_serving.py
"""
from __future__ import annotations

import json

from repro.core import COSERVE, CoServeSystem
from repro.core.memory import NUMA
from repro.core.workload import BOARD_A, BOARD_B, make_executor_specs
from repro.serve import (AdmissionConfig, AdmissionController, Autoscaler,
                         AutoscalerConfig, OnlineGateway, TenantSpec,
                         build_multi_board_coe)

N_REQUESTS = 1500

TENANTS = [
    TenantSpec(name="gold", board=BOARD_A, rate=30.0, process="poisson",
               slo_seconds=1.5, seed=1),
    TenantSpec(name="batch", board=BOARD_B, rate=25.0, process="bursty",
               request_class="random", slo_seconds=6.0, seed=2),
]


def build_system():
    coe = build_multi_board_coe([t.board for t in TENANTS],
                                weights=[t.rate for t in TENANTS])
    pools, specs = make_executor_specs(NUMA, 3, 1)
    return CoServeSystem(coe, specs, pools, policy=COSERVE, tier=NUMA), specs


def describe(label: str, report) -> dict:
    row = {"label": label}
    for name in ("gold", "batch"):
        snap = report.telemetry["per_tenant"][name]
        row[name] = {"p50_s": round(snap["p50"], 3),
                     "p99_s": round(snap["p99"], 3),
                     "violation_rate": snap["slo"]["violation_rate"],
                     "shed": snap["slo"]["shed"]}
    row["throughput_rps"] = round(report.metrics.throughput, 2)
    row["max_queue"] = report.telemetry["queue"]["max_depth"]
    if report.autoscaler:
        row["scaling"] = (f"{report.autoscaler['scale_ups']} up / "
                          f"{report.autoscaler['scale_downs']} down")
    return row


def main():
    rows = []

    system, _ = build_system()
    gw = OnlineGateway(system, TENANTS, slo_priority=False)
    rows.append(describe("static FIFO", gw.run(N_REQUESTS)))

    system, _ = build_system()
    gw = OnlineGateway(
        system, TENANTS, slo_priority=True,
        admission=AdmissionController(AdmissionConfig(policy="queue_depth",
                                                      max_queue=250)))
    rows.append(describe("EDF + admission", gw.run(N_REQUESTS)))

    system, specs = build_system()
    gw = OnlineGateway(
        system, TENANTS, slo_priority=True,
        admission=AdmissionController(AdmissionConfig(policy="queue_depth",
                                                      max_queue=250)),
        autoscaler=Autoscaler(AutoscalerConfig(spec=specs[0],
                                               min_executors=4,
                                               max_executors=8)))
    rows.append(describe("EDF + admission + autoscale", gw.run(N_REQUESTS)))

    print(json.dumps(rows, indent=1))
    gold = {r["label"]: r["gold"]["violation_rate"] for r in rows}
    print("\ngold-tenant SLO violation rate by configuration:")
    for label, vr in gold.items():
        print(f"  {label:30s} {vr:.3f}")


if __name__ == "__main__":
    main()
