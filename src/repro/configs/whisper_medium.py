"""Whisper-medium [arXiv:2212.04356]: encoder-decoder audio backbone.

The conv/mel frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, 1500, d]. Deviations (DESIGN.md): vocab padded 51865 -> 51968
for sharding (excess logits masked); sinusoidal positions on both stacks.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51968,
    logical_vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    mlp_type="gelu",
    norm_type="layernorm",
    tie_embeddings=True,
)
