"""StarCoder2-3B [arXiv:2402.19173; hf]: dense GQA decoder, RoPE."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    rope_theta=1e5,
    mlp_type="gelu",        # starcoder2 uses a standard 2-matrix GELU FFN
    norm_type="layernorm",
    tie_embeddings=True,    # hf: tie_word_embeddings=true -> 3.0B total
)
