"""Architecture registry + assigned input shapes + smoke-config reduction."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional

from repro.models.config import ModelConfig

ARCH_IDS = [
    "starcoder2_3b",
    "minitron_8b",
    "phi4_mini_3_8b",
    "minitron_4b",
    "jamba_v0_1_52b",
    "whisper_medium",
    "moonshot_v1_16b_a3b",
    "mixtral_8x22b",
    "falcon_mamba_7b",
    "qwen2_vl_2b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    """Shape cells for an arch; long_500k only with sub-quadratic attention
    (skips recorded in DESIGN.md SSArch-applicability)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        shapes.append("long_500k")
    return shapes


def shape_overrides(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Per-cell config adjustments (e.g. jamba attention switches to a 32k
    sliding window for the 500k-context cell)."""
    if shape == "long_500k" and cfg.family == "hybrid" and not cfg.sliding_window:
        return dataclasses.replace(cfg, sliding_window=32768)
    return cfg


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: tiny widths/embeddings,
    few experts, same structural pattern (periods, MoE/hybrid interleave)."""
    period = cfg.period()
    num_layers = period * (1 if period > 1 else 2)
    kv = 4 if cfg.num_kv_heads == cfg.num_heads else 2
    mrope = (4, 6, 6) if cfg.mrope_sections else ()
    return dataclasses.replace(
        cfg,
        num_layers=num_layers,
        d_model=128,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        logical_vocab_size=509 if cfg.logical_vocab_size else 0,
        moe_num_experts=min(cfg.moe_num_experts, 4) if cfg.moe_num_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        moe_d_ff=128 if cfg.moe_d_ff else 0,
        ssm_state_dim=8 if cfg.ssm_state_dim else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.is_encoder_decoder else cfg.encoder_seq,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        mrope_sections=mrope,
        attn_chunk=64,
        ssm_chunk=32,
        max_position=4096,
    )
