"""Jamba v0.1 52B [arXiv:2403.19887; hf]: hybrid Mamba+attention (1:7
interleave, attention at period-8 offset 4) with MoE (16 experts, top-2)
on every other layer."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    moe_num_experts=16,
    moe_top_k=2,
    moe_period=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    ssm_state_dim=16,
)
