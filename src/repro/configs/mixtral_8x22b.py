"""Mixtral 8x22B [arXiv:2401.04088; hf]: MoE 8 experts top-2, sliding-window
attention (window 32768)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    moe_num_experts=8,
    moe_top_k=2,
    moe_d_ff=16384,
    sliding_window=32768,
    rope_theta=1e6,
)
