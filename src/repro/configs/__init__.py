from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ShapeSpec,
    applicable_shapes,
    get_config,
    smoke_config,
)

__all__ = ["ARCH_IDS", "SHAPES", "ShapeSpec", "applicable_shapes",
           "get_config", "smoke_config"]
