"""Qwen2-VL-2B [arXiv:2409.12191; hf]: VLM backbone with M-RoPE.

The vision/patch frontend is a STUB: input_specs() provides M-RoPE position
triples (and optional patch embeddings); the backbone is a GQA decoder with
3-section rotary (temporal/height/width = 16/24/24 over the 64-dim half)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    tie_embeddings=True,
)
