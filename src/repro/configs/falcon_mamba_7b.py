"""Falcon-Mamba-7B [arXiv:2410.05355]: pure Mamba-1, attention-free,
64 layers, ssm_state=16."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon_mamba_7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state_dim=16,
    ssm_expand=2,
    tie_embeddings=True,
)
