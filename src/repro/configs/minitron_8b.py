"""Minitron-8B (pruned Nemotron) [arXiv:2407.14679; hf]: dense GQA decoder."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron_8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    mlp_type="gelu",        # nemotron squared-ReLU FFN: 2-matrix structure
)
