"""Phi-4-mini 3.8B [arXiv:2412.08905; hf]: RoPE + SwiGLU + GQA.

Deviation (DESIGN.md): partial-RoPE fraction not modelled; standard
full-head RoPE is applied.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4_mini_3_8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,    # hf: tie_word_embeddings=true -> 3.8B total
)
