"""Device-fleet topology subsystem: per-device links + explicit placement.

Source of truth for the fleet-level *shape* (how many devices, who shares a
pool) and for *placement policy* (where experts live, greedy or searched).

Extends the single-link, implicit-placement reproduction to multi-device
fleets (ROADMAP "multi-device fleets" open item; SN40L-style composition of
experts across sockets):

  ``FleetSpec`` / ``build_fleet``   N accelerators x executors-per-device,
                                    shared or per-device host->device links
  ``PlacementPlan``                 expert -> device-pool assignment and
                                    replication as a queryable object
  ``search_placement`` /            cost-model placement search: candidate
  ``WorkloadTrace`` /               plans scored by replaying a workload
  ``replay_cost``                   trace through the residency-aware
                                    ``MemoryHierarchy.assignment_cost``
  ``validate_pool_groups``          one pool group == one device kind

The links themselves live in ``repro.memory.tiers.TierTopology`` (per-group
PCIe channels, shared SSD fan-in, per-pool peer ingress links); this package
owns the fleet-level shape and placement decisions on top of them.
"""
from repro.fleet.placement import PlacementPlan
from repro.fleet.search import (SearchConfig, SearchResult, WorkloadTrace,
                                replay_cost, search_placement,
                                trace_from_counts, trace_from_requests,
                                trace_from_usage)
from repro.fleet.topology import (FleetSpec, build_fleet, device_group_name,
                                  validate_pool_groups)

__all__ = ["PlacementPlan", "FleetSpec", "build_fleet", "device_group_name",
           "validate_pool_groups", "SearchConfig", "SearchResult",
           "WorkloadTrace", "replay_cost", "search_placement",
           "trace_from_counts", "trace_from_requests", "trace_from_usage"]
