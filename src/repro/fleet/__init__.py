"""Device-fleet topology subsystem: per-device links + explicit placement.

Extends the single-link, implicit-placement reproduction to multi-device
fleets (ROADMAP "multi-device fleets" open item; SN40L-style composition of
experts across sockets):

  ``FleetSpec`` / ``build_fleet``   N accelerators x executors-per-device,
                                    shared or per-device host->device links
  ``PlacementPlan``                 expert -> device-pool assignment and
                                    replication as a queryable object
  ``validate_pool_groups``          one pool group == one device kind

The links themselves live in ``repro.memory.tiers.TierTopology`` (per-group
PCIe channels, shared SSD fan-in); this package owns the fleet-level shape
and placement decisions on top of them.
"""
from repro.fleet.placement import PlacementPlan
from repro.fleet.topology import (FleetSpec, build_fleet, device_group_name,
                                  validate_pool_groups)

__all__ = ["PlacementPlan", "FleetSpec", "build_fleet", "device_group_name",
           "validate_pool_groups"]
