"""Cost-model placement search: plans scored by the serving cost model.

Source of truth: this module owns HOW a ``PlacementPlan`` is chosen beyond
the greedy hot-first sweep — but it never invents a cost formula. Every
candidate plan is priced by replaying a workload trace through
``MemoryHierarchy.assignment_cost``, the same residency-aware,
contended-channel formula the online scheduler assigns requests with, so
the search optimizes exactly what serving pays (SN40L-style searched
composition-of-experts layouts; the QoS-Efficient Multi-MoE partial
reconfiguration argument).

  ``WorkloadTrace``      an expert-id sequence plus replay clock spacing.
                         Built from offline profiler traces / materialized
                         request lists (``trace_from_requests``, expected
                         routing chains included), from observed online
                         per-expert load (``trace_from_counts``), or from
                         static pre-assessed P(use) (``trace_from_usage``).
  ``replay_cost``        score one plan: warm a fresh ``MemoryHierarchy`` to
                         the plan's layout, then charge every trace event
                         the queueing-plus-switch cost of its best device
                         pool. Misses occupy the contended SSD/PCIe/peer
                         channels and per-pool service clocks advance, so a
                         plan that serializes the hot head of the
                         distribution behind one pool or one link is
                         penalized — the signal replication exists for.
  ``search_placement``   greedy local search (replicate / drop / migrate /
                         swap / place moves) from the greedy-sweep seed
                         plan. Accept-only-improvements plus a seed-plan
                         fallback guarantee the result never scores worse
                         than the greedy sweep on the same trace (pinned by
                         test); every candidate is materialized through
                         ``PlacementPlan.from_assignments``, so capacity and
                         replica-budget invariants hold by construction.

The replay is a static-residency approximation: the plan's layout is held
fixed (no eviction churn) and execution time is a per-event constant. The
event-driven simulator stays the ground truth — the search only needs the
*relative* ordering of candidate plans, and BENCH_placement.json checks the
ordering against full simulations.
"""
from __future__ import annotations

import collections
import dataclasses
import time as _time
from typing import TYPE_CHECKING, Dict, FrozenSet, List, Mapping, Optional, \
    Sequence, Tuple

import numpy as np

from repro.fleet.placement import PlacementPlan
from repro.memory import MemoryHierarchy, TierSpec

if TYPE_CHECKING:  # pragma: no cover — repro.core imports this package
    from repro.core.coe import CoEModel


# --------------------------------------------------------------------------- #
# workload traces
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class WorkloadTrace:
    """A concrete expert-demand sequence the cost model replays.

    ``gap_s`` spaces the replay clock between events (arrival cadence);
    ``exec_s`` is the constant per-event service time that advances a
    pool's busy clock — together they set how much queueing pressure the
    replay sees (gap < exec means queues build and replication pays)."""
    events: Tuple[str, ...]
    gap_s: float = 0.004
    exec_s: float = 0.020

    def weights(self) -> Dict[str, int]:
        """Per-expert event counts (the search's hot/cold ranking)."""
        return dict(collections.Counter(self.events))

    # --- artifact serialization (repro.api.artifacts wraps file io) ----- #
    def to_dict(self) -> dict:
        """Lossless JSON-ready form; ``from_dict(to_dict(t)) == t``."""
        return {"events": list(self.events), "gap_s": self.gap_s,
                "exec_s": self.exec_s}

    @classmethod
    def from_dict(cls, d: Mapping) -> "WorkloadTrace":
        try:
            events = tuple(str(e) for e in d["events"])
        except (KeyError, TypeError):
            raise ValueError(
                "workload trace dict needs an 'events' list of expert ids "
                f"(got keys {sorted(d)})") from None
        return cls(events, gap_s=float(d.get("gap_s", 0.004)),
                   exec_s=float(d.get("exec_s", 0.020)))


def trace_from_requests(coe: "CoEModel", requests: Sequence,
                        gap_s: float = 0.004, exec_s: float = 0.020,
                        chain_threshold: float = 0.5) -> WorkloadTrace:
    """Trace from a materialized request list (offline profiler trace): each
    request contributes its first expert plus the *expected* routing chain —
    the likeliest ``chain_prob`` successor is appended while its edge
    probability clears ``chain_threshold``, so shared downstream experts
    (the detection stage) carry their real aggregate traffic."""
    events: List[str] = []
    for r in requests:
        eid = r.expert_id
        events.append(eid)
        seen = {eid}
        cur = eid
        while True:
            edges = coe.routing.chain_prob.get(cur, {})
            if not edges:
                break
            nxt, p = max(edges.items(), key=lambda kv: (kv[1], kv[0]))
            if p < chain_threshold or nxt in seen:
                break
            events.append(nxt)
            seen.add(nxt)
            cur = nxt
    return WorkloadTrace(tuple(events), gap_s=gap_s, exec_s=exec_s)


def trace_from_counts(counts: Mapping[str, float], length: int = 512,
                      gap_s: float = 0.004,
                      exec_s: float = 0.020) -> WorkloadTrace:
    """Deterministic trace proportional to observed per-expert load (e.g.
    ``CoServeSystem.expert_load``): each expert gets round(share * length)
    events (at least one while its count is positive), interleaved evenly so
    the replay sees mixed traffic instead of sorted runs."""
    total = float(sum(v for v in counts.values() if v > 0))
    if total <= 0:
        return WorkloadTrace((), gap_s=gap_s, exec_s=exec_s)
    slots: List[Tuple[float, str]] = []
    for eid in sorted(counts):
        c = counts[eid]
        if c <= 0:
            continue
        n = max(1, int(round(length * (c / total))))
        for k in range(n):
            slots.append(((k + 0.5) / n, eid))
    slots.sort()
    return WorkloadTrace(tuple(eid for _, eid in slots),
                         gap_s=gap_s, exec_s=exec_s)


def trace_from_usage(coe: "CoEModel", length: int = 512,
                     gap_s: float = 0.004,
                     exec_s: float = 0.020) -> WorkloadTrace:
    """Trace from the static pre-assessed P(use) (paper §4.5) — what the
    online path uses before any load has been observed."""
    return trace_from_counts(
        {e.id: e.usage_prob for e in coe.experts.values()},
        length=length, gap_s=gap_s, exec_s=exec_s)


# --------------------------------------------------------------------------- #
# cost model
# --------------------------------------------------------------------------- #

def _device_groups(capacities: Mapping[str, int],
                   pool_devices: Optional[Mapping[str, str]]) -> List[str]:
    devices = pool_devices or {}
    return sorted(g for g in capacities
                  if devices.get(g, "gpu") not in ("host", "cpu"))


def _host_groups(capacities: Mapping[str, int],
                 pool_devices: Optional[Mapping[str, str]]) -> List[str]:
    """The host/CPU pool groups — candidate targets of the ``host_place``
    move (deliberate CPU residents under heterogeneous co-execution)."""
    devices = pool_devices or {}
    return sorted(g for g in capacities
                  if devices.get(g, "gpu") in ("host", "cpu"))


@dataclasses.dataclass
class _ReplayDetail:
    """Per-event decomposition of one full replay — the anchor the delta
    scorer perturbs. For every counted event i and group index gi it keeps
    the pool backlog (``wait_at``), the would-be host/disk miss price
    (``hostmiss``), the peer-ingress backlog (``peer_wait``; empty rows when
    the tier has no fabric) and the cost actually charged (``paid``), all
    recorded during the anchor replay with the pool busy clocks and channel
    state it really saw. A single-expert move re-prices only that expert's
    events against these frozen backgrounds.

    With host co-execution columns (``host_place``), ``groups`` carries the
    device groups first and the host/CPU groups after; ``host_set`` marks
    the host columns, whose events pay ``exec_pen`` (the extra CPU service
    time over the device exec constant) on top of their wait/switch, and
    whose ``hostmiss`` column is the host-arm assignment cost (never the
    device PCIe formula). ``peer_wait`` rows stay device-column-only."""
    groups: List[str]
    has_peer: bool = False
    total: float = 0.0
    n: int = 0
    paid: List[float] = dataclasses.field(default_factory=list)
    wait_at: List[List[float]] = dataclasses.field(default_factory=list)
    hostmiss: List[List[float]] = dataclasses.field(default_factory=list)
    peer_wait: List[List[float]] = dataclasses.field(default_factory=list)
    peer_pred: Dict[str, float] = dataclasses.field(default_factory=dict)
    events_of: Dict[str, List[int]] = dataclasses.field(default_factory=dict)
    host_set: FrozenSet[str] = frozenset()
    exec_pen: float = 0.0

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0


def _replay(coe: "CoEModel", capacities: Mapping[str, int],
            plan: PlacementPlan, trace: WorkloadTrace,
            tier: TierSpec, links: str = "shared",
            pool_devices: Optional[Mapping[str, str]] = None,
            record: bool = False,
            host_groups: Sequence[str] = (),
            host_exec_s: float = 0.0) -> _ReplayDetail:
    """The replay loop behind ``replay_cost``; with ``record`` it also
    captures the per-event backgrounds the delta scorer needs. Recording
    adds only *pure* probes (``host_disk_cost``, channel backlog reads), so
    the accumulated cost is bit-identical with and without it.

    ``host_groups`` (heterogeneous co-execution) adds the named host/CPU
    pools as candidate execution arms: their events pay the host-arm
    assignment cost (free for DRAM residents) plus the extra CPU service
    time ``host_exec_s - exec_s``, and their misses ride the SSD link only.
    Empty ``host_groups`` leaves every cost bit-identical to before."""
    groups = _device_groups(capacities, pool_devices)
    host_list = [g for g in host_groups if g in capacities]
    detail = _ReplayDetail(groups=groups + host_list,
                           host_set=frozenset(host_list))
    if not groups or not trace.events:
        return detail
    h = MemoryHierarchy(coe, tier, pools=dict(capacities), links=links,
                        link_groups=groups)
    detail.has_peer = h.topology.has_peer
    pen = max(0.0, host_exec_s - trace.exec_s) if host_list else 0.0
    detail.exec_pen = pen
    if host_list:
        h.host_exec_enabled = True
    for eid, g in plan.layout():
        pool = h.pools.get(g)
        if pool is not None and eid not in pool \
                and coe.spec(eid).mem_bytes <= pool.free_bytes():
            pool.add(eid)
            pool.ready.add(eid)
    if h.host is not None:
        # steady state: DRAM holds as much of the hot catalog as it can
        for spec in coe.by_usage():
            if spec.mem_bytes <= h.host.free_bytes():
                h.host.insert(spec.id)
    busy = {g: 0.0 for g in groups + host_list}
    now, cost, n = 0.0, 0.0, 0
    for eid in trace.events:
        if eid not in coe.experts:
            continue
        best_g, best_total, best_switch = None, 0.0, 0.0
        best_host = False
        waits: List[float] = []
        for g in groups:
            switch = 0.0 if eid in h.pools[g] \
                else h.assignment_cost(eid, now, group=g)
            wait = max(0.0, busy[g] - now)
            if record:
                waits.append(wait)
            total = wait + switch
            if best_g is None or total < best_total:
                best_g, best_total, best_switch = g, total, switch
                best_host = False
        for g in host_list:
            # device arms win ties (strict <): hetero only reroutes a batch
            # when the host arm is genuinely cheaper
            switch = 0.0 if eid in h.pools[g] \
                else h.assignment_cost(eid, now, group=g, device="cpu")
            wait = max(0.0, busy[g] - now)
            if record:
                waits.append(wait)
            total = wait + switch + pen
            if total < best_total:
                best_g, best_total, best_switch = g, total, switch
                best_host = True
        cost += best_total
        n += 1
        if record:
            detail.paid.append(best_total)
            detail.wait_at.append(waits)
            detail.hostmiss.append(
                [h.host_disk_cost(eid, now, group=g) for g in groups]
                + [h.assignment_cost(eid, now, group=g, device="cpu")
                   for g in host_list])
            if detail.has_peer:
                detail.peer_wait.append(
                    [max(0.0, h.topology.peer_for(g).busy_until - now)
                     for g in groups])
                if eid not in detail.peer_pred:
                    detail.peer_pred[eid] = h.transfer.predict_peer(
                        coe.spec(eid).mem_bytes)
            detail.events_of.setdefault(eid, []).append(n - 1)
        if eid not in h.pools[best_g]:
            if best_host:
                # a host-arm miss is a disk -> DRAM load: SSD link only,
                # never the device PCIe formula
                h.begin_host_load(eid, now)
            else:
                h.begin_device_load(eid, now, group=best_g)
        busy[best_g] = max(now, busy[best_g]) + best_switch \
            + (host_exec_s if best_host else trace.exec_s)
        now += trace.gap_s
    detail.total, detail.n = cost, n
    return detail


def replay_cost(coe: "CoEModel", capacities: Mapping[str, int],
                plan: PlacementPlan, trace: WorkloadTrace,
                tier: TierSpec, links: str = "shared",
                pool_devices: Optional[Mapping[str, str]] = None,
                host_groups: Sequence[str] = (),
                host_exec_s: float = 0.0) -> float:
    """Mean per-event queueing + switch seconds of serving ``trace`` under
    ``plan``'s (static) layout.

    A fresh ``MemoryHierarchy`` is warmed to the plan (device pools hold the
    planned copies, host DRAM fills hottest-first with the rest), then each
    event is assigned to the device pool minimizing
    ``pool busy backlog + assignment_cost`` — the same two terms the online
    scheduler's makespan argmin weighs. Misses start real transfers on the
    contended channels (SSD / per-group PCIe / peer ingress), so hot experts
    crowded behind one link keep getting more expensive within the replay,
    exactly as they would in the simulator. ``host_groups``/``host_exec_s``
    add host co-execution arms (see ``_replay``)."""
    return _replay(coe, capacities, plan, trace, tier, links=links,
                   pool_devices=pool_devices, host_groups=host_groups,
                   host_exec_s=host_exec_s).mean


class _DeltaScorer:
    """Scores assignment perturbations against a full-replay anchor.

    For each expert whose pool set differs from the anchor's, every one of
    its trace events is re-priced as ``min over groups`` of the recorded
    pool backlog plus: zero (resident under the candidate), the peer-copy
    price (fabric present and a sibling copy exists), or the recorded
    host/disk miss price. Events of unchanged experts keep their anchor
    cost, and cross-event busy-clock drift is ignored — the approximation
    periodic full-replay revalidation (and the final full replay) corrects,
    so accepted estimates never leak into the returned cost."""

    def __init__(self, detail: _ReplayDetail,
                 anchor_assign: Mapping[str, Sequence[str]]):
        self.d = detail
        self.anchor: Dict[str, FrozenSet[str]] = {
            e: frozenset(p) for e, p in anchor_assign.items() if p}

    def changed(self, assign: Mapping[str, Sequence[str]]) -> List[str]:
        """Experts whose pool set differs from the anchor's."""
        out = []
        # sorted: the caller sums float deltas in this order, so hash-order
        # iteration would make the estimate depend on PYTHONHASHSEED
        for e in sorted(assign.keys() | self.anchor.keys()):
            if frozenset(assign.get(e) or ()) != \
                    self.anchor.get(e, frozenset()):
                out.append(e)
        return out

    def estimate(self, assign: Mapping[str, Sequence[str]]) -> float:
        """Estimated mean replay cost of ``assign`` (anchor scale)."""
        d = self.d
        delta = 0.0
        for eid in self.changed(assign):
            pools = frozenset(assign.get(eid) or ())
            for i in d.events_of.get(eid, ()):
                delta += self._event_best(i, eid, pools) - d.paid[i]
        return (d.total + delta) / d.n if d.n else 0.0

    def _event_best(self, i: int, eid: str,
                    pools: FrozenSet[str]) -> float:
        d = self.d
        waits = d.wait_at[i]
        miss_host = d.hostmiss[i]
        host_set = d.host_set
        # only a *device* copy can seed a peer (pool -> pool) forward — a
        # host-pool placement never rides the fabric
        peer_ok = d.has_peer and any(p not in host_set for p in pools)
        peer_base = d.peer_pred.get(eid, 0.0) if peer_ok else 0.0
        best = None
        for gi, g in enumerate(d.groups):
            if g in host_set:   # host co-execution arm: wait + host-arm
                #                 switch + the extra CPU service time
                c = waits[gi] + d.exec_pen if g in pools \
                    else waits[gi] + miss_host[gi] + d.exec_pen
            elif g in pools:
                c = waits[gi]
            elif peer_ok:   # any planned device copy is a sibling of g here
                c = waits[gi] + peer_base + d.peer_wait[i][gi]
            else:
                c = waits[gi] + miss_host[gi]
            if best is None or c < best:
                best = c
        return best if best is not None else 0.0


# --------------------------------------------------------------------------- #
# local search
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SearchConfig:
    iterations: int = 400        # move proposals (delta: scored by the
    #                              anchor decomposition; full: one replay each)
    patience: int = 120          # stop after this many consecutive rejects
    seed: int = 0                # RNG seed (the search is deterministic
    #                              unless time_budget_s is set)
    replication: int = 2         # max planned copies beyond the primary
    replica_fraction: float = 0.35   # per-pool replica byte budget the
    #                                  search may spend (the greedy sweep's
    #                                  0.10 stays its own default)
    hot_pool: int = 32           # replicate/drop candidates come from the
    #                              hottest / coldest end of the trace weights
    scoring: str = "delta"       # delta (anchor + per-expert re-pricing,
    #                              periodic full-replay revalidation) | full
    #                              (every proposal replays the whole trace)
    revalidate_every: int = 8    # delta mode: full replay after this many
    #                              estimate-accepted moves (drift bound)
    time_budget_s: Optional[float] = None   # wall-clock cap on the proposal
    #                              loop (None: iterations/patience only) —
    #                              the benchmark's same-budget comparison
    host_place: bool = False     # heterogeneous co-execution: offer the
    #                              host/CPU pools as placement targets (the
    #                              ``host_place`` move plans deliberate CPU
    #                              residents for cold-tail experts)
    host_exec_factor: float = 12.0   # CPU service time as a multiple of the
    #                              trace's device exec constant (paper
    #                              Fig. 5: CPU is ~8-20x slower)

    def __post_init__(self):
        if self.iterations < 0 or self.patience <= 0:
            raise ValueError("iterations must be >= 0, patience > 0")
        if self.host_exec_factor <= 0:
            raise ValueError(f"host_exec_factor must be positive, "
                             f"got {self.host_exec_factor}")
        if self.replication < 0:
            raise ValueError(f"replication must be >= 0, "
                             f"got {self.replication}")
        if not 0.0 <= self.replica_fraction <= 1.0:
            raise ValueError(f"replica_fraction must be in [0, 1], "
                             f"got {self.replica_fraction}")
        if self.scoring not in ("delta", "full"):
            raise ValueError(f"scoring must be 'delta' or 'full', "
                             f"got {self.scoring!r}")
        if self.revalidate_every <= 0:
            raise ValueError(f"revalidate_every must be > 0, "
                             f"got {self.revalidate_every}")
        if self.time_budget_s is not None and self.time_budget_s <= 0:
            raise ValueError(f"time_budget_s must be positive, "
                             f"got {self.time_budget_s}")


@dataclasses.dataclass
class SearchResult:
    plan: PlacementPlan
    seed_cost: float             # replay cost of the greedy seed plan
    cost: float                  # replay cost of the returned plan (<= seed)
    proposed: int
    accepted: int
    fell_back: bool              # no move improved: the seed plan itself is
    #                              returned (pinned-equivalence fallback)
    scoring: str = "full"        # how proposals were scored
    full_replays: int = 0        # trace replays actually performed (delta
    #                              mode: anchor + revalidations; full mode:
    #                              seed + one per scored proposal)

    def snapshot(self) -> dict:
        return {"seed_cost_s": round(self.seed_cost, 6),
                "cost_s": round(self.cost, 6),
                "improvement": round(1.0 - self.cost / self.seed_cost, 4)
                if self.seed_cost > 0 else 0.0,
                "proposed": self.proposed,
                "accepted": self.accepted,
                "fell_back": self.fell_back,
                "scoring": self.scoring,
                "full_replays": self.full_replays,
                "plan": self.plan.snapshot()}


class _Mover:
    """Move proposals over an expert -> [pools] mapping (pure: every
    proposal returns a mutated copy; feasibility beyond free capacity is
    enforced by ``PlacementPlan.from_assignments`` at scoring time)."""

    def __init__(self, coe: "CoEModel", capacities: Mapping[str, int],
                 groups: List[str], weights: Mapping[str, int],
                 rng: np.random.RandomState, cfg: SearchConfig,
                 host_groups: Sequence[str] = ()):
        self.coe = coe
        self.capacities = capacities
        self.groups = groups
        self.host_groups = list(host_groups)
        self.weights = weights
        self.rng = rng
        self.cfg = cfg
        by_weight = sorted(coe.experts,
                           key=lambda e: (-weights.get(e, 0), e))
        self.hot = by_weight[:cfg.hot_pool]
        self.cold = by_weight[-cfg.hot_pool:]

    # ------------------------------------------------------------------ #
    def _free(self, assign: Mapping[str, List[str]]) -> Dict[str, int]:
        free = dict(self.capacities)
        for eid, pools in assign.items():
            for g in pools:
                free[g] = free.get(g, 0) - self.coe.spec(eid).mem_bytes
        return free

    def _pick(self, items: List):
        return items[self.rng.randint(len(items))] if items else None

    @staticmethod
    def _copy(assign: Mapping[str, List[str]]) -> Dict[str, List[str]]:
        return {e: list(p) for e, p in assign.items() if p}

    # ------------------------------------------------------------------ #
    def propose(self, assign: Mapping[str, List[str]]
                ) -> Optional[Dict[str, List[str]]]:
        moves = ["replicate", "replicate", "replace", "replace",
                 "replace", "drop_replica", "drop_cold", "migrate",
                 "swap", "place"]
        if self.host_groups:
            # appended only when host placement is on, so the RNG stream —
            # and therefore the whole search trajectory — is unchanged when
            # it is off
            moves.append("host_place")
        move = self._pick(moves)
        return getattr(self, "_" + move)(assign)

    def _replicate(self, assign):
        free = self._free(assign)
        cands = []
        for eid in self.hot:
            pools = assign.get(eid, ())
            if not pools or len(pools) > self.cfg.replication:
                continue
            mem = self.coe.spec(eid).mem_bytes
            for g in self.groups:
                if g not in pools and mem <= free[g]:
                    cands.append((eid, g))
        picked = self._pick(cands)
        if picked is None:
            return None
        eid, g = picked
        new = self._copy(assign)
        new[eid].append(g)
        return new

    def _drop_replica(self, assign):
        cands = [(eid, g) for eid, pools in assign.items()
                 for g in pools[1:] if g in self.groups]
        picked = self._pick(cands)
        if picked is None:
            return None
        eid, g = picked
        new = self._copy(assign)
        new[eid].remove(g)
        return new

    def _replace(self, assign):
        """Composite move for full pools: evict a colder single-copy
        resident of a pool AND give a hotter expert a copy there in one
        proposal — neither half alone improves strictly (dropping a
        zero-weight expert is cost-neutral, placing needs the space first),
        so greedy accept would plateau without it."""
        free = self._free(assign)
        w = self.weights
        by_group: Dict[str, List[str]] = {}
        for e, pools in assign.items():
            if len(pools) == 1 and pools[0] in self.groups:
                by_group.setdefault(pools[0], []).append(e)
        cands = []
        for eid in self.hot:
            pools = assign.get(eid, ())
            if pools and len(pools) > self.cfg.replication:
                continue
            mem = self.coe.spec(eid).mem_bytes
            for g in self.groups:
                if g in pools:
                    continue
                for victim in by_group.get(g, ()):
                    if victim == eid or w.get(victim, 0) >= w.get(eid, 0):
                        continue
                    if mem <= free[g] + self.coe.spec(victim).mem_bytes:
                        cands.append((eid, g, victim))
        picked = self._pick(cands)
        if picked is None:
            return None
        eid, g, victim = picked
        new = self._copy(assign)
        del new[victim]
        new.setdefault(eid, []).append(g)
        return new

    def _drop_cold(self, assign):
        """Drop a cold single-copy expert off its device pool entirely (it
        falls back to host/disk) — the move that lets hot replicas claim
        space the greedy sweep spent on the tail."""
        cands = [eid for eid in self.cold
                 if len(assign.get(eid, ())) == 1
                 and assign[eid][0] in self.groups]
        eid = self._pick(cands)
        if eid is None:
            return None
        new = self._copy(assign)
        del new[eid]
        return new

    def _migrate(self, assign):
        free = self._free(assign)
        placed = [eid for eid, pools in assign.items()
                  if any(g in self.groups for g in pools)]
        eid = self._pick(placed)
        if eid is None:
            return None
        src = self._pick([g for g in assign[eid] if g in self.groups])
        mem = self.coe.spec(eid).mem_bytes
        dsts = [g for g in self.groups
                if g != src and g not in assign[eid] and mem <= free[g]]
        dst = self._pick(dsts)
        if dst is None:
            return None
        new = self._copy(assign)
        new[eid][new[eid].index(src)] = dst
        return new

    def _swap(self, assign):
        singles = [eid for eid, pools in assign.items()
                   if len(pools) == 1 and pools[0] in self.groups]
        if len(singles) < 2:
            return None
        a = self._pick(singles)
        b = self._pick([e for e in singles if assign[e][0] != assign[a][0]])
        if b is None:
            return None
        new = self._copy(assign)
        new[a][0], new[b][0] = new[b][0], new[a][0]
        return new

    def _place(self, assign):
        free = self._free(assign)
        cands = []
        for eid, w in self.weights.items():
            if w <= 0 or assign.get(eid) or eid not in self.coe.experts:
                continue
            mem = self.coe.spec(eid).mem_bytes
            cands.extend((eid, g) for g in self.groups if mem <= free[g])
        picked = self._pick(cands)
        if picked is None:
            return None
        eid, g = picked
        new = self._copy(assign)
        new[eid] = [g]
        return new

    def _host_place(self, assign):
        """Deliberate CPU residents (heterogeneous co-execution): move a
        cold single-copy device-pool expert — or place an unplaced traced
        expert — onto a host/CPU pool, where it executes in place. Frees
        device bytes for hotter experts while the cold tail keeps serving
        without a disk reload."""
        free = self._free(assign)
        cands = []
        for eid in self.cold:
            if eid not in self.coe.experts:
                continue
            pools = assign.get(eid, ())
            if pools and (len(pools) != 1 or pools[0] not in self.groups):
                continue
            mem = self.coe.spec(eid).mem_bytes
            cands.extend((eid, g) for g in self.host_groups
                         if mem <= free[g])
        picked = self._pick(cands)
        if picked is None:
            return None
        eid, g = picked
        new = self._copy(assign)
        new[eid] = [g]
        return new


def search_placement(coe: "CoEModel", capacities: Mapping[str, int],
                     trace: WorkloadTrace, tier: TierSpec,
                     links: str = "shared",
                     pool_devices: Optional[Mapping[str, str]] = None,
                     seed_plan: Optional[PlacementPlan] = None,
                     config: Optional[SearchConfig] = None) -> SearchResult:
    """Local search over placements, seeded by (and never worse than) the
    greedy hot-first sweep.

    Starting from ``seed_plan`` (default: ``PlacementPlan.build`` with no
    replication — the paper's sweep), propose replicate / drop / migrate /
    swap / place moves; stop after ``config.patience`` consecutive rejects,
    ``config.iterations`` proposals, or ``config.time_budget_s`` wall
    seconds. With ``scoring='full'`` every proposal replays the whole trace
    and only strict improvements are kept. With ``scoring='delta'`` (the
    default) proposals are scored against a full-replay *anchor* by
    re-pricing only the moved experts' trace events; every
    ``revalidate_every`` estimate-accepts (and once at the end) a real
    replay re-anchors the search, and only plans a *full* replay verified
    as strictly better than the incumbent ever become the result — so the
    returned cost is always a true replay cost and never worse than the
    greedy seed. When nothing improves, the *original seed plan object* is
    returned (``fell_back``), so greedy-equivalence is exact, not
    approximate."""
    cfg = config or SearchConfig()
    if seed_plan is None:
        seed_plan = PlacementPlan.build(coe, capacities)
    groups = _device_groups(capacities, pool_devices)
    host_groups = _host_groups(capacities, pool_devices) \
        if cfg.host_place else []
    host_exec_s = cfg.host_exec_factor * trace.exec_s if host_groups else 0.0
    seed_assign = {e: list(seed_plan.pools_for(e))
                   for e in seed_plan.assignments}
    # a caller-supplied seed may already spend more replicas than the search
    # config allows; widen the limits so the seed itself stays feasible
    seed_snap = seed_plan.snapshot()
    repl_limit = max(cfg.replication, seed_plan.replication,
                     max((len(p) - 1 for p in seed_assign.values()),
                         default=0))
    frac_limit = cfg.replica_fraction
    for g, rb in seed_snap["replica_bytes"].items():
        cap = capacities.get(g, 0)
        if cap > 0 and rb > 0:
            frac_limit = max(frac_limit, min(1.0, (rb + 1) / cap))

    def materialize(assign) -> PlacementPlan:
        return PlacementPlan.from_assignments(
            coe, capacities, assign, replication=repl_limit,
            replica_fraction=frac_limit)

    def full_detail(plan) -> _ReplayDetail:
        return _replay(coe, capacities, plan, trace, tier, links=links,
                       pool_devices=pool_devices,
                       record=cfg.scoring == "delta",
                       host_groups=host_groups, host_exec_s=host_exec_s)

    state = {"full_replays": 1}
    seed_detail = full_detail(seed_plan)
    seed_cost = seed_detail.mean
    best_assign, best_cost, best_plan = seed_assign, seed_cost, seed_plan
    best_detail = seed_detail
    proposed = accepted = stale = 0
    deadline = None if cfg.time_budget_s is None \
        else _time.monotonic() + cfg.time_budget_s

    def out_of_budget(it: int) -> bool:
        if it >= cfg.iterations or stale >= cfg.patience:
            return True
        return deadline is not None and _time.monotonic() >= deadline

    if groups and trace.events:
        mover = _Mover(coe, capacities, groups, trace.weights(),
                       np.random.RandomState(cfg.seed), cfg,
                       host_groups=host_groups)
        if cfg.scoring == "full":
            it = 0
            while not out_of_budget(it):
                it += 1
                cand = mover.propose(best_assign)
                proposed += 1
                if cand is None:
                    stale += 1
                    continue
                try:
                    plan = materialize(cand)
                except ValueError:   # replica budget / capacity infeasible
                    stale += 1
                    continue
                cost = replay_cost(coe, capacities, plan, trace, tier,
                                   links=links, pool_devices=pool_devices,
                                   host_groups=host_groups,
                                   host_exec_s=host_exec_s)
                state["full_replays"] += 1
                if cost < best_cost - 1e-12:
                    best_assign, best_cost, best_plan = cand, cost, plan
                    accepted += 1
                    stale = 0
                else:
                    stale += 1
        else:
            scorer = _DeltaScorer(seed_detail, seed_assign)
            cur_assign, cur_est = seed_assign, seed_cost
            pending = 0     # estimate-accepts since the last revalidation

            def revalidate():
                """Full replay of the current assignment: adopt it as the
                incumbent iff strictly better, else rewind to the verified
                best; re-anchor the scorer either way."""
                nonlocal best_assign, best_cost, best_plan, best_detail
                nonlocal cur_assign, cur_est, scorer, pending
                plan = materialize(cur_assign)
                detail = full_detail(plan)
                state["full_replays"] += 1
                if detail.mean < best_cost - 1e-12:
                    best_assign, best_cost, best_plan = \
                        cur_assign, detail.mean, plan
                    best_detail = detail
                else:
                    cur_assign = best_assign
                    detail = best_detail
                scorer = _DeltaScorer(detail, cur_assign)
                cur_est = detail.mean
                pending = 0

            it = 0
            while not out_of_budget(it):
                it += 1
                cand = mover.propose(cur_assign)
                proposed += 1
                if cand is None:
                    stale += 1
                    continue
                try:
                    materialize(cand)    # feasibility gate only
                except ValueError:
                    stale += 1
                    continue
                est = scorer.estimate(cand)
                if est < cur_est - 1e-12:
                    cur_assign, cur_est = cand, est
                    accepted += 1
                    pending += 1
                    stale = 0
                    if pending >= cfg.revalidate_every:
                        revalidate()
                else:
                    stale += 1
            if pending:
                revalidate()
    return SearchResult(plan=best_plan, seed_cost=seed_cost, cost=best_cost,
                        proposed=proposed, accepted=accepted,
                        fell_back=best_plan is seed_plan,
                        scoring=cfg.scoring,
                        full_replays=state["full_replays"])
