"""Device-fleet topology: how many accelerators, and who owns which link.

Source of truth: the only builder of multi-device (pools, executor specs)
shapes, and the only validator of the one-pool-one-device-kind invariant
(``validate_pool_groups``) — construction-time and runtime scale-ups both
go through it.

One physical system (paper §5.1) is a single accelerator behind one SSD and
one PCIe link; a *fleet* is N accelerators that each own a device-memory
pool and a host->device channel while fanning in on the shared SSD.
``FleetSpec`` describes that shape declaratively; ``build_fleet`` turns it
into the (pools, executor specs) pair ``CoServeSystem`` consumes, with the
single-device case reproducing ``workload.make_executor_specs`` exactly so
the paper-reproduction trajectory is unchanged.

``validate_pool_groups`` is the spec-level guard: two executor specs with
conflicting ``device`` kinds must not share one pool group — a pool is one
physical device's memory, and mixing (say) a CPU executor's DRAM pool with
a GPU executor's HBM pool would silently merge two different latency models
into one residency set.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.memory.tiers import LINK_MODES, TierSpec


def device_group_name(index: int, n_devices: int, kind: str = "gpu") -> str:
    """Pool-group name of accelerator ``index``: the seed's bare ``gpu`` for
    a single device (compat), ``gpu0``/``gpu1``/... for a fleet."""
    return kind if n_devices == 1 else f"{kind}{index}"


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Shape of one serving fleet.

    ``gpu_per_device`` executors run on each of ``n_devices`` accelerators
    (the paper's 3-executors-on-one-GPU layout, per device); ``n_cpu``
    host-side executors run from DRAM. ``links`` picks the host->device
    channel layout: ``shared`` (one PCIe link the whole fleet queues on —
    the PR 2 baseline) or ``per-device`` (one link per accelerator).
    Expert replication is a *placement* decision, not a fleet-shape one —
    pass it to ``CoServeSystem``/``PlacementPlan.build``.
    """
    n_devices: int = 1
    gpu_per_device: int = 3
    n_cpu: int = 1
    links: str = "shared"

    def __post_init__(self):
        if self.n_devices < 1:
            raise ValueError(f"fleet needs >= 1 device, got {self.n_devices}")
        if self.gpu_per_device < 0 or self.n_cpu < 0:
            raise ValueError("executor counts must be >= 0")
        if self.links not in LINK_MODES:
            raise ValueError(f"unknown link mode {self.links!r} "
                             f"(expected one of {LINK_MODES})")

    def device_groups(self) -> List[str]:
        return [device_group_name(i, self.n_devices)
                for i in range(self.n_devices)]


def build_fleet(tier: TierSpec, fleet: FleetSpec,
                pool_fraction: float = 0.75,
                gpu_pool_bytes: Optional[int] = None,
                cpu_multiplier: float = 0.0
                ) -> Tuple[Dict[str, int], list]:
    """(pools, executor specs) for a fleet on ``tier``-class devices.

    Each accelerator owns ``tier.device_bytes`` of memory split pool/batch by
    ``pool_fraction`` (batch region divided between that device's
    executors); CPU executors share half the host DRAM as in the seed. For
    ``n_devices == 1`` the output is identical to
    ``workload.make_executor_specs(tier, gpu_per_device, n_cpu)``.
    ``cpu_multiplier`` > 0 derives the CPU service-time model from the
    device time instead of the static constants (``hetero.cpu_multiplier``).
    """
    # lazy: workload imports repro.core.serving, which imports repro.fleet
    from repro.core.serving import ExecutorSpec
    from repro.core.workload import device_profile

    pools: Dict[str, int] = {}
    specs: List[ExecutorSpec] = []
    n_gpu_total = fleet.n_devices * fleet.gpu_per_device
    gpu_prof = device_profile("gpu", tier, cpu_multiplier)
    cpu_prof = device_profile("cpu", tier, cpu_multiplier)

    if tier.unified:
        # one unified memory region split between device- and host-side
        # executors (seed semantics), then carved per accelerator
        gpu_region_total = tier.device_bytes * n_gpu_total \
            // max(1, n_gpu_total + fleet.n_cpu)
        cpu_region = tier.device_bytes - gpu_region_total
        gpu_region = gpu_region_total // max(1, fleet.n_devices)
    else:
        gpu_region = tier.device_bytes        # each device has its own HBM
        cpu_region = tier.host_cache_bytes // 2

    if fleet.gpu_per_device:
        for d in range(fleet.n_devices):
            group = device_group_name(d, fleet.n_devices)
            pool = gpu_pool_bytes if gpu_pool_bytes is not None \
                else int(gpu_region * pool_fraction)
            pools[group] = pool
            batch_each = (gpu_region - pool) // fleet.gpu_per_device
            for _ in range(fleet.gpu_per_device):
                specs.append(ExecutorSpec("gpu", gpu_prof, batch_each, group))
    if fleet.n_cpu:
        pool = int(cpu_region * pool_fraction)
        pools["cpu"] = pool
        batch_each = (cpu_region - pool) // fleet.n_cpu
        for _ in range(fleet.n_cpu):
            specs.append(ExecutorSpec("cpu", cpu_prof, batch_each, "cpu"))
    return pools, specs


def validate_pool_groups(executor_specs: Sequence,
                         membership: Optional[Dict[str, str]] = None
                         ) -> Dict[str, str]:
    """Map pool group -> device kind, rejecting conflicting co-tenants.

    A pool group is one physical device's memory: every executor spec mapped
    onto it must declare the same ``device`` kind. Returns the (new or
    extended copy of ``membership``) map, surfaced in
    ``Metrics.memory['pool_devices']`` — ``add_executor`` passes the current
    membership so runtime scale-ups share the same invariant.
    """
    membership = dict(membership or {})
    for spec in executor_specs:
        group = spec.pool_group or spec.device
        seen = membership.get(group)
        if seen is None:
            membership[group] = spec.device
        elif seen != spec.device:
            raise ValueError(
                f"pool group {group!r} maps executors with conflicting "
                f"device kinds {seen!r} and {spec.device!r} — one pool is "
                "one physical device's memory")
    return membership
