"""PlacementPlan: expert -> device-pool assignment as a first-class object.

Source of truth: the only record of where each expert is *supposed* to
live and how many planned copies it has — pools hold what the plan says
(modulo runtime eviction), and every byte-accounting question about
placement (per-pool planned/replica budgets) is answered here.

The seed decided initial expert placement inside a loop in
``CoServeSystem._initial_placement`` — round-robin over pools by descending
usage probability — and then forgot the decision: nothing could ask "where
is expert X *supposed* to live", replication was impossible, and a scale
event could only re-divide batch memory. SambaNova's SN40L composes experts
across many sockets and the QoS-Efficient Multi-MoE work partially
reconfigures expert placement across devices at runtime; both need placement
to be an explicit, queryable object. ``PlacementPlan`` is that object:

  base assignment   the paper's §4.1 round-robin-by-usage sweep, recorded
                    per expert instead of executed and discarded;
  replication       during the same hot-first sweep, an expert also gets up
                    to ``replication`` planned copies on other pools, drawn
                    from a bounded per-pool replica budget
                    (``replica_fraction`` of capacity) — hot experts claim
                    replica slots *before* cold experts claim primaries, so
                    several devices can serve the head of the distribution
                    switch-free while the tail still spills to host/disk;
  rebalance         scale events re-run the replication pass with pools
                    weighted by live executor count (hot pools first), so
                    placement follows the fleet instead of staying frozen
                    at construction.

The plan never exceeds a pool's byte capacity (planned bytes are accounted
exactly), and it is engine-independent: ``CoServeSystem`` applies it with
warm loads at init and the autoscaler applies rebalance deltas through the
normal contended load path.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Mapping, Optional, Sequence, \
    Tuple

if TYPE_CHECKING:  # pragma: no cover — core imports this package
    from repro.core.coe import CoEModel


class PlacementPlan:
    """Explicit expert -> device-pool assignment with bounded replication."""

    def __init__(self, coe: "CoEModel", capacities: Mapping[str, int],
                 replication: int = 0, replica_fraction: float = 0.10):
        if replication < 0:
            raise ValueError(f"replication must be >= 0, got {replication}")
        if not 0.0 <= replica_fraction <= 1.0:
            raise ValueError(f"replica_fraction must be in [0, 1], "
                             f"got {replica_fraction}")
        self.coe = coe
        self.capacities: Dict[str, int] = dict(capacities)
        self.replication = replication
        self.replica_fraction = replica_fraction
        # expert -> pools holding a planned copy; first entry is the base
        # (primary) assignment, the rest are replicas
        self.assignments: Dict[str, List[str]] = {}
        self._planned_bytes: Dict[str, int] = {g: 0 for g in self.capacities}
        self._replica_bytes: Dict[str, int] = {g: 0 for g in self.capacities}
        # (expert, pool) in planned load order — hottest first, so applying
        # the plan warms pools deterministically
        self._layout: List[Tuple[str, str]] = []
        self.rebalances = 0

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, coe: "CoEModel", capacities: Mapping[str, int],
              replication: int = 0, replica_fraction: float = 0.10,
              pool_order: Optional[List[str]] = None) -> "PlacementPlan":
        """One hot-first sweep: each expert's primary goes round-robin
        first-fit (bit-identical to the seed's ``_initial_placement`` loop
        when ``replication == 0``), and — with replication on — up to
        ``replication`` copies land on *other* pools out of each pool's
        bounded replica budget, so the head of the usage distribution claims
        its replica slots before the tail claims primaries."""
        plan = cls(coe, capacities, replication, replica_fraction)
        pools = pool_order if pool_order is not None else list(capacities)
        if pools:
            i = 0
            for spec in coe.by_usage():
                primary = None
                for j in range(len(pools)):
                    g = pools[(i + j) % len(pools)]
                    if spec.mem_bytes <= plan.free_planned(g):
                        plan._place(spec.id, g)
                        primary = g
                        i = (i + j + 1) % len(pools)
                        break
                # pools full / expert too large: stays on lower tiers
                if primary is not None and replication:
                    plan._replicate_one(spec, pools)
        return plan

    @classmethod
    def from_assignments(cls, coe: "CoEModel", capacities: Mapping[str, int],
                         assignments: Mapping[str, Sequence[str]],
                         replication: int = 0,
                         replica_fraction: float = 0.10) -> "PlacementPlan":
        """Materialize an explicit expert -> pools mapping (e.g. a searched
        plan) as a validated ``PlacementPlan``. The first pool of each
        expert's list is its primary; the rest are replicas. Layout order is
        hottest-first (``coe.by_usage``), matching the greedy sweep's warm
        order. Raises ``ValueError`` when the mapping overflows a pool, puts
        two copies on one pool, exceeds ``replication`` copies beyond the
        primary, or blows a pool's replica budget
        (``replica_fraction`` x capacity) — the invariants the seeded-random
        tests pin."""
        plan = cls(coe, capacities, replication, replica_fraction)
        unknown = [e for e, pools in assignments.items()
                   if pools and e not in coe.experts]
        if unknown:
            raise ValueError(
                f"assignments name experts not in the catalog: {unknown}")
        known = set(plan.capacities)
        for spec in coe.by_usage():
            pools = assignments.get(spec.id) or ()
            for i, g in enumerate(pools):
                if g not in known:
                    raise ValueError(
                        f"assignment of {spec.id!r} names unknown pool {g!r}")
                if i > 0 and spec.mem_bytes > plan._replica_budget(g):
                    raise ValueError(
                        f"replica of {spec.id!r} overflows pool {g!r}'s "
                        f"replica budget ({replica_fraction:.0%} of capacity)")
                plan._place(spec.id, g, replica=i > 0)
            if len(pools) > 1 + replication:
                raise ValueError(
                    f"{spec.id!r} plans {len(pools) - 1} replicas, "
                    f"replication allows {replication}")
        plan.validate()
        return plan

    def _place(self, expert_id: str, group: str, replica: bool = False):
        self.assignments.setdefault(expert_id, []).append(group)
        self._planned_bytes[group] = self._planned_bytes.get(group, 0) \
            + self.coe.spec(expert_id).mem_bytes
        if replica:
            self._replica_bytes[group] = self._replica_bytes.get(group, 0) \
                + self.coe.spec(expert_id).mem_bytes
        self._layout.append((expert_id, group))

    def _replica_budget(self, group: str) -> int:
        """Bytes still available for replicas on ``group``: replicas may
        claim at most ``replica_fraction`` of the pool, so they sharpen the
        head of the distribution without crowding out primaries wholesale."""
        cap = int(self.capacities.get(group, 0) * self.replica_fraction)
        return cap - self._replica_bytes.get(group, 0)

    def _replicate_one(self, spec, pool_order: List[str]):
        """Plan up to ``replication`` extra copies of one expert on pools it
        is not on yet, within each pool's replica budget. Re-runnable:
        existing copies are kept."""
        placed = self.assignments.get(spec.id)
        if not placed:
            return                     # never replicate what never fit
        want = min(self.replication, len(pool_order) - 1)
        for g in pool_order:
            if len(placed) >= 1 + want:
                break
            if g in placed:
                continue
            if spec.mem_bytes <= self.free_planned(g) \
                    and spec.mem_bytes <= self._replica_budget(g):
                self._place(spec.id, g, replica=True)

    # ------------------------------------------------------------------ #
    # queries
    # ------------------------------------------------------------------ #
    def pools_for(self, expert_id: str) -> Tuple[str, ...]:
        """Every pool planned to hold a copy (empty: lower tiers only)."""
        return tuple(self.assignments.get(expert_id, ()))

    def primary_pool(self, expert_id: str) -> Optional[str]:
        pools = self.assignments.get(expert_id)
        return pools[0] if pools else None

    def replica_count(self, expert_id: str) -> int:
        """Planned copies beyond the primary."""
        return max(0, len(self.assignments.get(expert_id, ())) - 1)

    def planned(self, group: str) -> List[str]:
        """Experts planned onto ``group``, hottest (base sweep order) first."""
        return [eid for eid, g in self._layout if g == group]

    def planned_bytes(self, group: str) -> int:
        return self._planned_bytes.get(group, 0)

    def free_planned(self, group: str) -> int:
        return self.capacities.get(group, 0) - self._planned_bytes.get(group, 0)

    def layout(self) -> List[Tuple[str, str]]:
        """(expert, pool) pairs in planned load order."""
        return list(self._layout)

    # ------------------------------------------------------------------ #
    # artifact serialization (repro.api.artifacts wraps file io)
    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        """Lossless JSON-ready form. ``from_dict`` rebuilds an equivalent
        plan (same assignments, layout order, budgets) against the same
        catalog — the capacities are stored so a reload onto a different
        fleet shape fails loudly instead of silently misplacing."""
        return {"replication": self.replication,
                "replica_fraction": self.replica_fraction,
                "capacities": dict(self.capacities),
                "assignments": {e: list(p)
                                for e, p in self.assignments.items()}}

    @classmethod
    def from_dict(cls, coe: "CoEModel", d: Mapping,
                  capacities: Optional[Mapping[str, int]] = None
                  ) -> "PlacementPlan":
        """Rebuild a saved plan against ``coe``. ``capacities`` (e.g. the
        pools of the system about to apply the plan) must match the saved
        pool shape byte for byte — a plan searched for one fleet is not
        valid on another."""
        for key in ("capacities", "assignments"):
            if key not in d:
                raise ValueError(
                    f"placement plan dict is missing {key!r} "
                    f"(got keys {sorted(d)})")
        saved = {str(g): int(b) for g, b in d["capacities"].items()}
        if capacities is not None and dict(capacities) != saved:
            raise ValueError(
                "saved placement plan was built for pools "
                f"{saved} but the target fleet has {dict(capacities)} — "
                "re-run the placement search for this fleet shape")
        return cls.from_assignments(
            coe, saved, {str(e): list(p) for e, p in d["assignments"].items()},
            replication=int(d.get("replication", 0)),
            replica_fraction=float(d.get("replica_fraction", 0.10)))

    # ------------------------------------------------------------------ #
    # runtime reconfiguration
    # ------------------------------------------------------------------ #
    def rebalance(self, pool_weights: Mapping[str, float],
                  expert_weights: Optional[Mapping[str, float]] = None
                  ) -> List[Tuple[str, str]]:
        """Re-run the replication pass with pools ordered hottest-first by
        ``pool_weights`` (e.g. live executors per pool after a scale event).
        ``expert_weights`` (e.g. observed per-expert assignment counts from
        the online path) re-ranks which experts claim replica slots first;
        without it the static pre-assessed P(use) order is used. Base
        assignments are kept — moving primaries would invalidate warm state
        for no modeled gain — only replicas are (re)planned. Returns the
        newly planned (expert, pool) copies."""
        self.rebalances += 1
        if not self.replication:
            return []
        order = sorted(self.capacities,
                       key=lambda g: (-pool_weights.get(g, 0.0), g))
        if expert_weights:
            specs = sorted(self.coe.experts.values(),
                           key=lambda e: (-expert_weights.get(e.id, 0.0),
                                          -e.usage_prob, e.id))
        else:
            specs = self.coe.by_usage()
        before = len(self._layout)
        for spec in specs:
            self._replicate_one(spec, order)
        return self._layout[before:]

    # ------------------------------------------------------------------ #
    def validate(self):
        """Planned bytes must fit every pool; replicas must be distinct."""
        for g, used in self._planned_bytes.items():
            cap = self.capacities.get(g, 0)
            if used > cap:
                raise ValueError(
                    f"placement plan overflows pool {g!r}: {used} > {cap}")
        for eid, pools in self.assignments.items():
            if len(set(pools)) != len(pools):
                raise ValueError(f"duplicate replica pools for {eid}: {pools}")

    def snapshot(self) -> dict:
        replicas = sum(self.replica_count(e) for e in self.assignments)
        return {
            "replication": self.replication,
            "placed": len(self.assignments),
            "replicas": replicas,
            "rebalances": self.rebalances,
            "planned_bytes": dict(self._planned_bytes),
            "replica_bytes": dict(self._replica_bytes),
        }
