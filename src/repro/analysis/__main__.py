"""CLI for the invariant analyzer: ``python -m repro.analysis [opts] [paths]``.

Source of truth: the exit-code contract CI relies on — 0 iff the scanned
tree is violation-free (and, under ``--strict``, the registries are not
stale); 1 on any violation; 2 on usage errors.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.analysis.checks import CHECK_NAMES, run_checks
from repro.analysis.registry import ALLOWLIST


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant analyzer for the CoServe repro "
                    "(determinism, epoch discipline, tracer guards, "
                    "frozen specs, source-of-truth docstrings).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="treat stale registry entries as errors")
    ap.add_argument("--check", action="append", choices=CHECK_NAMES,
                    help="run only this check (repeatable; default: all)")
    ap.add_argument("--explain", action="store_true",
                    help="print the declared exemption registry and exit")
    args = ap.parse_args(argv)

    if args.explain:
        for e in ALLOWLIST:
            print(f"[{e.check}] {e.module}:{e.qualname or '*'} — {e.reason}")
        return 0

    checks = tuple(args.check) if args.check else CHECK_NAMES
    t0 = time.perf_counter()
    report = run_checks(args.paths or ["src"], checks)
    wall_s = time.perf_counter() - t0

    for v in report.violations:
        print(v.render())
    for w in report.warnings:
        print(w.render(), file=sys.stderr)
    status = "clean" if report.ok(args.strict) else "FAILED"
    print(f"repro.analysis: {report.files} files, "
          f"{len(report.violations)} violation(s), "
          f"{len(report.warnings)} warning(s), "
          f"{wall_s:.2f}s — {status}")
    return 0 if report.ok(args.strict) else 1


if __name__ == "__main__":
    sys.exit(main())
