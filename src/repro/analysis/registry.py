"""Declared exemptions and invariant registries for ``repro.analysis``.

Source of truth: the ONLY place an invariant-analyzer exemption may live.
The checks in ``repro.analysis.checks`` are deliberately strict; everything
the real tree legitimately does against the letter of a rule is declared
here as one reviewable line with a reason. An entry that stops matching
anything is reported as stale (an error under ``--strict``), so the
registry can never silently outlive the code it excuses.

Three registries:

  ``ALLOWLIST``       per-check (module, qualname-prefix) exemptions — the
                      legitimate wall-clock measurement sites, the one
                      queue-mutation helper whose callers bump, etc.
  ``EPOCH_CLASSES``   the version-counter discipline itself: which classes
                      own epoch-guarded state, which fields constitute that
                      state, what counts as the bump, and which methods are
                      exempt (with reasons).
  ``EPOCH_FIELDS``    attribute names that are epoch-guarded state wherever
                      they are mutated (cross-module: ``pool.kv_bytes`` in
                      the decode runtime must bump the pool's epoch).
  ``TRACE_HELPERS``   functions whose *internal* ``emit`` is exempt from the
                      guard-domination rule because every call site carries
                      the guard — calls to these helpers are then checked
                      exactly like raw ``emit`` calls.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Tuple


@dataclasses.dataclass(frozen=True)
class Exemption:
    """One declared, reviewable escape hatch for one check.

    ``qualname`` is a prefix: ``"build_real_system"`` covers the profiling
    closures nested inside it (``build_real_system.run_batch_factory.
    run_batch``), ``"RealEngine"`` covers every method of the class.
    """
    check: str           # which check this exempts ("wallclock", "epoch", ...)
    module: str          # dotted module, e.g. "repro.core.simulator"
    qualname: str        # qualname prefix within the module ("" = whole module)
    reason: str          # why this is legitimate — shown in --explain output


# --------------------------------------------------------------------------- #
# determinism lint: legitimate wall-clock measurement sites.
#
# The rule: sim *semantics* (anything a scheduling decision or a metric that
# must be bit-identical across runs can observe) never reads the wall clock.
# Wall time may only be *measured and reported* — Metrics.wall_s, overhead
# accounting (Fig. 19), real-engine transfer/forward timing, offline
# profiling, and search time budgets (which bound effort, not decisions:
# the returned cost is always an exact replay, budget or not).
# --------------------------------------------------------------------------- #
ALLOWLIST: Tuple[Exemption, ...] = (
    Exemption("wallclock", "repro.core.simulator", "Simulation.run",
              "Metrics.wall_s: measured wall time of the run loop"),
    Exemption("wallclock", "repro.core.simulator", "run_real",
              "real-mode makespan is measured wall time, not sim time"),
    Exemption("wallclock", "repro.core.executor", "Executor.start_load",
              "ExecStats.mgmt_time: eviction-decision overhead (Fig. 19)"),
    Exemption("wallclock", "repro.core.serving", "CoServeSystem.assign",
              "Metrics.sched_time: scheduling overhead (Fig. 19)"),
    Exemption("wallclock", "repro.core.engines", "RealEngine",
              "real backend: measured transfer / forward wall time"),
    Exemption("wallclock", "repro.api.build", "build_real_system",
              "offline profiling measures real jitted forwards (§4.5)"),
    Exemption("wallclock", "repro.fleet.search", "search_placement",
              "time_budget_s bounds search effort, never the result "
              "(the reported cost is an exact replay either way)"),
    Exemption("wallclock", "repro.launch.dryrun", "_compile_stats",
              "reports lower/compile wall time of the dry-run build"),
    Exemption("wallclock", "repro.launch.train", "main",
              "training throughput measurement (tokens/sec)"),
    Exemption("wallclock", "repro.analysis.__main__", "main",
              "the analyzer reports its own wall time; not sim semantics"),
    # epoch-discipline: the one mutation site whose bump lives in callers
    Exemption("epoch", "repro.core.scheduler", "split_batch",
              "both call sites (Executor.start_next_batch, decode admit) "
              "bump the owning queue immediately after the split — the "
              "helper has no queue reference to bump"),
)


# --------------------------------------------------------------------------- #
# epoch-discipline: the PR-7 cache-coherence rule, as data.
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class EpochClass:
    """One class whose state is epoch-guarded: any method that mutates a
    ``field`` (attribute assignment / augmented assignment / subscript store
    / del / mutating container-method call on ``self.<field>``, or a
    ``super().<mutator>()`` call for container subclasses) must also execute
    the declared ``bump`` in the same method."""
    module: str
    cls: str
    fields: Tuple[str, ...]           # guarded attributes of self
    super_mutators: Tuple[str, ...]   # super() calls that mutate (subclasses)
    bump: str                         # human-readable bump description
    bump_attrs: Tuple[str, ...]       # attribute paths that count as the bump
    #                                 # ("epoch.bump" matches self.epoch.bump())
    exempt: Mapping[str, str]         # method -> reason


_CONTAINER_MUTATORS = ("add", "discard", "remove", "pop", "clear", "update",
                       "difference_update", "intersection_update",
                       "symmetric_difference_update", "append", "insert",
                       "extend", "__delitem__", "__setitem__", "__iadd__",
                       "popitem", "setdefault")

EPOCH_CLASSES: Tuple[EpochClass, ...] = (
    EpochClass(
        module="repro.memory.residency", cls="DevicePool",
        fields=("resident", "insert_seq", "used_bytes", "kv_bytes"),
        super_mutators=(),
        bump="self.epoch.bump()", bump_attrs=("epoch.bump",),
        exempt={
            "__init__": "construction precedes any cached reads",
            "touch": "LRU touch reorders eviction, never changes load cost",
        }),
    EpochClass(
        module="repro.memory.residency", cls="HostTier",
        fields=("resident", "insert_seq", "used_bytes", "ready_at"),
        super_mutators=(),
        bump="self.epoch.bump()", bump_attrs=("epoch.bump",),
        exempt={
            "__init__": "construction precedes any cached reads",
            "touch": "LRU touch reorders eviction, never changes load cost",
        }),
    EpochClass(
        module="repro.memory.residency", cls="ReadySet",
        fields=(),
        super_mutators=_CONTAINER_MUTATORS,
        bump="self.epoch.bump()", bump_attrs=("epoch.bump",),
        exempt={"__init__": "construction precedes any cached reads"}),
    EpochClass(
        module="repro.core.executor", cls="TrackedQueue",
        fields=(),
        super_mutators=_CONTAINER_MUTATORS,
        bump="self.version += 1", bump_attrs=("version",),
        exempt={"__init__": "construction precedes any cached reads"}),
)
# HostTier.insert bumps inside its success branch only; the check is
# function-granular (a bump anywhere in the method satisfies it), so no
# exemption is needed for it.


# Cross-module epoch-guarded attribute names: a mutation of ``<base>.<name>``
# in any scoped module (outside the owning classes above) must be paired
# with an epoch/version bump in the same function. ``requests`` covers the
# in-place Group grow/shrink sites (arrange joins, batch splits), whose bump
# is ``bump_queue(...)`` / ``queue.bump()``.
EPOCH_FIELDS: Dict[str, str] = {
    "kv_bytes": "DevicePool KV-byte accounting (decode runtime)",
    "used_bytes": "tier byte accounting",
    "resident": "tier membership",
    "insert_seq": "tier insertion order",
    "requests": "in-place Group mutation (must bump the owning queue)",
}

# Calls that satisfy the cross-module bump requirement: any attribute call
# path ending in one of these, or a bare call to one of these names.
EPOCH_BUMP_CALLS = ("bump",)          # pool.epoch.bump(), queue.bump()
EPOCH_BUMP_FUNCS = ("bump_queue",)    # repro.core.scheduler.bump_queue


# --------------------------------------------------------------------------- #
# tracer-guard lint: registered trace helpers.
#
# ``TransferEngine._trace`` centralizes the per-leg xfer event but carries
# no guard itself — every CALL site holds the ``tracer.enabled`` fast guard
# (one boolean test instead of re-reading it per leg). Registering it here
# exempts the helper's internal ``emit`` and transfers the guard requirement
# to its call sites, which the check then enforces like raw emits.
# --------------------------------------------------------------------------- #
TRACE_HELPERS: Dict[Tuple[str, str], str] = {
    ("repro.memory.transfer", "TransferEngine._trace"):
        "per-leg xfer emitter; every call site carries the enabled guard",
}


def exemptions_for(check: str) -> Tuple[Exemption, ...]:
    return tuple(e for e in ALLOWLIST if e.check == check)
