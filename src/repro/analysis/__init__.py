"""Invariant analyzer + cache sanitizer for the CoServe repro.

Source of truth: machine-checking of the conventions in
docs/architecture.md "Hot paths and invariants" — determinism (no wall
clock / unseeded RNG / set-iteration order in sim semantics), epoch
discipline (every guarded-state mutation bumps its version counter),
tracer fast-guards, frozen specs, and source-of-truth docstrings — plus
the runtime cache sanitizer that shadow-validates the epoch-validated
caches against ``repro.core.reference`` recompute.

Static side::

    python -m repro.analysis --strict src/      # CI entry point
    python tools/lint.py                        # same, repo-root wrapper

Dynamic side (cachesan)::

    REPRO_CACHE_SANITIZE=1 python -m pytest tests/test_simperf_equivalence.py
    # or per-spec: {"observability": {"sanitize": true}}

See docs/analysis.md for the check catalogue and the allowlist policy.
"""
from repro.analysis.checks import (CHECK_NAMES, Report, Violation,
                                   module_name, run_checks)
from repro.analysis.registry import (ALLOWLIST, EPOCH_CLASSES, EPOCH_FIELDS,
                                     TRACE_HELPERS, Exemption)
from repro.analysis.cachesan import (CacheDivergence, CacheSanitizer,
                                     install_from_env, sanitizer_self_test)

__all__ = [
    "ALLOWLIST", "CHECK_NAMES", "CacheDivergence", "CacheSanitizer",
    "EPOCH_CLASSES", "EPOCH_FIELDS", "Exemption", "Report", "TRACE_HELPERS",
    "Violation", "install_from_env", "module_name", "run_checks",
    "sanitizer_self_test",
]
