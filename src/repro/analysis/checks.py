"""AST invariant checks for the CoServe repro tree.

Source of truth: the machine-checked form of docs/architecture.md "Hot
paths and invariants". Five checks, each enforcing one convention the
fast-path equivalence results rest on:

  ``wallclock``   sim semantics never read the wall clock or unseeded RNG,
                  and never iterate a set (hash-order hazard) — every
                  legitimate measurement site is a declared
                  ``registry.ALLOWLIST`` line;
  ``epoch``       every mutation of epoch-guarded state (pool/host
                  membership, byte accounting, in-place group mutation)
                  bumps the paired version counter in the same function —
                  the PR-7 cache-coherence rule, checked against
                  ``registry.EPOCH_CLASSES`` / ``EPOCH_FIELDS``;
  ``tracer``      every ``.emit(`` on a tracer (and every call to a
                  registered trace helper) is dominated by an
                  ``if tracer.enabled:`` / ``if tracer.full:`` guard, and
                  literal event kinds come from ``EVENT_KINDS``;
  ``frozenspec``  no attribute assignment on ``repro.api.spec`` dataclass
                  instances outside ``__post_init__`` /
                  ``dataclasses.replace``, and ``object.__setattr__`` only
                  inside ``__post_init__``;
  ``docstring``   ``fleet/*``, ``memory/*``, ``serve/*``, ``obs/*`` module
                  docstrings carry their latency-number-ownership
                  ("Source of truth") line (PR-4 convention).

Checks are purely syntactic (``ast``), per-file, dependency-free. Scope is
derived from the dotted module path, so fixture trees that mirror
``src/repro/...`` are checked with the real registries.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis import registry
from repro.obs.tracer import EVENT_KINDS

# packages whose modules are sim semantics (wallclock / tracer / epoch scope)
SIM_SCOPE = ("repro.core", "repro.memory", "repro.fleet", "repro.serve",
             "repro.api", "repro.obs", "repro.launch", "repro.analysis")

# module docstrings here must declare latency-number ownership (PR 4)
DOCSTRING_SCOPE = ("repro.fleet", "repro.memory", "repro.serve", "repro.obs")
DOCSTRING_TOKENS = ("source of truth", "source-of-truth")

WALLCLOCK_TIME_FUNCS = ("time", "perf_counter", "perf_counter_ns",
                        "monotonic", "monotonic_ns", "process_time",
                        "process_time_ns", "time_ns", "clock")
WALLCLOCK_DATETIME_FUNCS = ("now", "utcnow", "today")
UNSEEDED_RNG_CLASSES = ("Random", "RandomState", "default_rng", "Generator")
FORBIDDEN_CALLS = {("os", "urandom"): "os.urandom is nondeterministic",
                   ("uuid", "uuid1"): "uuid1 reads clock + MAC",
                   ("uuid", "uuid4"): "uuid4 is nondeterministic"}


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    check: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.check}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Warning_:
    """Non-fatal finding (stale registry entry); fatal under --strict."""
    check: str
    message: str

    def render(self) -> str:
        return f"warning: [{self.check}] {self.message}"


@dataclasses.dataclass
class Report:
    violations: List[Violation] = dataclasses.field(default_factory=list)
    warnings: List[Warning_] = dataclasses.field(default_factory=list)
    files: int = 0

    def ok(self, strict: bool = False) -> bool:
        return not self.violations and not (strict and self.warnings)


# --------------------------------------------------------------------------- #
# path / AST plumbing
# --------------------------------------------------------------------------- #

def module_name(path: str) -> str:
    """Dotted module for a file path: everything from the last ``repro``
    path component on (``.../src/repro/core/executor.py`` ->
    ``repro.core.executor``). Files outside a ``repro`` tree get ""
    (unscoped: only universal checks apply)."""
    parts = os.path.normpath(path).split(os.sep)
    if "repro" not in parts:
        return ""
    i = len(parts) - 1 - parts[::-1].index("repro")
    mod_parts = parts[i:]
    mod_parts[-1] = mod_parts[-1][:-3] if mod_parts[-1].endswith(".py") \
        else mod_parts[-1]
    if mod_parts[-1] == "__init__":
        mod_parts = mod_parts[:-1]
    return ".".join(mod_parts)


def iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs.sort()
                dirs[:] = [d for d in dirs if d != "__pycache__"]
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


class _Scope:
    """Per-file context: qualnames, parents, import aliases."""

    def __init__(self, tree: ast.Module):
        self.parents: Dict[ast.AST, ast.AST] = {}
        self.qualname: Dict[ast.AST, str] = {}
        self.time_aliases: Set[str] = set()       # import time as _t
        self.datetime_names: Set[str] = set()     # datetime / imported class
        self.random_aliases: Set[str] = set()     # import random [as r]
        self.nprandom_bases: Set[str] = set()     # np / numpy aliases
        self.from_time: Set[str] = set()          # from time import perf_counter
        stack: List[str] = []

        def visit(node: ast.AST, parent: Optional[ast.AST]):
            if parent is not None:
                self.parents[node] = parent
            is_def = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                       ast.ClassDef))
            if is_def:
                stack.append(node.name)
                self.qualname[node] = ".".join(stack)
            for child in ast.iter_child_nodes(node):
                visit(child, node)
            if is_def:
                stack.pop()

        visit(tree, None)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name
                    if a.name == "time":
                        self.time_aliases.add(name)
                    elif a.name == "datetime":
                        self.datetime_names.add(name)
                    elif a.name == "random":
                        self.random_aliases.add(name)
                    elif a.name == "numpy":
                        self.nprandom_bases.add(name)
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    name = a.asname or a.name
                    if node.module == "time":
                        self.from_time.add(name)
                    elif node.module == "datetime":
                        self.datetime_names.add(name)
                    elif node.module == "numpy" and a.name == "random":
                        self.nprandom_bases.add("")  # `from numpy import random`
                        self.random_aliases.add(name)

    def enclosing_qualname(self, node: ast.AST) -> str:
        """Qualname of the innermost def/class containing ``node``
        ("" at module level)."""
        cur = self.parents.get(node)
        while cur is not None:
            if cur in self.qualname:
                return self.qualname[cur]
            cur = self.parents.get(cur)
        return ""

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None


def _attr_path(node: ast.AST) -> str:
    """Dotted source path of a Name/Attribute chain ("" if not a chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _exempt(check: str, module: str, qualname: str,
            matched: Set[Tuple[str, str, str]]) -> bool:
    for e in registry.exemptions_for(check):
        if e.module != module:
            continue
        if e.qualname == "" or qualname == e.qualname \
                or qualname.startswith(e.qualname + "."):
            matched.add((e.check, e.module, e.qualname))
            return True
    return False


# --------------------------------------------------------------------------- #
# check 1: determinism (wall clock / unseeded RNG / set iteration)
# --------------------------------------------------------------------------- #

def check_wallclock(path: str, module: str, tree: ast.Module, scope: _Scope,
                    out: List[Violation], matched: Set) -> None:
    if not module.startswith(SIM_SCOPE):
        return

    def flag(node: ast.AST, msg: str):
        qn = scope.enclosing_qualname(node)
        if not _exempt("wallclock", module, qn, matched):
            out.append(Violation(path, node.lineno, "wallclock", msg))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            # direct set iteration: for/comprehension over a set expression
            it = None
            if isinstance(node, (ast.For, ast.AsyncFor)):
                it = node.iter
            elif isinstance(node, ast.comprehension):
                it = node.iter
            if it is not None and _is_set_expr(it):
                out_node = it if hasattr(it, "lineno") else node
                qn = scope.enclosing_qualname(out_node)
                if not _exempt("wallclock", module, qn, matched):
                    out.append(Violation(
                        path, out_node.lineno, "wallclock",
                        "iteration over a set: hash order is not "
                        "deterministic across runs — wrap in sorted(...)"))
            continue
        fn = node.func
        fpath = _attr_path(fn)
        if not fpath:
            continue
        head, _, tail = fpath.partition(".")
        # wall clock: time.time(), _t.perf_counter(), perf_counter() ...
        if head in scope.time_aliases and tail in WALLCLOCK_TIME_FUNCS:
            flag(node, f"wall-clock read {fpath}() in sim-semantics module "
                       "— sim decisions/metrics must use sim time (add an "
                       "ALLOWLIST entry only for measurement-and-report "
                       "sites)")
        elif "." not in fpath and fpath in scope.from_time \
                and fpath in WALLCLOCK_TIME_FUNCS:
            flag(node, f"wall-clock read {fpath}() (from time import ...) "
                       "in sim-semantics module")
        # datetime.now() / datetime.datetime.now()
        elif head in scope.datetime_names \
                and fpath.split(".")[-1] in WALLCLOCK_DATETIME_FUNCS:
            flag(node, f"wall-clock read {fpath}() in sim-semantics module")
        # unseeded RNG constructors: random.Random(), np.random.RandomState()
        elif fpath.split(".")[-1] in UNSEEDED_RNG_CLASSES \
                and not node.args and not node.keywords \
                and (head in scope.random_aliases
                     or (head in scope.nprandom_bases
                         and ".random." in f".{fpath}.")
                     or fpath.startswith("random.")):
            flag(node, f"unseeded RNG {fpath}() — pass an explicit seed so "
                       "runs are reproducible")
        # module-level random.* draws share hidden global state
        elif head in scope.random_aliases and tail and "." not in tail \
                and tail not in UNSEEDED_RNG_CLASSES \
                and tail in ("random", "randint", "randrange", "choice",
                             "choices", "shuffle", "sample", "uniform",
                             "gauss", "expovariate", "betavariate"):
            flag(node, f"module-level {fpath}() uses the hidden global RNG "
                       "— use a seeded random.Random(seed) instance")
        elif (head, tail) in FORBIDDEN_CALLS:
            flag(node, f"{fpath}(): {FORBIDDEN_CALLS[(head, tail)]}")


def _is_set_expr(node: ast.AST) -> bool:
    """Syntactically-certain set expressions: literals, set()/frozenset()
    calls, and &|^- combinations of .keys() views. Membership tests are
    fine; only *iteration* over these is order-hazardous."""
    if isinstance(node, ast.Set):
        return True
    if isinstance(node, ast.SetComp):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitAnd, ast.BitOr, ast.BitXor, ast.Sub)):
        def keysish(n):
            return (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "keys") or _is_set_expr(n)
        return keysish(node.left) or keysish(node.right)
    return False


# --------------------------------------------------------------------------- #
# check 2: epoch discipline
# --------------------------------------------------------------------------- #

def _mutated_fields(fn: ast.AST, bases: Tuple[str, ...],
                    fields: Sequence[str]) -> List[Tuple[str, int]]:
    """(field, line) for every mutation of ``<base>.<field>`` inside ``fn``
    where base is one of ``bases`` ("" = any base). Mutations: assignment,
    augmented assignment, subscript store/del, and mutating container-method
    calls."""
    hits: List[Tuple[str, int]] = []

    def field_of(target: ast.AST) -> Optional[str]:
        # <expr>.field  or  <expr>.field[...]
        if isinstance(target, ast.Subscript):
            target = target.value
        if not isinstance(target, ast.Attribute):
            return None
        if target.attr not in fields:
            # <base>.field[...] appears as Subscript(Attribute(attr=field));
            # <base>.field.method() handled in the Call branch below
            return None
        if bases and ("",) != bases:
            base = _attr_path(target.value)
            if base.split(".")[-1] not in bases and base not in bases:
                return None
        return target.attr

    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                f = field_of(t)
                if f is not None:
                    hits.append((f, node.lineno))
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                f = field_of(t)
                if f is not None:
                    hits.append((f, node.lineno))
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in registry._CONTAINER_MUTATORS:
            f = field_of(node.func.value)
            if f is not None:
                hits.append((f, node.lineno))
    return hits


def _has_bump(fn: ast.AST, bump_attrs: Sequence[str],
              bump_funcs: Sequence[str] = (),
              aug_names: Sequence[str] = ()) -> bool:
    """Whether ``fn`` contains a bump: a call whose attribute path ends in
    one of ``bump_attrs`` (``self.epoch.bump()``, ``pool.epoch.bump()``), a
    bare call to one of ``bump_funcs`` (``bump_queue(q)``), or an augmented
    ``+= 1`` on an attribute named in ``aug_names`` (``self.version += 1``)."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            p = _attr_path(node.func)
            if any(p == b or p.endswith("." + b) for b in bump_attrs):
                return True
            if isinstance(node.func, ast.Name) and node.func.id in bump_funcs:
                return True
        elif isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Add):
            if isinstance(node.target, ast.Attribute) \
                    and node.target.attr in aug_names:
                return True
    return False


def check_epoch(path: str, module: str, tree: ast.Module, scope: _Scope,
                out: List[Violation], matched: Set,
                seen_classes: Set[Tuple[str, str]]) -> None:
    if not module.startswith(SIM_SCOPE):
        return
    # part A: the registered classes' own mutators
    reg_here = {ec.cls: ec for ec in registry.EPOCH_CLASSES
                if ec.module == module}
    class_defs: Dict[str, ast.ClassDef] = {}
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            class_defs[node.name] = node
    for cls_name, ec in reg_here.items():
        cdef = class_defs.get(cls_name)
        if cdef is None:
            continue            # stale-registry warning handled by caller
        seen_classes.add((ec.module, ec.cls))
        for item in cdef.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if item.name in ec.exempt:
                continue
            mutations = _mutated_fields(item, ("self",), ec.fields)
            for node in ast.walk(item):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in ec.super_mutators \
                        and isinstance(node.func.value, ast.Call) \
                        and _attr_path(node.func.value.func) == "super":
                    mutations.append((node.func.attr, node.lineno))
            if mutations and not _has_bump(
                    item, ec.bump_attrs, aug_names=ec.bump_attrs):
                f, line = mutations[0]
                out.append(Violation(
                    path, line, "epoch",
                    f"{ec.cls}.{item.name} mutates epoch-guarded state "
                    f"({f}) without {ec.bump} — epoch-validated caches "
                    "(_holders_cache, _work_cache) would serve stale "
                    "values; bump, or declare an exemption with a reason"))
    # part B: cross-module mutations of registered field names
    owning = set(reg_here)
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        encl = scope.enclosing_qualname(node)
        cls_of = encl.split(".")[0] if encl else ""
        if node.name in ("__init__",) or cls_of in owning \
                or node.name in {c.cls for c in registry.EPOCH_CLASSES}:
            continue
        # skip methods of registered classes (part A covered them)
        parent = scope.parents.get(node)
        if isinstance(parent, ast.ClassDef) and parent.name in owning:
            continue
        mutations = _mutated_fields(node, ("",),
                                    tuple(registry.EPOCH_FIELDS))
        # only direct statements of THIS function: drop hits inside nested
        # defs (they are walked as their own functions)
        nested: Set[int] = set()
        for sub in ast.walk(node):
            if sub is not node and isinstance(
                    sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for s2 in ast.walk(sub):
                    if hasattr(s2, "lineno"):
                        nested.add(s2.lineno)
        mutations = [(f, ln) for f, ln in mutations if ln not in nested]
        if not mutations:
            continue
        qn = scope.qualname.get(node, node.name)
        if _exempt("epoch", module, qn, matched):
            continue
        if _has_bump(node, registry.EPOCH_BUMP_CALLS,
                     registry.EPOCH_BUMP_FUNCS,
                     aug_names=("version", "n")):
            continue
        f, line = mutations[0]
        out.append(Violation(
            path, line, "epoch",
            f"{qn} mutates epoch-guarded state ({f}: "
            f"{registry.EPOCH_FIELDS[f]}) with no epoch/version bump in "
            "the same function — pair it with .epoch.bump() / "
            "bump_queue(...), or declare an ALLOWLIST exemption"))


# --------------------------------------------------------------------------- #
# check 3: tracer guards + event kinds
# --------------------------------------------------------------------------- #

def _is_tracerish(expr: ast.AST) -> bool:
    p = _attr_path(expr)
    last = p.split(".")[-1] if p else ""
    return last in ("tracer", "_trace") or p == "tracer"


def _guard_names(fn: Optional[ast.AST]) -> Set[str]:
    """Local names assigned from a ``...enabled`` / ``...full`` read
    (``traced = self.tracer.enabled``)."""
    names: Set[str] = set()
    if fn is None:
        return names
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Attribute) \
                and node.value.attr in ("enabled", "full"):
            names.add(node.targets[0].id)
    return names


def _test_guards(test: ast.AST, guard_names: Set[str]) -> bool:
    """Whether an ``if`` test (or any and-ed component) is a tracer guard."""
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        return any(_test_guards(v, guard_names) for v in test.values)
    if isinstance(test, ast.Attribute) and test.attr in ("enabled", "full"):
        return True
    if isinstance(test, ast.Name) and test.id in guard_names:
        return True
    return False


def _guarded(node: ast.AST, scope: _Scope, guard_names: Set[str]) -> bool:
    cur = scope.parents.get(node)
    while cur is not None and not isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        if isinstance(cur, ast.If) and _test_guards(cur.test, guard_names):
            return True
        cur = scope.parents.get(cur)
    return False


def check_tracer(path: str, module: str, tree: ast.Module, scope: _Scope,
                 out: List[Violation], matched_helpers: Set) -> None:
    if not module.startswith(SIM_SCOPE) or module == "repro.obs.tracer":
        return
    helper_names = {qual.split(".")[-1]: (mod, qual)
                    for (mod, qual) in registry.TRACE_HELPERS}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        attr = node.func.attr
        is_emit = attr == "emit" and _is_tracerish(node.func.value)
        helper_key = helper_names.get(attr)
        is_helper_call = (helper_key is not None
                          and helper_key[0] == module
                          and attr != "emit")
        if not is_emit and not is_helper_call:
            continue
        fn = scope.enclosing_function(node)
        qn = scope.enclosing_qualname(node)
        if is_emit:
            # inside a registered helper, the internal emit is exempt (the
            # guard lives at the call sites, which are checked below)
            if (module, qn) in registry.TRACE_HELPERS:
                matched_helpers.add((module, qn))
            elif not _guarded(node, scope, _guard_names(fn)):
                out.append(Violation(
                    path, node.lineno, "tracer",
                    f"unguarded tracer.emit in {qn or '<module>'} — "
                    "hot-path emits must sit under `if tracer.enabled:` "
                    "or `if tracer.full:` (NULL_TRACER still pays argument "
                    "construction without the guard)"))
            # literal event kinds must be registered
            kind = node.args[1] if len(node.args) > 1 else None
            if isinstance(kind, ast.Constant) and isinstance(kind.value, str) \
                    and kind.value not in EVENT_KINDS:
                out.append(Violation(
                    path, kind.lineno, "tracer",
                    f"event kind {kind.value!r} not in EVENT_KINDS "
                    f"{EVENT_KINDS} — trace consumers (export, timeline, "
                    "trace_report --strict) reject unknown kinds"))
        else:
            # a call to a registered unguarded helper needs the same guard
            if qn == helper_key[1]:
                continue       # the helper calling itself
            if not _guarded(node, scope, _guard_names(fn)):
                out.append(Violation(
                    path, node.lineno, "tracer",
                    f"call to trace helper {attr}() in "
                    f"{qn or '<module>'} without an enabled/full guard — "
                    f"{helper_key[1]} emits unconditionally by design "
                    "(registered in TRACE_HELPERS); its call sites carry "
                    "the guard"))


# --------------------------------------------------------------------------- #
# check 4: frozen spec discipline
# --------------------------------------------------------------------------- #

_SPEC_CLASSES_CACHE: Optional[Set[str]] = None


def spec_class_names() -> Set[str]:
    """Frozen-dataclass class names parsed from ``repro/api/spec.py``'s AST
    (no import needed — works on fixture trees too)."""
    global _SPEC_CLASSES_CACHE
    if _SPEC_CLASSES_CACHE is not None:
        return _SPEC_CLASSES_CACHE
    import repro.api.spec as spec_mod
    with open(spec_mod.__file__, encoding="utf-8") as f:
        tree = ast.parse(f.read())
    names: Set[str] = set()
    for node in tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) \
                    and _attr_path(dec.func).endswith("dataclass") \
                    and any(kw.arg == "frozen"
                            and isinstance(kw.value, ast.Constant)
                            and kw.value.value is True
                            for kw in dec.keywords):
                names.add(node.name)
    _SPEC_CLASSES_CACHE = names
    return names


def check_frozenspec(path: str, module: str, tree: ast.Module, scope: _Scope,
                     out: List[Violation]) -> None:
    if not module.startswith("repro."):
        return
    specs = spec_class_names()
    for node in ast.walk(tree):
        # rule (a): object.__setattr__ only inside __post_init__
        if isinstance(node, ast.Call) \
                and _attr_path(node.func) == "object.__setattr__":
            qn = scope.enclosing_qualname(node)
            if not qn.split(".")[-1] == "__post_init__":
                out.append(Violation(
                    path, node.lineno, "frozenspec",
                    "object.__setattr__ outside __post_init__ — frozen "
                    "specs are immutable after validation; use "
                    "dataclasses.replace to derive a new spec"))
        # rule (b): attr assignment on a var bound to a spec constructor
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name == "__post_init__":
                continue
            spec_vars: Set[str] = set()
            for stmt in ast.walk(node):
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name) \
                        and isinstance(stmt.value, ast.Call):
                    fpath = _attr_path(stmt.value.func)
                    head = fpath.split(".")[0]
                    tail = fpath.split(".")[-1]
                    if head in specs or (tail in ("from_dict", "load")
                                         and head in specs):
                        spec_vars.add(stmt.targets[0].id)
            if not spec_vars:
                continue
            for stmt in ast.walk(node):
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        if isinstance(t, ast.Attribute) \
                                and isinstance(t.value, ast.Name) \
                                and t.value.id in spec_vars:
                            out.append(Violation(
                                path, stmt.lineno, "frozenspec",
                                f"attribute assignment on spec instance "
                                f"{t.value.id!r} — specs are frozen; use "
                                "dataclasses.replace"))


# --------------------------------------------------------------------------- #
# check 5: source-of-truth docstrings
# --------------------------------------------------------------------------- #

def check_docstring(path: str, module: str, tree: ast.Module,
                    out: List[Violation]) -> None:
    if not module.startswith(DOCSTRING_SCOPE):
        return
    if os.path.basename(path) == "__init__.py":
        # package __init__ re-exports; the per-concern lines live in modules
        return
    doc = ast.get_docstring(tree) or ""
    if not any(tok in doc.lower() for tok in DOCSTRING_TOKENS):
        out.append(Violation(
            path, 1, "docstring",
            f"module {module} lacks its latency-number-ownership line — "
            "subsystem modules must declare what they are the "
            "'Source of truth' for (docs/architecture.md, PR-4 convention)"))


# --------------------------------------------------------------------------- #
# driver
# --------------------------------------------------------------------------- #

CHECK_NAMES = ("wallclock", "epoch", "tracer", "frozenspec", "docstring")


def run_checks(paths: Sequence[str],
               checks: Sequence[str] = CHECK_NAMES) -> Report:
    report = Report()
    matched_exemptions: Set[Tuple[str, str, str]] = set()
    matched_helpers: Set[Tuple[str, str]] = set()
    seen_epoch_classes: Set[Tuple[str, str]] = set()
    scanned_modules: Set[str] = set()
    for path in iter_py_files(paths):
        try:
            with open(path, encoding="utf-8") as f:
                tree = ast.parse(f.read(), filename=path)
        except SyntaxError as e:
            report.violations.append(Violation(
                path, e.lineno or 1, "parse", f"syntax error: {e.msg}"))
            continue
        report.files += 1
        module = module_name(path)
        if module:
            scanned_modules.add(module)
        scope = _Scope(tree)
        if "wallclock" in checks:
            check_wallclock(path, module, tree, scope, report.violations,
                            matched_exemptions)
        if "epoch" in checks:
            check_epoch(path, module, tree, scope, report.violations,
                        matched_exemptions, seen_epoch_classes)
        if "tracer" in checks:
            check_tracer(path, module, tree, scope, report.violations,
                         matched_helpers)
        if "frozenspec" in checks:
            check_frozenspec(path, module, tree, scope, report.violations)
        if "docstring" in checks:
            check_docstring(path, module, tree, report.violations)
    # stale-registry warnings: entries that matched nothing in a scan that
    # actually covered their module (fixture scans cover a couple of files —
    # don't report the rest of the registry as stale there)
    for e in registry.ALLOWLIST:
        if e.module in scanned_modules \
                and (e.check, e.module, e.qualname) not in matched_exemptions:
            report.warnings.append(Warning_(
                e.check,
                f"stale ALLOWLIST entry ({e.module}, {e.qualname!r}): "
                f"matched nothing — remove it or fix the qualname "
                f"[reason was: {e.reason}]"))
    for ec in registry.EPOCH_CLASSES:
        if ec.module in scanned_modules \
                and (ec.module, ec.cls) not in seen_epoch_classes:
            report.warnings.append(Warning_(
                "epoch",
                f"EPOCH_CLASSES entry {ec.module}.{ec.cls} not found in "
                "the scanned tree — registry is stale"))
    for (mod, qual), reason in registry.TRACE_HELPERS.items():
        if mod in scanned_modules and (mod, qual) not in matched_helpers:
            report.warnings.append(Warning_(
                "tracer",
                f"TRACE_HELPERS entry {mod}.{qual} matched no emit — "
                f"registry is stale [reason was: {reason}]"))
    return report
