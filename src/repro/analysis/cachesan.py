"""Runtime cache sanitizer ("cachesan") for the epoch-validated fast paths.

Source of truth: shadow-validation of every PR-7 cache against its retained
naive arm — ``_holders_cache`` / the inlined ``assignment_cost`` peer arm
vs ``assignment_cost_ref``, ``peer_source`` vs ``_peer_source_scan``,
``Executor._work_cache`` / ``_groups_cache`` vs the naive queue walk, and
the memoized transfer predictions vs their pure formulas. The static
epoch-discipline check (``repro.analysis.checks``) proves every *registered*
mutation site bumps; cachesan is the dynamic detector for the bug class it
cannot prove absent — an unregistered mutation path serving a stale epoch.

At seeded-random probe points a probed call runs BOTH arms and raises
:class:`CacheDivergence` (with the divergent key, the residency epoch, and
both values) on any mismatch. Between probes the fast path runs untouched,
so a sanitized run still exercises the caches it is validating.

Enable with ``REPRO_CACHE_SANITIZE=1`` (rate via ``REPRO_CACHE_SANITIZE_RATE``,
seed via ``REPRO_CACHE_SANITIZE_SEED``) or per-spec with
``{"observability": {"sanitize": true}}``. Comparisons are exact (``==``,
never ``isclose``): the equivalence contract is bit-identical floats because
the cached arms preserve summation order.
"""
from __future__ import annotations

import os
import random
from typing import Any, Callable, Dict, List, Optional, Tuple

ENV_FLAG = "REPRO_CACHE_SANITIZE"
ENV_RATE = "REPRO_CACHE_SANITIZE_RATE"
ENV_SEED = "REPRO_CACHE_SANITIZE_SEED"
DEFAULT_RATE = 0.25
_TRUTHY = ("1", "true", "yes", "on")


class CacheDivergence(RuntimeError):
    """A cached value disagreed with its naive recompute."""

    def __init__(self, site: str, key: Any, epoch: Optional[int],
                 cached: Any, naive: Any):
        self.site = site
        self.key = key
        self.epoch = epoch
        self.cached = cached
        self.naive = naive
        super().__init__(
            f"cachesan: {site} diverged for key={key!r} at epoch={epoch}: "
            f"cached={cached!r} naive={naive!r} — an epoch-guarded mutation "
            "site is missing its bump (see docs/analysis.md)")


class CacheSanitizer:
    """Installable shadow-validator for one system's caches.

    Probe decisions come from a private seeded ``random.Random`` so a
    sanitized run is itself reproducible; the RNG is never the system's
    (sim semantics see no extra draws). ``install`` is idempotent per
    system and reversible via ``uninstall``.
    """

    def __init__(self, probe_rate: float = DEFAULT_RATE, seed: int = 0):
        if not 0.0 < probe_rate <= 1.0:
            raise ValueError(f"probe_rate must be in (0, 1]: {probe_rate}")
        self.probe_rate = probe_rate
        self.seed = seed
        self._rng = random.Random(seed)
        self._restore: List[Tuple[Any, str, Any]] = []
        self.probes = 0           # probed calls (both arms ran)
        self.calls = 0            # wrapped calls seen

    # ------------------------------------------------------------------ #
    def _probe(self) -> bool:
        self.calls += 1
        if self._rng.random() < self.probe_rate:
            self.probes += 1
            return True
        return False

    def _patch(self, obj: Any, name: str, wrapper: Callable) -> None:
        self._restore.append((obj, name, getattr(obj, name)))
        setattr(obj, name, wrapper)

    # ------------------------------------------------------------------ #
    def install(self, system: Any) -> "CacheSanitizer":
        if getattr(system, "_cachesan", None) is not None:
            return system._cachesan
        h = getattr(system, "hierarchy", None)
        if h is not None:
            self._wrap_hierarchy(h)
            self._wrap_transfer(h.transfer)
        for ex in getattr(system, "executors", ()):
            self._wrap_executor(ex)
        system._cachesan = self
        self._system = system
        return self

    def uninstall(self) -> None:
        for obj, name, orig in reversed(self._restore):
            setattr(obj, name, orig)
        self._restore.clear()
        sys_ = getattr(self, "_system", None)
        if sys_ is not None and getattr(sys_, "_cachesan", None) is self:
            sys_._cachesan = None

    # ------------------------------------------------------------------ #
    def _wrap_hierarchy(self, h: Any) -> None:
        san = self
        cost = h.assignment_cost          # bound originals
        cost_ref = h.assignment_cost_ref
        peer = h.peer_source
        peer_scan = h._peer_source_scan

        def assignment_cost(expert_id, now, group="", device=""):
            out = cost(expert_id, now, group, device)
            if san._probe():
                ref = cost_ref(expert_id, now, group, device)
                if out != ref:
                    raise CacheDivergence(
                        "hierarchy.assignment_cost (_holders_cache)",
                        (expert_id, group, device), h.epoch.n, out, ref)
            return out

        def peer_source(expert_id, dst_group):
            out = peer(expert_id, dst_group)
            if san._probe():
                ref = peer_scan(expert_id, dst_group) \
                    if h.topology.has_peer and dst_group in h.link_groups \
                    else None
                if out != ref:
                    raise CacheDivergence(
                        "hierarchy.peer_source (_holders_cache)",
                        (expert_id, dst_group), h.epoch.n, out, ref)
            return out

        self._patch(h, "assignment_cost", assignment_cost)
        self._patch(h, "peer_source", peer_source)

    def _wrap_transfer(self, t: Any) -> None:
        from repro.memory.transfer import (predicted_load_latency,
                                           predicted_peer_copy_latency)
        san = self
        predict = t.predict
        predict_peer = t.predict_peer

        def predict_w(mem_bytes, in_host_cache):
            out = predict(mem_bytes, in_host_cache)
            if san._probe():
                ref = predicted_load_latency(t.spec, mem_bytes, in_host_cache)
                if out != ref:
                    raise CacheDivergence(
                        "transfer.predict (_pred_memo)",
                        (mem_bytes, in_host_cache), None, out, ref)
            return out

        def predict_peer_w(mem_bytes):
            out = predict_peer(mem_bytes)
            if san._probe():
                ref = predicted_peer_copy_latency(t.spec, mem_bytes)
                if out != ref:
                    raise CacheDivergence(
                        "transfer.predict_peer (_peer_memo)",
                        mem_bytes, None, out, ref)
            return out

        self._patch(t, "predict", predict_w)
        self._patch(t, "predict_peer", predict_peer_w)

    def _wrap_executor(self, ex: Any) -> None:
        san = self
        work = ex.queue_work
        groups = ex.queued_groups

        def queue_work():
            out = work()
            if san._probe():
                # flag-flip recompute: with ``use_pending_cache`` off,
                # ``_residency_epoch()`` is None, so the original method
                # runs its naive loop and stores nothing — side-effect free
                flag = ex.use_pending_cache
                ex.use_pending_cache = False
                try:
                    ref = work()
                finally:
                    ex.use_pending_cache = flag
                if out != ref:
                    epoch = ex._residency_epoch()
                    raise CacheDivergence(
                        f"executor[{ex.id}].queue_work (_work_cache)",
                        ("queue.version", ex.queue.version),
                        epoch.n if epoch is not None else None, out, ref)
            return out

        def queued_groups():
            out = groups()
            if san._probe():
                ref: Dict[str, int] = {}
                for g in ex.queue:
                    ref[g.expert_id] = ref.get(g.expert_id, 0) + 1
                if out != ref:
                    raise CacheDivergence(
                        f"executor[{ex.id}].queued_groups (_groups_cache)",
                        ("queue.version", getattr(ex.queue, "version", None)),
                        None, out, ref)
            return out

        self._patch(ex, "queue_work", queue_work)
        self._patch(ex, "queued_groups", queued_groups)


# ---------------------------------------------------------------------- #
# activation hooks
# ---------------------------------------------------------------------- #

def env_enabled() -> bool:
    return os.environ.get(ENV_FLAG, "").strip().lower() in _TRUTHY


def install_from_env(system: Any) -> Optional[CacheSanitizer]:
    """Install on ``system`` iff ``REPRO_CACHE_SANITIZE`` is truthy.
    Called from ``CoServeSystem.__init__`` so every system built anywhere
    (tests, benchmarks, serve CLI) is covered without plumbing."""
    if not env_enabled():
        return None
    rate = float(os.environ.get(ENV_RATE, DEFAULT_RATE))
    seed = int(os.environ.get(ENV_SEED, "0"))
    return CacheSanitizer(probe_rate=rate, seed=seed).install(system)


def sanitizer_self_test(system: Any) -> bool:
    """Inject a stale-epoch fault and verify the sanitizer catches it.

    Corrupts one executor's ``_work_cache`` entry in place (valid queue
    version and epoch, wrong value — exactly what a missed bump produces)
    and asserts the next probed ``queue_work`` raises. Restores the
    system's original methods before returning. True iff the fault was
    detected; False means the sanitizer is NOT protecting this system
    (no epoch-cacheable executor, or detection failed)."""
    if getattr(system, "_cachesan", None) is not None:
        return False            # refuse to displace an active sanitizer
    ex = next((e for e in getattr(system, "executors", ())
               if e._residency_epoch() is not None), None)
    if ex is None:
        return False
    san = CacheSanitizer(probe_rate=1.0, seed=0)
    san.install(system)
    try:
        good = ex.queue_work()             # primes a valid cache entry
        qv, en, _ = ex._work_cache
        ex._work_cache = (qv, en, good + 1.0)
        try:
            ex.queue_work()
        except CacheDivergence:
            return True
        return False
    finally:
        ex._work_cache = (-1, -1, 0.0)
        san.uninstall()
