"""Version-compat shim for the Pallas TPU compiler-params rename.

Newer JAX releases expose ``pltpu.CompilerParams``; 0.4.x releases only have
the ``TPUCompilerParams`` spelling (and future ones may drop it). Kernels
import ``CompilerParams`` from here so they lower on either side of the
rename.
"""
from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:
    CompilerParams = pltpu.TPUCompilerParams
