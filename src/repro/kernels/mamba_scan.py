"""Chunked Mamba-1 selective scan — Pallas TPU kernel.

One program owns a [block_d] slice of the inner channels for one batch row;
the sequence axis is the sequential grid dimension in [block_s] chunks, with
the SSM state h [block_d, N] carried in VMEM scratch across chunks. Within a
chunk the recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t runs as a
``fori_loop`` over timesteps on VMEM-resident tiles (N = 16 keeps the state
tile narrow; block_d is 128-aligned for the VPU lanes).

Inputs are the *pre-projection* streams (x, dt, B, C) so the [S, D, N]
expanded tensors never touch HBM — the kernel materialises them only per
chunk in VMEM, which is the core memory saving of the Mamba scan on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, hout_ref,
                 h_ref, *, block_s, seq_len, n_chunks):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # [bs, bd]
    dt = dt_ref[0].astype(jnp.float32)        # [bs, bd]
    bm = b_ref[0].astype(jnp.float32)         # [bs, N]
    cm = c_ref[0].astype(jnp.float32)         # [bs, N]
    a = a_ref[...].astype(jnp.float32)        # [bd, N]
    d_vec = d_ref[...].astype(jnp.float32)    # [1, bd]

    def step(t, carry):
        h, y = carry
        da = jnp.exp(dt[t][:, None] * a)                  # [bd, N]
        dbx = (dt[t] * x[t])[:, None] * bm[t][None, :]    # [bd, N]
        h = da * h + dbx
        y_t = jnp.sum(h * cm[t][None, :], axis=1)         # [bd]
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t[None], t, axis=0)
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros((block_s, x.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, block_s, step, (h0, y0))
    h_ref[...] = h
    y_ref[0] = (y + x * d_vec).astype(y_ref.dtype)

    @pl.when(sj == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_s", "interpret"))
def mamba_scan(x, dt, b_mat, c_mat, a, d_vec, *, block_d: int = 128,
               block_s: int = 128, interpret: bool = True):
    """x, dt: [B,S,D]; b_mat, c_mat: [B,S,N]; a: [D,N]; d_vec: [D].
    Returns (y [B,S,D], h_final [B,D,N])."""
    bsz, s, d = x.shape
    n = b_mat.shape[-1]
    block_d = min(block_d, d)
    block_s = min(block_s, s)
    nd = pl.cdiv(d, block_d)
    ns = pl.cdiv(s, block_s)
    if nd * block_d != d:
        raise ValueError(f"D={d} must divide into block_d={block_d}")
    s_pad = ns * block_s - s
    if s_pad:
        # zero dt => exp(0*A)=1, dbx=0: padded steps keep the state unchanged
        x = jnp.pad(x, ((0, 0), (0, s_pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, s_pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, s_pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, s_pad), (0, 0)))

    kernel = functools.partial(_scan_kernel, block_s=block_s, seq_len=s,
                               n_chunks=ns)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(bsz, nd, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda bi, di, sj: (bi, sj, di)),
            pl.BlockSpec((1, block_s, block_d), lambda bi, di, sj: (bi, sj, di)),
            pl.BlockSpec((1, block_s, n), lambda bi, di, sj: (bi, sj, 0)),
            pl.BlockSpec((1, block_s, n), lambda bi, di, sj: (bi, sj, 0)),
            pl.BlockSpec((block_d, n), lambda bi, di, sj: (di, 0)),
            pl.BlockSpec((1, block_d), lambda bi, di, sj: (0, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda bi, di, sj: (bi, sj, di)),
            pl.BlockSpec((1, block_d, n), lambda bi, di, sj: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s + s_pad, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b_mat, c_mat, a, d_vec.reshape(1, d))
    return y[:, :s], h_final
