"""Pallas TPU kernels for the compute hot-spots, with pure-jnp oracles.

- flash_attention: prefill/train attention (causal, GQA, sliding window)
- decode_attention: one-token GQA attention vs a ring KV cache
- mamba_scan: chunked selective scan for the SSM/hybrid architectures

Each kernel is a ``pl.pallas_call`` with explicit BlockSpec VMEM tiling,
validated in interpret mode against ``ref.py`` across shape/dtype sweeps.
"""
from repro.kernels.ops import (decode_attention_op, flash_attention_op,
                               mamba_scan_op)

__all__ = ["decode_attention_op", "flash_attention_op", "mamba_scan_op"]
