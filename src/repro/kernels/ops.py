"""Jit'd kernel entry points with backend selection.

On TPU the Pallas kernels lower natively; elsewhere (this CPU container) they
run in ``interpret=True`` mode. ``impl="xla"`` falls back to the pure-jnp
reference (used by the dry-run, where only XLA ops lower for the host
platform). Models call these through ``cfg.attn_impl``.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention_op(q, k, v, *, causal=True, window=0, impl="pallas",
                       block_q=128, block_k=128):
    """q: [B,H,S,D]; k,v: [B,Hkv,T,D]."""
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=_interpret())


def decode_attention_op(q, k_cache, v_cache, pos, *, window=0, impl="pallas",
                        block_k=256):
    """q: [B,H,D]; caches: [B,Hkv,W,D]."""
    if impl == "xla":
        return ref.decode_attention_ref(q, k_cache, v_cache, pos,
                                        window=window)
    return decode_attention(q, k_cache, v_cache, pos, window=window,
                            block_k=block_k, interpret=_interpret())


def mamba_scan_op(x, dt, b_mat, c_mat, a, d_vec, *, impl="pallas",
                  block_d=128, block_s=128):
    """Returns (y [B,S,D], h_final [B,D,N])."""
    if impl == "xla":
        return ref.mamba_scan_ref(x, dt, b_mat, c_mat, a, d_vec)
    return mamba_scan(x, dt, b_mat, c_mat, a, d_vec,
                      block_d=block_d, block_s=block_s,
                      interpret=_interpret())
