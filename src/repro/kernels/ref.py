"""Pure-jnp oracles for every Pallas kernel (independent, naive math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive attention. q: [B,H,S,D]; k,v: [B,Hkv,T,D]; GQA by repetition."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= q_pos + (t - s) >= k_pos          # right-aligned causality
    if window:
        mask &= (q_pos + (t - s) - k_pos) < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, *, window: int = 0):
    """Single-token GQA attention vs a ring cache.

    q: [B,H,D]; caches: [B,Hkv,W,D]; ``pos`` absolute position of the new
    token (cache slot i holds absolute position pos - ((pos - i) mod W))."""
    b, h, d = q.shape
    hkv, w = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    k = jnp.repeat(k_cache, g, axis=1)
    v = jnp.repeat(v_cache, g, axis=1)
    scores = jnp.einsum("bhd,bhwd->bhw", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    slots = jnp.arange(w)
    abs_pos = pos - jnp.mod(pos - slots, w)
    valid = abs_pos >= 0
    if window:
        valid &= (pos - abs_pos) < window
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhw,bhwd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mamba_scan_ref(x, dt, b_mat, c_mat, a, d_vec, h0=None):
    """Naive sequential selective scan.

    x, dt: [B,S,D]; b_mat, c_mat: [B,S,N]; a: [D,N]; d_vec: [D].
    Returns (y [B,S,D], h_final [B,D,N])."""
    bsz, s, d = x.shape
    n = b_mat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)
    af = a.astype(jnp.float32)
    h = jnp.zeros((bsz, d, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[:, :, None] * af[None])            # [B,D,N]
        dbx = (dt_t * x_t)[:, :, None] * b_t[:, None, :]     # [B,D,N]
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h, ys = jax.lax.scan(
        step, h, (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                  bf.swapaxes(0, 1), cf.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + xf * d_vec.astype(jnp.float32)[None, None]
    return y.astype(x.dtype), h
