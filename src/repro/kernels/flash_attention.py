"""Flash attention (causal / GQA / sliding-window) as a Pallas TPU kernel.

Tiling: one program handles a [block_q, head_dim] query tile held in VMEM
while streaming [block_k, head_dim] K/V tiles; online softmax carries
(m, l, acc) in VMEM scratch across the sequential kv-block grid dimension.
Block sizes are MXU-aligned (multiples of 128 on the contracting dims).
Grid: (batch*heads, q_blocks, kv_blocks) — kv is the innermost sequential
loop ("arbitrary" semantics); fully-masked tiles above the causal diagonal
or outside the sliding window are skipped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, block_q, block_k, seq_q, seq_k, causal, window,
               n_kv_blocks):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global positions of this tile (causality is right-aligned for T >= S)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (seq_k - seq_q)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < seq_k
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal or window:
        # skip tiles entirely above the diagonal / outside the window
        first_q = qi * block_q + (seq_k - seq_q)
        last_q = first_q + block_q - 1
        live = (kj * block_k <= last_q) if causal else (kj * block_k < seq_k)
        if window:
            live &= (kj + 1) * block_k - 1 >= first_q - window + 1
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: [B,H,S,D]; k,v: [B,Hkv,T,D] -> [B,H,S,D] (GQA via head grouping)."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(t, block_k)
    scale = d ** -0.5

    # pad to block multiples (zero-fill; padded keys are masked by k_pos)
    s_pad, t_pad = nq * block_q - s, nk * block_k - t
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad), (0, 0)))

    qf = q.reshape(b * h, s + s_pad, d)
    kf = k.reshape(b * hkv, t + t_pad, d)
    vf = v.reshape(b * hkv, t + t_pad, d)

    def kv_index(bh, qi, kj):
        # program bh = bi*H + hi; its kv row is bi*Hkv + hi//g
        return ((bh // h) * hkv + (bh % h) // g, kj, 0)

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=s, seq_k=t, causal=causal, window=window, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s + s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s + s_pad, d)[:, :, :s]
