"""GQA decode attention vs a ring KV cache — Pallas TPU kernel.

Flash-decoding layout: one program per (batch, kv_head) handles that head's
whole query group ([G, D] tile, G = Hq/Hkv) while streaming [block_k, D]
cache tiles along the sequential grid axis; (m, l, acc) carried in VMEM
scratch. Ring-buffer validity (slot i holds absolute position
``pos - ((pos - i) mod W)``) and the sliding window are evaluated per tile
from the scalar ``pos`` carried in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _dec_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale, block_k, width, window, n_kv_blocks):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale                 # [G, D]
    k = k_ref[0].astype(jnp.float32)                         # [bk, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, bk]

    slots = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)                           # [1, bk]
    abs_pos = pos - jnp.mod(pos - slots, width)
    valid = (abs_pos >= 0) & (slots < width)
    if window:
        valid &= (pos - abs_pos) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     block_k: int = 256, interpret: bool = True):
    """q: [B,H,D]; caches: [B,Hkv,W,D]; pos: scalar int32 -> [B,H,D]."""
    b, h, d = q.shape
    hkv, w = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    block_k = min(block_k, w)
    nk = pl.cdiv(w, block_k)
    w_pad = nk * block_k - w
    if w_pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, w_pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, w_pad), (0, 0)))
    scale = d ** -0.5

    qf = q.reshape(b * hkv, g, d)
    kf = k_cache.reshape(b * hkv, w + w_pad, d)
    vf = v_cache.reshape(b * hkv, w + w_pad, d)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(
        _dec_kernel, scale=scale, block_k=block_k, width=w, window=window,
        n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda bh, kj: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, kj: (bh, kj, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, kj: (bh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, kj: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos_arr, qf, kf, vf)
    return out.reshape(b, h, d)
