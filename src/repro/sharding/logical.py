"""Logical axis rules with divisibility fallback.

MaxText-style indirection: model code annotates arrays with *logical* axis
names ("batch", "heads", "mlp", ...); a rule table maps logical names to mesh
axes. Resolution drops any mesh axis that does not evenly divide the dimension
(e.g. 24 attention heads on a 16-way ``model`` axis, or 8 Mixtral experts),
which keeps every (arch x shape x mesh) cell lowerable without per-arch
special cases.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> ordered candidate mesh axes. Earlier axes are applied first;
# each mesh axis may be used at most once per array.
LogicalRules = Mapping[str, Tuple[str, ...]]

# Training: FSDP on "data" (+"pod"), TP on "model", residual-stream sequence
# parallelism on "model" (Megatron-SP style: the carry between blocks is
# [batch/data, seq/model, d]; GSPMD inserts the gather/scatter pairs at the
# projection boundaries where "mlp"/"heads"/"ssm_inner" take over the axis).
TRAIN_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "embed": ("data",),          # FSDP shard of weight d_model dims
    "embed_act": (),             # activation d_model stays replicated
    "seq_q": ("model",),         # residual-stream sequence sharding
    "seq_attn": (),              # attention-internal seq (heads take "model")
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv": ("model",),           # fused q/kv projection output dim
    "mlp": ("model",),
    "moe_mlp": ("model",),
    "experts": ("model",),
    # MoE dispatch groups NEVER take the model axis: a model-sharded group
    # dim competes with the expert-FFN f dim for the same axis, forcing GSPMD
    # to replicate h and all-reduce FULL f32 expert grads (9.2 GiB/layer on
    # mixtral — §Perf iteration B1). Groups shard (pod, data); f shards model
    # (TP), or experts take model under true EP.
    "moe_groups": ("pod", "data"),
    "moe_tokens": (),                  # within-group token dim
    "vocab": ("model",),
    "kv_seq": (),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "conv": (),
    "layers": (),
    "stage": (),
}

# Serving/decode: TP on "model", batch on ("pod","data"); weights replicated
# on the data axis by default (no FSDP gather in the decode loop) — the
# per-arch rule builder re-enables FSDP when a 16-way TP shard exceeds HBM.
# KV caches shard seq on whatever batch leaves free (long-context cells).
SERVE_RULES: LogicalRules = {
    **TRAIN_RULES,
    "embed": (),
    "seq_q": (),
    "kv_seq": ("data", "model"),
}


def rules_for(cfg, mesh: Mesh, mode: str,
              hbm_budget_bytes: float = 8e9) -> LogicalRules:
    """Arch-aware rule table (divisibility quirks + memory-driven FSDP).

    - serve: if a pure-TP (model-axis) bf16 weight shard would exceed
      ``hbm_budget_bytes`` (mixtral-8x22b), weight d_model dims also shard on
      "data" (FSDP-gathered serving).
    """
    rules = dict(TRAIN_RULES if mode == "train" else SERVE_RULES)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axis_sizes.get("model", 1)
    if mode != "train":
        tp_bytes = cfg.param_count() * 2 / model_n
        if tp_bytes > hbm_budget_bytes:
            rules["embed"] = ("data",)
    heads_split = cfg.num_heads and model_n > 1 and cfg.num_heads % model_n
    if heads_split:
        # heads don't divide the model axis (starcoder2/phi4 24H, qwen2 12H
        # on 16): shard attention internals by the q-sequence instead
        # (flash-style row parallelism; KV replicated on model is cheap for
        # small-kv GQA) — §Perf iteration B7
        rules["seq_attn"] = ("model",)
    if mode == "prefill" and not cfg.moe_num_experts \
            and cfg.family != "ssm":
        # §Perf iteration B8: full sequence parallelism for prefill —
        # residual seq-sharded, attention/MLP weights unsharded on the model
        # axis, every matmul local; the per-layer KV all-gather (~tens of
        # MB) replaces the TP reshard pair that dominated these cells
        # (3.1–3.4x on the 24/12-head archs, 2.6x on whisper). Weights
        # replicate when the bf16 model fits a chip, else FSDP on the data
        # axis (B9: per-layer bf16 gather ~400 MB for minitron-8b, far
        # below its TP reshard traffic). SSMs are excluded: the selective
        # scan is sequential along seq and cannot seq-shard.
        rules["seq_q"] = ("model",)
        rules["seq_attn"] = ("model",)
        rules["qkv"] = ()
        rules["mlp"] = ()
        rules["heads"] = ()
        rules["kv_heads"] = ()
        if cfg.param_count() * 2 >= 12e9:
            rules["embed"] = ("data",)      # FSDP-gathered weights (B9)
            rules["vocab"] = ()
    if cfg.moe_num_experts and model_n > 1 \
            and cfg.moe_num_experts % model_n == 0:
        # true expert parallelism: experts own "model", groups own "data"
        rules["moe_groups"] = ("pod", "data")
    return rules


class _RulesState(threading.local):
    def __init__(self):
        self.rules: Optional[LogicalRules] = None
        self.mesh: Optional[Mesh] = None


_STATE = _RulesState()


@contextlib.contextmanager
def use_rules(rules: Optional[LogicalRules], mesh: Optional[Mesh] = None):
    """Activate a logical-rule table (and optionally a mesh) for model code."""
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def current_rules() -> Optional[LogicalRules]:
    return _STATE.rules


def current_mesh() -> Optional[Mesh]:
    if _STATE.mesh is not None:
        return _STATE.mesh
    # fall back to the ambient mesh context if one is installed
    env = jax.sharding.get_abstract_mesh() if hasattr(jax.sharding, "get_abstract_mesh") else None
    return _STATE.mesh or None


def resolve_spec(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: LogicalRules,
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-dividing mesh axes."""
    if len(shape) != len(logical_axes):
        raise ValueError(
            f"shape rank {len(shape)} != logical axes {logical_axes}"
        )
    used: set = set()
    out = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, name in zip(shape, logical_axes):
        if name is None:
            out.append(None)
            continue
        candidates = rules.get(name, ())
        chosen = []
        remaining = dim
        for ax in candidates:
            if ax not in axis_sizes or ax in used:
                continue
            sz = axis_sizes[ax]
            if remaining % sz == 0:
                chosen.append(ax)
                used.add(ax)
                remaining //= sz
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(tuple(chosen))
    # strip trailing Nones for a tidy spec
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def logical_constraint(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names; no-op w/o rules."""
    rules = _STATE.rules
    mesh = _STATE.mesh
    if rules is None or mesh is None:
        return x
    spec = resolve_spec(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
