from repro.sharding.logical import (
    LogicalRules,
    TRAIN_RULES,
    SERVE_RULES,
    resolve_spec,
    logical_constraint,
    use_rules,
    current_rules,
)
from repro.sharding.partition import (
    param_shardings,
    shape_shardings,
    tree_size_bytes,
)

__all__ = [
    "LogicalRules",
    "TRAIN_RULES",
    "SERVE_RULES",
    "resolve_spec",
    "logical_constraint",
    "use_rules",
    "current_rules",
    "param_shardings",
    "shape_shardings",
    "tree_size_bytes",
]
