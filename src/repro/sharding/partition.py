"""Pytree -> NamedSharding resolution and sizing helpers."""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.sharding.logical import LogicalRules, resolve_spec


def param_shardings(abstract_params: Any, param_axes: Any, mesh: Mesh, rules: LogicalRules):
    """Resolve a pytree of logical-axis tuples into NamedShardings.

    ``abstract_params`` supplies shapes (arrays or ShapeDtypeStructs);
    ``param_axes`` is a matching pytree whose leaves are tuples of logical
    axis names (or None) per dimension.
    """

    def _one(p, axes):
        return NamedSharding(mesh, resolve_spec(p.shape, axes, mesh, rules))

    return jax.tree.map(_one, abstract_params, param_axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


def shape_shardings(abstract_tree: Any, axes_tree: Any, mesh: Mesh, rules: LogicalRules):
    """Same as param_shardings; alias used for inputs/caches."""
    return param_shardings(abstract_tree, axes_tree, mesh, rules)


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStructs too)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
    return total
