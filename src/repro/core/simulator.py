"""Event-driven serving simulator (virtual clock).

Drives the CoServeSystem state machine over an arrival stream: ARRIVAL events
run the dependency-aware scheduler; executors interleave LOAD_DONE/EXEC_DONE
events with single-load-channel overlap (prefetch). Chained experts (routing
follow-ups) re-enter as arrivals at completion time. Also supports failure /
elastic-scaling injections for the fault-tolerance tests.

Online extensions (repro.serve): arrivals can come from a lazy *source*
generator instead of a pre-materialized list (one pending SOURCE event at a
time, so unbounded streams cost O(1) heap space), TICK events drive periodic
telemetry/control callbacks, and hooks observe admissions and completions:

  ``admission(sim, req) -> bool``  gate on SOURCE arrivals (False = shed);
  ``on_complete(sim, req, now)``   every finished chain-terminal request;
  ``on_stage(sim, req, expert_id, now)``  every finished batch member,
  including intermediate chain stages (per-expert telemetry).
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, \
    Sequence, Tuple

from repro.core.coe import Request
from repro.core.executor import Executor
from repro.core.serving import CoServeSystem, Metrics

ARRIVAL, EXEC_DONE, LOAD_DONE, INJECT, SOURCE, TICK, DECODE = range(7)


class Simulation:
    def __init__(self, system: CoServeSystem):
        self.system = system
        # token-level decode (PR 9): the system's DecodeRuntime, or None for
        # stage-level simulation (every decode branch below degrades to one
        # ``is None`` check so decode=off stays bit-identical)
        self.decode = getattr(system, "decode", None)
        self.heap: List[Tuple[float, int, int, Any]] = []
        self._seq = itertools.count()
        self.completed: List[Request] = []
        self.now = 0.0
        # --- online hooks (all optional; None = offline behaviour) ------ #
        self._source: Optional[Iterator[Request]] = None
        self.admission: Optional[Callable[["Simulation", Request], bool]] = None
        self.on_complete: Optional[Callable[["Simulation", Request, float],
                                            None]] = None
        self.on_stage: Optional[Callable[["Simulation", Request, str, float],
                                         None]] = None
        self.shed = 0     # count only: retaining Request objects would grow
        #                   without bound on long overloaded streams
        self._work_events = 0     # non-TICK events in the heap: ticks stop
        #                           rescheduling once only ticks remain

    # ------------------------------------------------------------------ #
    def push(self, t: float, kind: int, payload: Any):
        if kind != TICK:
            self._work_events += 1
        heapq.heappush(self.heap, (t, next(self._seq), kind, payload))

    def submit(self, requests: Sequence[Request]):
        for r in requests:
            self.push(r.arrival_time, ARRIVAL, r)

    def inject(self, t: float, fn: Callable[["Simulation"], None]):
        """Schedule a fault/elasticity injection at time t."""
        self.push(t, INJECT, fn)

    # ------------------------------------------------------------------ #
    # online arrival source + periodic ticks
    # ------------------------------------------------------------------ #
    def set_source(self, requests: Iterable[Request]):
        """Feed arrivals lazily from a generator of Requests (monotone
        ``arrival_time``). Only the next arrival is ever materialized."""
        self._source = iter(requests)
        self._pull_source()

    def _pull_source(self):
        if self._source is None:
            return
        try:
            req = next(self._source)
        except StopIteration:
            self._source = None
            return
        self.push(req.arrival_time, SOURCE, req)

    def add_ticker(self, interval: float,
                   fn: Callable[["Simulation", float], None],
                   start: Optional[float] = None):
        """Call ``fn(sim, now)`` every ``interval`` sim-seconds while work
        remains (ticks never keep an otherwise-drained simulation alive)."""
        if interval <= 0.0:
            raise ValueError(f"ticker interval must be positive, "
                             f"got {interval}")  # 0 would re-arm at the same
        #                                          time and stall the clock
        t0 = self.now + interval if start is None else start
        self.push(t0, TICK, (interval, fn))

    # ------------------------------------------------------------------ #
    def run(self) -> Metrics:
        sys = self.system
        t0 = time.perf_counter()
        n_events = 0
        while self.heap:
            t, _, kind, payload = heapq.heappop(self.heap)
            self.now = t
            n_events += 1
            if kind != TICK:
                self._work_events -= 1
            if kind == ARRIVAL:
                ex = sys.assign(payload, t)
                self.kick(ex, t)
            elif kind == SOURCE:
                req = payload
                if self.admission is None or self.admission(self, req):
                    ex = sys.assign(req, t)
                    self.kick(ex, t)
                else:
                    self.shed += 1
                self._pull_source()
            elif kind == TICK:
                interval, fn = payload
                fn(self, t)
                if self._work_events > 0 or self._source is not None:
                    self.push(t + interval, TICK, (interval, fn))
            elif kind == LOAD_DONE:
                ex, eid = payload
                if not ex.alive:
                    continue
                ex.finish_load(eid)
                # the pool is shared: peers waiting on this expert wake too
                # (pool.users is exactly the executors sharing the pool, in
                # construction order — no fleet-wide scan; kick() skips dead)
                for peer in list(ex.pool.users):
                    self.kick(peer, t)
            elif kind == EXEC_DONE:
                ex = payload
                if not ex.alive or ex.current is None:
                    continue
                eid, batch, outputs = ex.finish_batch(t)
                for i, req in enumerate(batch):
                    out = outputs[i] if outputs else None
                    if self.on_stage is not None:
                        self.on_stage(self, req, eid, t)
                    follow = sys.route_followup(req, eid, out)
                    if follow is None:
                        if self.decode is not None:
                            # terminal stage = prefill: the request joins the
                            # executor's continuous decode batch instead of
                            # completing; it finishes at its last token
                            self.decode.admit(ex, req, t)
                        else:
                            self.completed.append(req)
                            if self.on_complete is not None:
                                self.on_complete(self, req, t)
                    else:
                        follow.arrival_time = t
                        self.push(t, ARRIVAL, follow)
                self.kick(ex, t)
                # a finished batch unpins its expert: pool-sharing peers whose
                # pending load was blocked on that pin can now proceed
                for peer in list(ex.pool.users):
                    if peer is not ex:
                        self.kick(peer, t)
                # idle peers may steal from the longest queue (try_steal is a
                # guaranteed no-op with stealing off — skip the fleet scan)
                if sys.policy.work_stealing:
                    for peer in sys.live_executors():
                        if peer is not ex and not peer.queue \
                                and peer.current is None:
                            if sys.try_steal(peer, t):
                                self.kick(peer, t)
            elif kind == DECODE:
                ex = payload
                if not ex.alive:
                    continue   # fail_executor already dropped its members
                for req in self.decode.finish_step(ex, t):
                    req.done_time = t
                    self.completed.append(req)
                    if self.on_complete is not None:
                        self.on_complete(self, req, t)
                self.kick(ex, t)
                # KV offload/release may have freed pool bytes peers' loads
                # were blocked on
                for peer in list(ex.pool.users):
                    if peer is not ex:
                        self.kick(peer, t)
            else:  # INJECT
                payload(self)
        makespan = max((r.done_time or 0.0) for r in self.completed) \
            if self.completed else 0.0
        m = sys.collect_metrics(self.completed, makespan)
        m.events_processed = n_events
        m.wall_s = time.perf_counter() - t0
        return m

    # ------------------------------------------------------------------ #
    def kick(self, ex: Executor, now: float):
        """Advance one executor: start loads and/or the next batch."""
        if not ex.alive:
            return
        self.system.scheduler.reorder_head(ex, now)
        dec = self.decode
        # start executing if the head group's expert is ready (with decode
        # on, prefill is preferred over the next decode step while the
        # continuous batch has room; a full batch or an unready head lets
        # the decode loop run — steps overlap in-flight demand loads)
        if ex.current is None and (dec is None or not dec.stepping(ex)):
            if not ex.queue and self.system.try_steal(ex, now):
                pass
            done = None
            if dec is None or dec.has_room(ex):
                done = ex.start_next_batch(now)
            if done is not None:
                self.push(done, EXEC_DONE, ex)
            else:
                if ex.queue and ex.load_in_flight is None:
                    head = ex.queue[0].expert_id
                    if head not in ex.pool:
                        # demand load: the executor is idle until it lands
                        t_done = ex.start_load(head, now, demand=True)
                        if t_done is not None:
                            self.push(t_done, LOAD_DONE, (ex, head))
                if dec is not None:
                    t_step = dec.start_step(ex, now)
                    if t_step is not None:
                        ex.busy_until = t_step
                        self.push(t_step, DECODE, ex)
        # overlap: prefetch the next missing expert while executing — strict
        # mode never displaces experts that still have queued groups, and a
        # long shared-channel backlog defers the speculation so it cannot
        # queue ahead of peers' imminent demand loads (retried on next kick)
        if ex.prefetch and ex.load_in_flight is None \
                and (ex.current is not None
                     or (dec is not None and dec.stepping(ex))):
            cand = ex.prefetch_candidate()
            if cand is not None and (ex.hierarchy is None
                                     or ex.hierarchy.speculation_ok(
                                         cand, now, ex.link_group,
                                         ex.device)):
                t_done = ex.start_load(cand, now, strict=True)
                if t_done is not None:
                    self.push(t_done, LOAD_DONE, (ex, cand))

    # ------------------------------------------------------------------ #
    def fail_executor_at(self, t: float, index: int):
        def _fail(sim: "Simulation"):
            sys = sim.system
            ex = sys.executors[index]
            if not ex.alive:
                return
            orphans = sys.fail_executor(ex, sim.now)
            for r in orphans:   # at-most-once re-queue of in-flight work
                sim.push(sim.now, ARRIVAL, r)
            # peers may have been waiting on the dead executor's load channel
            for peer in sys.live_executors():
                sim.kick(peer, sim.now)
        self.inject(t, _fail)

    def add_executor_at(self, t: float, spec):
        def _add(sim: "Simulation"):
            sim.system.add_executor(spec)
        self.inject(t, _add)


def run_real(system: CoServeSystem, requests: Sequence[Request]) -> Metrics:
    """Drive the same state machine with the RealEngine in wall-clock time.

    Arrivals are replayed in order (timestamps compressed); executors are
    drained cooperatively in one process. Switch counts match the simulator
    for identical scheduling decisions.
    """
    import time
    t0 = time.perf_counter()
    sim = Simulation(system)
    now = 0.0
    for r in requests:
        r.arrival_time = now
        sim.push(now, ARRIVAL, r)
    metrics = sim.run()
    metrics.makespan = time.perf_counter() - t0
    metrics.throughput = metrics.completed / metrics.makespan \
        if metrics.makespan > 0 else 0.0
    return metrics
