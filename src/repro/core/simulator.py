"""Event-driven serving simulator (virtual clock).

Drives the CoServeSystem state machine over an arrival stream: ARRIVAL events
run the dependency-aware scheduler; executors interleave LOAD_DONE/EXEC_DONE
events with single-load-channel overlap (prefetch). Chained experts (routing
follow-ups) re-enter as arrivals at completion time. Also supports failure /
elastic-scaling injections for the fault-tolerance tests.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.coe import Request
from repro.core.executor import Executor
from repro.core.serving import CoServeSystem, Metrics

ARRIVAL, EXEC_DONE, LOAD_DONE, INJECT = range(4)


class Simulation:
    def __init__(self, system: CoServeSystem):
        self.system = system
        self.heap: List[Tuple[float, int, int, Any]] = []
        self._seq = itertools.count()
        self.completed: List[Request] = []
        self.now = 0.0

    # ------------------------------------------------------------------ #
    def push(self, t: float, kind: int, payload: Any):
        heapq.heappush(self.heap, (t, next(self._seq), kind, payload))

    def submit(self, requests: Sequence[Request]):
        for r in requests:
            self.push(r.arrival_time, ARRIVAL, r)

    def inject(self, t: float, fn: Callable[["Simulation"], None]):
        """Schedule a fault/elasticity injection at time t."""
        self.push(t, INJECT, fn)

    # ------------------------------------------------------------------ #
    def run(self) -> Metrics:
        sys = self.system
        while self.heap:
            t, _, kind, payload = heapq.heappop(self.heap)
            self.now = t
            if kind == ARRIVAL:
                ex = sys.assign(payload, t)
                self.kick(ex, t)
            elif kind == LOAD_DONE:
                ex, eid = payload
                if not ex.alive:
                    continue
                ex.finish_load(eid)
                # the pool is shared: peers waiting on this expert wake too
                for peer in sys.live_executors():
                    if peer.pool is ex.pool:
                        self.kick(peer, t)
            elif kind == EXEC_DONE:
                ex = payload
                if not ex.alive or ex.current is None:
                    continue
                eid, batch, outputs = ex.finish_batch(t)
                for i, req in enumerate(batch):
                    out = outputs[i] if outputs else None
                    follow = sys.route_followup(req, eid, out)
                    if follow is None:
                        self.completed.append(req)
                    else:
                        follow.arrival_time = t
                        self.push(t, ARRIVAL, follow)
                self.kick(ex, t)
                # a finished batch unpins its expert: pool-sharing peers whose
                # pending load was blocked on that pin can now proceed
                for peer in sys.live_executors():
                    if peer is not ex and peer.pool is ex.pool:
                        self.kick(peer, t)
                # idle peers may steal from the longest queue
                for peer in sys.live_executors():
                    if peer is not ex and not peer.queue and peer.current is None:
                        if sys.try_steal(peer, t):
                            self.kick(peer, t)
            else:  # INJECT
                payload(self)
        makespan = max((r.done_time or 0.0) for r in self.completed) \
            if self.completed else 0.0
        return sys.collect_metrics(self.completed, makespan)

    # ------------------------------------------------------------------ #
    def kick(self, ex: Executor, now: float):
        """Advance one executor: start loads and/or the next batch."""
        if not ex.alive:
            return
        self.system.scheduler.reorder_head(ex)
        # start executing if the head group's expert is ready
        if ex.current is None:
            if not ex.queue and self.system.try_steal(ex, now):
                pass
            done = ex.start_next_batch(now)
            if done is not None:
                self.push(done, EXEC_DONE, ex)
            elif ex.queue and ex.load_in_flight is None:
                head = ex.queue[0].expert_id
                if head not in ex.pool:
                    t_done = ex.start_load(head, now)
                    if t_done is not None:
                        self.push(t_done, LOAD_DONE, (ex, head))
        # overlap: prefetch the next missing expert while executing — strict
        # mode never displaces experts that still have queued groups
        if ex.prefetch and ex.current is not None and ex.load_in_flight is None:
            cand = ex.prefetch_candidate()
            if cand is not None:
                t_done = ex.start_load(cand, now, strict=True)
                if t_done is not None:
                    self.push(t_done, LOAD_DONE, (ex, cand))

    # ------------------------------------------------------------------ #
    def fail_executor_at(self, t: float, index: int):
        def _fail(sim: "Simulation"):
            sys = sim.system
            ex = sys.executors[index]
            if not ex.alive:
                return
            orphans = sys.fail_executor(ex, sim.now)
            for r in orphans:   # at-most-once re-queue of in-flight work
                sim.push(sim.now, ARRIVAL, r)
            # peers may have been waiting on the dead executor's load channel
            for peer in sys.live_executors():
                sim.kick(peer, sim.now)
        self.inject(t, _fail)

    def add_executor_at(self, t: float, spec):
        def _add(sim: "Simulation"):
            sim.system.add_executor(spec)
        self.inject(t, _add)


def run_real(system: CoServeSystem, requests: Sequence[Request]) -> Metrics:
    """Drive the same state machine with the RealEngine in wall-clock time.

    Arrivals are replayed in order (timestamps compressed); executors are
    drained cooperatively in one process. Switch counts match the simulator
    for identical scheduling decisions.
    """
    import time
    t0 = time.perf_counter()
    sim = Simulation(system)
    now = 0.0
    for r in requests:
        r.arrival_time = now
        sim.push(now, ARRIVAL, r)
    metrics = sim.run()
    metrics.makespan = time.perf_counter() - t0
    metrics.throughput = metrics.completed / metrics.makespan \
        if metrics.makespan > 0 else 0.0
    return metrics
