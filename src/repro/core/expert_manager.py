"""Dependency-aware expert management (paper §4.3).

The eviction *order* is a pluggable per-tier strategy from
``repro.memory.policies`` (the same registry the host tier uses); this
manager owns the device-pool mechanics around it: how much must be freed,
which experts are protected by queued work, and the two-stage CoServe
semantics documented on ``DependencyProbPolicy``:

  Stage 1 — evict *dependent* experts whose preliminary (upstream) experts
  are not resident: they cannot execute until their upstream loads, so they
  only waste pool memory. Sorted by memory footprint **descending**.
  Stage 2 — if still short, evict by pre-assessed usage probability
  **ascending** (the CoE prior replaces Samba-CoE's LRU history).

``load_cost_fn`` (cost-aware policies) is residency-aware since the fleet
refactor: the executor passes the memory hierarchy's assignment cost, so a
victim's reload price reflects the tier it would come back from (HOST vs
DISK) and the backlog of the specific device link it would ride — the same
number the scheduler scores assignments with.
"""
from __future__ import annotations

from typing import List, Optional, Set

from repro.core.coe import CoEModel
from repro.memory.policies import make_policy
from repro.memory.residency import DevicePool


class ExpertManager:
    def __init__(self, coe: CoEModel, policy: str = "dependency_prob"):
        self.coe = coe
        self.policy = policy
        self.strategy = make_policy(policy)   # raises on unknown names
        # live per-expert assignment counts for the "observed" policy: the
        # owning CoServeSystem shares its expert_load dict (same object, so
        # updates are visible without re-wiring); None = cold start
        self.observed_load = None

    # ------------------------------------------------------------------ #
    def pick_victims(self, pool: DevicePool, incoming_id: str,
                     load_cost_fn=None, protected: Optional[Set[str]] = None,
                     strict: bool = False) -> Optional[List[str]]:
        """Experts to evict so ``incoming_id`` fits; None if impossible.

        ``protected`` marks experts with queued work on this executor:
        with ``strict`` (prefetch path) they are never evicted — a prefetch
        that displaces pending work thrashes (measured: a hot expert reloads
        40+ times); without ``strict`` (demand path) they are only evicted
        after all unprotected candidates are exhausted."""
        need = self.coe.spec(incoming_id).mem_bytes - pool.free_bytes()
        if need <= 0:
            return []
        order = self._eviction_order(pool, incoming_id, load_cost_fn)
        protected = protected or set()
        if protected:
            unprot = [e for e in order if e not in protected]
            order = unprot if strict else unprot + [e for e in order
                                                    if e in protected]
        victims, freed = [], 0
        for eid in order:
            if freed >= need:
                break
            victims.append(eid)
            freed += self.coe.spec(eid).mem_bytes
        if freed < need:
            return None
        return victims

    # ------------------------------------------------------------------ #
    def _eviction_order(self, pool: DevicePool, incoming_id: str,
                        load_cost_fn=None) -> List[str]:
        return self.strategy.order(
            pool.eviction_view(incoming_id, load_cost_fn,
                               observed_load=self.observed_load))

    # ------------------------------------------------------------------ #
    def ensure_loadable(self, pool: DevicePool, expert_id: str,
                        load_cost_fn=None, protected: Optional[Set[str]] = None,
                        strict: bool = False) -> Optional[List[str]]:
        """Evict (mutating the pool) until expert fits; returns evicted ids or
        None if the expert cannot fit (e.g. larger than the whole pool)."""
        if not pool.fits(expert_id):
            return None
        victims = self.pick_victims(pool, expert_id, load_cost_fn,
                                    protected=protected, strict=strict)
        if victims is None:
            return None
        for v in victims:
            pool.remove(v)
        return victims
