"""Dependency-aware expert management (paper §4.3).

Two-stage eviction:
  Stage 1 — evict *dependent* experts whose preliminary (upstream) experts are
  not resident: they cannot execute until their upstream loads, so they only
  waste pool memory. Sorted by memory footprint **descending** (fewest
  evictions that satisfy the requirement).
  Stage 2 — if still short, evict by pre-assessed usage probability
  **ascending** (the CoE prior replaces Samba-CoE's LRU history).

Baseline policies (lru / fifo) and the beyond-paper cost-benefit order
(P(use)·reload_cost/byte) share the same entry point.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Set

from repro.core.coe import CoEModel
from repro.core.memory import ModelPool


class ExpertManager:
    def __init__(self, coe: CoEModel, policy: str = "dependency_prob"):
        if policy not in ("dependency_prob", "lru", "fifo", "prob",
                          "cost_benefit"):
            raise ValueError(f"unknown eviction policy {policy!r}")
        self.coe = coe
        self.policy = policy

    # ------------------------------------------------------------------ #
    def pick_victims(self, pool: ModelPool, incoming_id: str,
                     load_cost_fn=None, protected: Optional[Set[str]] = None,
                     strict: bool = False) -> Optional[List[str]]:
        """Experts to evict so ``incoming_id`` fits; None if impossible.

        ``protected`` marks experts with queued work on this executor:
        with ``strict`` (prefetch path) they are never evicted — a prefetch
        that displaces pending work thrashes (measured: a hot expert reloads
        40+ times); without ``strict`` (demand path) they are only evicted
        after all unprotected candidates are exhausted."""
        need = self.coe.spec(incoming_id).mem_bytes - pool.free_bytes()
        if need <= 0:
            return []
        order = self._eviction_order(pool, incoming_id, load_cost_fn)
        protected = protected or set()
        if protected:
            unprot = [e for e in order if e not in protected]
            order = unprot if strict else unprot + [e for e in order
                                                    if e in protected]
        victims, freed = [], 0
        for eid in order:
            if freed >= need:
                break
            victims.append(eid)
            freed += self.coe.spec(eid).mem_bytes
        if freed < need:
            return None
        return victims

    # ------------------------------------------------------------------ #
    def _eviction_order(self, pool: ModelPool, incoming_id: str,
                        load_cost_fn=None) -> List[str]:
        cands = [e for e in pool.evictable() if e != incoming_id]
        if self.policy == "lru":
            return sorted(cands, key=lambda e: pool.resident[e])
        if self.policy == "fifo":
            return sorted(cands, key=lambda e: pool.resident[e])  # insertion-
            # ordered counters double as FIFO order (no touch() in FIFO mode)
        if self.policy == "prob":
            return sorted(cands, key=lambda e: (self.coe.spec(e).usage_prob, e))
        if self.policy == "cost_benefit":
            def cb(eid):
                s = self.coe.spec(eid)
                reload_cost = load_cost_fn(eid) if load_cost_fn else 1.0
                return (s.usage_prob * reload_cost / max(1, s.mem_bytes), eid)
            return sorted(cands, key=cb)

        # --- CoServe two-stage order (paper Fig. 10) ---
        resident: Set[str] = set(pool.resident) | {incoming_id}
        stage1, rest = [], []
        for eid in cands:
            spec = self.coe.spec(eid)
            # blocked = a downstream expert none of whose preliminary experts
            # is resident: it cannot receive work until one of them loads
            blocked = spec.is_dependent and not any(
                up in resident for up in spec.depends_on)
            (stage1 if blocked else rest).append(eid)
        stage1.sort(key=lambda e: (-self.coe.spec(e).mem_bytes, e))
        rest.sort(key=lambda e: (self.coe.spec(e).usage_prob, e))
        return stage1 + rest

    # ------------------------------------------------------------------ #
    def ensure_loadable(self, pool: ModelPool, expert_id: str,
                        load_cost_fn=None, protected: Optional[Set[str]] = None,
                        strict: bool = False) -> Optional[List[str]]:
        """Evict (mutating the pool) until expert fits; returns evicted ids or
        None if the expert cannot fit (e.g. larger than the whole pool)."""
        if not pool.fits(expert_id):
            return None
        victims = self.pick_victims(pool, expert_id, load_cost_fn,
                                    protected=protected, strict=strict)
        if victims is None:
            return None
        for v in victims:
            pool.remove(v)
        return victims
