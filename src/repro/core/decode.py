"""Token-level continuous batching with paged KV-cache residency (PR 9).

Source of truth: the only owner of decode-phase state — which requests are
mid-generation on which executor, how many KV blocks each holds and on which
tier. The simulator turns a request's terminal stage into prefill (the
existing ``exec`` event) followed by per-step decode events driven from here;
``decode = off`` (``CoServeSystem.decode is None``) leaves every consumer on
its existing stage-level path bit-for-bit.

The memory model mirrors vLLM-style paged attention scaled to CoServe's
regime: KV grows in fixed blocks (``block_tokens * token_bytes``) that
occupy *device* bytes next to expert weights (``DevicePool.kv_bytes``), so
under the paper's 4.5x/8x memory pressure KV and weights genuinely fight
over the same capacity. Two eviction disciplines are benchmarked:

  ``kv_aware``     idle requests' KV blocks offload to host DRAM over the
                   (contended) PCIe link when the pool needs room — for a
                   growing batch or an incoming expert load — and reload
                   before their owner's next step; the reload debt is priced
                   into ``MemoryHierarchy.assignment_cost`` so the scheduler
                   steers new work away from KV-thrashed pools.
  ``weight_only``  KV is pinned on device (the seed's implicit behaviour);
                   only expert weights evict. Device capacity left for
                   weights shrinks as batches grow, so weight reloads ride
                   the slow disk path more often — the contrast
                   ``BENCH_decode.json`` quantifies.

Determinism: token counts are drawn from a per-request hash-seeded stream
(order-independent), step latency is the linear model ``step_b + step_k*n``
(or the real engine's measured kernel time), and every transfer rides the
hierarchy's contended channels — so two runs of one seeded spec produce
identical event streams, the same discipline the tracer pins.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import TYPE_CHECKING, Dict, List, Optional

from repro.obs.tracer import NULL_TRACER

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.coe import Request
    from repro.memory import MemoryHierarchy
    from repro.memory.residency import DevicePool


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    """Token-level decode knobs (``api.spec.DecodeSection`` resolves here).

    ``token_bytes`` is per-token KV across the expert's layers (the
    ``models.kvcache.slot_cache_shape`` footprint); one block holds
    ``block_tokens`` tokens, so the default block is ~4 MiB. ``kv_budget``
    caps KV at a fraction of each pool — eMoE-style task-aware budgeting —
    beyond which fresh blocks spill to host at birth."""
    tokens: int = 24                  # mean generated tokens per request
    tokens_dist: str = "fixed"        # "fixed" | "geometric"
    block_tokens: int = 16            # tokens per paged KV block
    token_bytes: int = 262_144        # KV bytes per token across layers
    kv_budget_fraction: float = 0.5   # max pool fraction KV may occupy
    kv_evict: str = "kv_aware"        # "kv_aware" | "weight_only"
    max_decode_batch: int = 8         # continuous-batch membership cap
    step_k: float = 0.002             # per-member seconds per decode step
    step_b: float = 0.0005            # fixed per-step overhead seconds
    seed: int = 0                     # token-count draw stream


@dataclasses.dataclass
class DecodeState:
    """One mid-generation request: its continuous-batch slot + KV ledger."""
    req: "Request"
    ex_id: str
    group: str                        # device pool the KV lives against
    tokens_total: int
    admit_t: float
    prev_token_t: float
    tokens_done: int = 0
    blocks_device: int = 0
    blocks_host: int = 0              # offloaded or spilled-at-birth
    last_step: int = 0                # recency for idle-victim ordering
    reloads: int = 0


def _pct(sorted_xs: List[float], q: float) -> float:
    """Nearest-rank percentile on a pre-sorted sample (matches
    ``core.serving.nearest_rank`` — duplicated to keep this module free of
    a serving import cycle)."""
    if not sorted_xs:
        return 0.0
    k = max(0, min(len(sorted_xs) - 1, math.ceil(q * len(sorted_xs)) - 1))
    return sorted_xs[k]


def _lat_stats(samples: List[float]) -> dict:
    xs = sorted(samples)
    n = len(xs)
    return {"count": n,
            "mean": (sum(xs) / n) if n else 0.0,
            "p50": _pct(xs, 0.50),
            "p99": _pct(xs, 0.99)}


class DecodeRuntime:
    """Continuous-batch + KV-residency state machine.

    Driven by the simulator loop: ``admit`` when a terminal stage's prefill
    finishes, ``start_step``/``finish_step`` around each DECODE event,
    ``fail_executor`` on fault injection. The executor's weight-load path
    calls ``expert_load_pressure`` so KV yields device bytes to incoming
    experts (kv_aware), and the hierarchy prices ``reload_debt`` into
    assignment costs.
    """

    def __init__(self, cfg: DecodeConfig, hierarchy: "MemoryHierarchy",
                 tracer=None, engine=None):
        self.cfg = cfg
        self.hierarchy = hierarchy
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # real backend hook: an engine exposing ``decode_step`` supplies
        # measured kernel time per step instead of the linear model
        self.engine = engine if hasattr(engine, "decode_step") else None
        self.block_bytes = cfg.block_tokens * cfg.token_bytes
        self.states: Dict[int, DecodeState] = {}      # rid -> state
        self.batch: Dict[str, List[int]] = {}         # ex.id -> member rids
        self._inflight: Dict[str, List[int]] = {}     # ex.id -> stepping rids
        self._host_kv: Dict[str, int] = {}            # group -> host KV bytes
        self._step_seq = 0
        self.hub = None                               # TelemetryHub (optional)
        # counters surfaced in Metrics.decode
        self.tokens_out = 0
        self.kv_offload_events = 0
        self.kv_offload_bytes = 0
        self.kv_reload_events = 0
        self.kv_reload_bytes = 0
        self.kv_spills = 0
        self.peak_kv: Dict[str, int] = {}
        self.ttft_samples: List[float] = []
        self.token_samples: List[float] = []

    # ------------------------------------------------------------------ #
    # membership
    # ------------------------------------------------------------------ #
    def _tokens_for(self, rid: int) -> int:
        """Deterministic, order-independent token-count draw: seeded per
        request so replaying a subset of requests draws identical lengths
        (reference-pinning discipline). String seeding is stable across
        processes — tuple seeding would ride the randomized hash()."""
        cfg = self.cfg
        if cfg.tokens_dist == "fixed":
            return max(1, cfg.tokens)
        u = random.Random(f"{cfg.seed}:{rid}:decode-tokens").random()
        p = 1.0 / max(1.0, float(cfg.tokens))
        # inverse-CDF geometric with mean ~= cfg.tokens
        return max(1, 1 + int(math.log1p(-u) / math.log1p(-p)))

    def has_room(self, ex) -> bool:
        return len(self.batch.get(ex.id, ())) < self.cfg.max_decode_batch

    def stepping(self, ex) -> bool:
        return ex.id in self._inflight

    def active(self) -> int:
        return len(self.states)

    def admit(self, ex, req: "Request", now: float) -> None:
        """Terminal-stage prefill finished: the request joins ``ex``'s
        continuous batch and gets its first KV block."""
        rid = req.id
        st = DecodeState(req=req, ex_id=ex.id, group=ex.pool.group,
                         tokens_total=self._tokens_for(rid),
                         admit_t=now, prev_token_t=now,
                         last_step=self._step_seq)
        self.states[rid] = st
        self.batch.setdefault(ex.id, []).append(rid)
        self._alloc_block(st, now, ex)

    # ------------------------------------------------------------------ #
    # the per-step loop
    # ------------------------------------------------------------------ #
    def start_step(self, ex, now: float) -> Optional[float]:
        """Begin one decode step over ``ex``'s current membership; returns
        its completion time (the simulator's DECODE event) or None when the
        batch is empty. Membership snapshots at step start: joiners wait for
        the next step boundary (continuous batching, not preemption)."""
        members = self.batch.get(ex.id)
        if not members:
            return None
        members = list(members)
        kv_wait = 0.0
        for rid in members:
            w = self._prepare_member(self.states[rid], now, ex)
            if w > kv_wait:
                kv_wait = w
        if self.engine is not None:
            step = self.engine.decode_step(
                ex, [self.states[r] for r in members], now)
        else:
            step = self.cfg.step_b + self.cfg.step_k * len(members)
        dur = kv_wait + step
        self._step_seq += 1
        for rid in members:
            self.states[rid].last_step = self._step_seq
        self._inflight[ex.id] = members
        ex.stats.busy_time += dur
        if self.tracer.full:
            self.tracer.emit(now, "decode", ex.id, "step", dur=dur,
                             requests=members, n=len(members),
                             kv_wait=kv_wait)
        return now + dur

    def finish_step(self, ex, now: float) -> List["Request"]:
        """One token landed for every member; returns requests that just
        generated their last token (the simulator completes them)."""
        members = self._inflight.pop(ex.id, [])
        queue = self.batch.get(ex.id, [])
        finished: List["Request"] = []
        for rid in members:
            st = self.states.get(rid)
            if st is None:
                continue
            st.tokens_done += 1
            self.tokens_out += 1
            if st.tokens_done == 1:
                ttft = now - st.req.e2e_arrival()
                self.ttft_samples.append(ttft)
                if self.hub is not None:
                    self.hub.on_first_token(ttft)
            else:
                lat = now - st.prev_token_t
                self.token_samples.append(lat)
                if self.hub is not None:
                    self.hub.on_token(lat)
            st.prev_token_t = now
            if st.tokens_done >= st.tokens_total:
                self._release(st, now)
                queue.remove(rid)
                del self.states[rid]
                finished.append(st.req)
            elif st.tokens_done % self.cfg.block_tokens == 0:
                self._alloc_block(st, now, ex)
        return finished

    def fail_executor(self, ex) -> List["Request"]:
        """Executor died mid-decode: drop its members' KV (device bytes
        return to the pool, host bytes stop owing reloads) and hand the
        orphaned requests back for re-assignment from scratch."""
        self._inflight.pop(ex.id, None)
        orphans: List["Request"] = []
        for rid in self.batch.pop(ex.id, []):
            st = self.states.pop(rid, None)
            if st is None:
                continue
            self._release(st, 0.0)
            st.req.done_time = 0.0
            orphans.append(st.req)
        return orphans

    # ------------------------------------------------------------------ #
    # KV block lifecycle
    # ------------------------------------------------------------------ #
    def _pool(self, group: str) -> Optional["DevicePool"]:
        return self.hierarchy.pools.get(group)

    def _kv_trace(self, now: float, st: DecodeState, op: str, nbytes: int):
        if self.tracer.enabled:
            self.tracer.emit(now, "kv", st.group, op, request=st.req.id,
                             bytes=nbytes, device_blocks=st.blocks_device,
                             host_blocks=st.blocks_host)

    def _grow_device(self, pool: "DevicePool", st: DecodeState,
                     nbytes: int, blocks: int):
        pool.kv_bytes += nbytes
        st.blocks_device += blocks
        pool.epoch.bump()
        if pool.kv_bytes > self.peak_kv.get(pool.group, 0):
            self.peak_kv[pool.group] = pool.kv_bytes

    def _alloc_block(self, st: DecodeState, now: float, ex) -> None:
        """Grow the request's KV by one block, preferring device residency:
        over-budget pools first offload idle peers (kv_aware), then the
        block spills to host at birth; within budget, expert weights evict
        LRU to make room (both disciplines — weights reload, KV doesn't)."""
        need = self.block_bytes
        pool = self._pool(st.group)
        if pool is None:
            st.blocks_host += 1
            return
        budget = int(pool.capacity * self.cfg.kv_budget_fraction)
        unified = self.hierarchy.spec.unified
        if pool.kv_bytes + need > budget and not unified \
                and self.cfg.kv_evict == "kv_aware":
            self._offload_idle(
                pool, now, keep=st.req.id,
                done=lambda: pool.kv_bytes + need <= budget)
        if pool.kv_bytes + need > budget:
            st.blocks_host += 1
            self.kv_spills += 1
            if not unified:
                self._host_kv[st.group] = \
                    self._host_kv.get(st.group, 0) + need
            self._kv_trace(now, st, "spill", need)
            return
        if need > pool.free_bytes():
            self._evict_weights(pool, need, now, ex)
        if need <= pool.free_bytes():
            self._grow_device(pool, st, need, 1)
            self._kv_trace(now, st, "grow", need)
        else:
            st.blocks_host += 1
            self.kv_spills += 1
            if not unified:
                self._host_kv[st.group] = \
                    self._host_kv.get(st.group, 0) + need
            self._kv_trace(now, st, "spill", need)

    def _prepare_member(self, st: DecodeState, now: float, ex) -> float:
        """Bring a member's host-resident KV back before its step. When the
        pool has room (within budget) the blocks rematerialize on device;
        otherwise they stream — the transfer is paid *every* step but the
        batch always makes progress (no admission deadlock). Returns the
        reload wait this member contributes to the step."""
        if st.blocks_host == 0:
            return 0.0
        if self.hierarchy.spec.unified:
            # UMA: one address space — spilled blocks are already reachable
            return 0.0
        pool = self._pool(st.group)
        nbytes = st.blocks_host * self.block_bytes
        materialize = False
        if pool is not None:
            budget = int(pool.capacity * self.cfg.kv_budget_fraction)
            if pool.kv_bytes + nbytes <= budget:
                if nbytes > pool.free_bytes():
                    self._evict_weights(pool, nbytes, now, ex)
                materialize = nbytes <= pool.free_bytes()
        tr = self.hierarchy.transfer.begin_kv_reload(
            now, nbytes, st.group, label=f"r{st.req.id}")
        self.kv_reload_events += 1
        self.kv_reload_bytes += nbytes
        st.reloads += 1
        if materialize:
            self._grow_device(pool, st, nbytes, st.blocks_host)
            self._host_kv[st.group] = \
                self._host_kv.get(st.group, 0) - nbytes
            st.blocks_host = 0
            self._kv_trace(now, st, "reload", nbytes)
        else:
            self._kv_trace(now, st, "stream", nbytes)
        return max(0.0, tr.done - now)

    def _offload_idle(self, pool: "DevicePool", now: float,
                      keep: int, done) -> None:
        """kv_aware pressure valve: offload whole requests' device KV to
        host DRAM over the PCIe link, least-recently-stepped first, until
        ``done()``. Members of an in-flight step and ``keep`` are skipped
        (their blocks are being read)."""
        busy = {r for mem in self._inflight.values() for r in mem}
        cands = sorted(
            (st for st in self.states.values()
             if st.group == pool.group and st.blocks_device > 0
             and st.req.id != keep and st.req.id not in busy),
            key=lambda s: (s.last_step, s.req.id))
        for st in cands:
            if done():
                return
            nbytes = st.blocks_device * self.block_bytes
            self.hierarchy.transfer.begin_kv_offload(
                now, nbytes, pool.group, label=f"r{st.req.id}")
            pool.kv_bytes -= nbytes
            st.blocks_host += st.blocks_device
            st.blocks_device = 0
            pool.epoch.bump()
            self._host_kv[pool.group] = \
                self._host_kv.get(pool.group, 0) + nbytes
            self.kv_offload_events += 1
            self.kv_offload_bytes += nbytes
            self._kv_trace(now, st, "offload", nbytes)

    def _evict_weights(self, pool: "DevicePool", need: int, now: float,
                       ex) -> None:
        """Evict LRU expert weights until ``need`` device bytes are free —
        used by BOTH disciplines when KV (within budget) wants room:
        weights can always reload from host/disk, KV state cannot be
        recomputed. Experts queued or executing anywhere on the pool are
        protected, same rule as ``Executor.start_load``."""
        protected = set()
        for peer in pool.users:
            protected.update(g.expert_id for g in peer.queue)
            if peer.current is not None:
                protected.add(peer.current[0])
            if peer.load_in_flight is not None:
                protected.add(peer.load_in_flight[0])
        order = sorted(pool.evictable(), key=lambda e: pool.resident[e])
        for victim in order:
            if pool.free_bytes() >= need:
                return
            if victim in protected:
                continue
            pool.remove(victim)
            ex.engine.unload(ex, victim)
            ex.stats.evictions += 1
            if self.tracer.enabled:
                self.tracer.emit(now, "evict", ex.id, victim,
                                 pool=pool.group, by="kv")

    def _release(self, st: DecodeState, now: float) -> None:
        nbytes = st.blocks_device * self.block_bytes
        pool = self._pool(st.group)
        if pool is not None and nbytes:
            pool.kv_bytes -= nbytes
            pool.epoch.bump()
        if st.blocks_host and not self.hierarchy.spec.unified:
            self._host_kv[st.group] = self._host_kv.get(st.group, 0) \
                - st.blocks_host * self.block_bytes
        self._kv_trace(now, st, "release",
                       nbytes + st.blocks_host * self.block_bytes)
        st.blocks_device = 0
        st.blocks_host = 0
        if self.engine is not None:
            release = getattr(self.engine, "decode_release", None)
            if release is not None:
                release(st.req.id)

    def expert_load_pressure(self, ex, expert_id: str, now: float) -> None:
        """An incoming expert load wants device bytes: under kv_aware, idle
        requests' KV yields the room first (PCIe offload) so the load
        displaces as few weights as possible. weight_only does nothing —
        KV stays pinned and weights fight over what's left."""
        if self.cfg.kv_evict != "kv_aware" or self.hierarchy.spec.unified:
            return
        pool = ex.pool
        need = self.hierarchy.coe.spec(expert_id).mem_bytes
        if need <= pool.free_bytes():
            return
        self._offload_idle(pool, now, keep=-1,
                           done=lambda: need <= pool.free_bytes())

    # ------------------------------------------------------------------ #
    # pricing + reporting
    # ------------------------------------------------------------------ #
    def reload_debt(self, group: str, now: float) -> float:
        """Predicted PCIe time to bring ``group``'s offloaded KV back — the
        latency a new assignment behind this pool's continuing batch would
        absorb. Priced with the same host-hit transfer formula expert loads
        use, so the scheduler compares like with like."""
        nbytes = self._host_kv.get(group, 0)
        if nbytes <= 0:
            return 0.0
        return self.hierarchy.transfer.predict(nbytes, in_host_cache=True)

    def attach_telemetry(self, hub) -> None:
        self.hub = hub

    def metrics_snapshot(self) -> dict:
        return {
            "tokens_out": self.tokens_out,
            "active": len(self.states),
            "ttft": _lat_stats(self.ttft_samples),
            "token": _lat_stats(self.token_samples),
            "kv": {"block_bytes": self.block_bytes,
                   "offload_events": self.kv_offload_events,
                   "offload_bytes": self.kv_offload_bytes,
                   "reload_events": self.kv_reload_events,
                   "reload_bytes": self.kv_reload_bytes,
                   "spills": self.kv_spills,
                   "peak_kv_bytes": dict(self.peak_kv)},
        }
