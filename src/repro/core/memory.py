"""Compatibility shim: the memory model now lives in ``repro.memory``.

The tier specs, pools and latency math that used to be defined here were
extracted into the unified tiered-memory subsystem (``repro.memory``:
topology + shared transfer channels + per-tier residency + cross-tier
prefetch). This module keeps the seed's import surface working:

  ``ModelPool``  -> ``repro.memory.DevicePool``
  ``HostCache``  -> ``repro.memory.HostTier``
  ``load_latency(spec, mem_bytes, in_host_cache)``
                 -> ``repro.memory.transfer.predicted_load_latency``
"""
from __future__ import annotations

from repro.memory.residency import DevicePool, HostTier
from repro.memory.tiers import NUMA, TPU_V5E, UMA, Residency, TierSpec
from repro.memory.transfer import predicted_load_latency as load_latency

# seed names
ModelPool = DevicePool
HostCache = HostTier

__all__ = ["ModelPool", "HostCache", "DevicePool", "HostTier", "TierSpec",
           "NUMA", "UMA", "TPU_V5E", "Residency", "load_latency"]
