"""Memory tiers and the per-executor model pool (paper §2.2, §3.3, §4.4).

Tier layout mirrors the paper's NUMA / UMA devices, renamed for the TPU
adaptation (DESIGN.md §2): device HBM <- host DRAM <- disk. The *device pool*
budget is the expert-loading share of device memory; the rest is reserved for
batch (activation/KV) memory — the split the offline profiler optimises.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Set

from repro.core.coe import CoEModel


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Bandwidths in bytes/sec; fixed per-load overhead in seconds."""
    name: str
    disk_bw: float = 530e6           # paper NUMA: MICRON SSD 530 MB/s
    host_to_device_bw: float = 12e9  # PCIe-class host->HBM
    host_overhead: float = 0.010     # framework/layout overhead per load
    disk_overhead: float = 0.005
    unified: bool = False            # UMA: no separate host cache tier
    host_cache_bytes: int = 16 << 30
    device_bytes: int = 12 << 30


NUMA = TierSpec(name="numa", disk_bw=530e6, host_to_device_bw=12e9,
                unified=False, host_cache_bytes=16 << 30, device_bytes=12 << 30)
UMA = TierSpec(name="uma", disk_bw=3000e6, host_to_device_bw=40e9,
               host_overhead=0.030,  # paper: >60% of latency even on UMA
               unified=True, host_cache_bytes=0, device_bytes=24 << 30)
TPU_V5E = TierSpec(name="tpu_v5e", disk_bw=2000e6, host_to_device_bw=16e9,
                   unified=False, host_cache_bytes=128 << 30,
                   device_bytes=16 << 30)


class HostCache:
    """Host-DRAM expert cache shared by a device's executors (NUMA path).

    Eviction is usage-probability-ordered for CoServe and LRU for the
    Samba-CoE baselines (policy injected by the owner).
    """

    def __init__(self, capacity_bytes: int, coe: CoEModel, policy: str = "prob"):
        self.capacity = capacity_bytes
        self.coe = coe
        self.policy = policy
        self.resident: Dict[str, int] = {}   # expert -> last-use counter
        self.used_bytes = 0
        self._clock = 0

    def __contains__(self, expert_id: str) -> bool:
        return expert_id in self.resident

    def touch(self, expert_id: str):
        self._clock += 1
        if expert_id in self.resident:
            self.resident[expert_id] = self._clock

    def insert(self, expert_id: str) -> List[str]:
        """Insert (evicting if needed); returns evicted ids."""
        if self.capacity <= 0:
            return []
        size = self.coe.spec(expert_id).mem_bytes
        evicted = []
        while self.used_bytes + size > self.capacity and self.resident:
            victim = self._pick_victim()
            if victim is None:
                break
            evicted.append(victim)
            self.used_bytes -= self.coe.spec(victim).mem_bytes
            del self.resident[victim]
        if self.used_bytes + size <= self.capacity:
            self._clock += 1
            self.resident[expert_id] = self._clock
            self.used_bytes += size
        return evicted

    def _pick_victim(self) -> Optional[str]:
        if not self.resident:
            return None
        if self.policy == "lru":
            return min(self.resident, key=lambda e: self.resident[e])
        if self.policy == "fifo":
            return next(iter(self.resident))
        # probability-ordered (CoServe): evict lowest P(use)
        return min(self.resident,
                   key=lambda e: (self.coe.spec(e).usage_prob, e))


class ModelPool:
    """Device-memory expert pool (paper §4.1 'model pool').

    One pool per physical memory domain: executors on the same device (the
    paper's 3 GPU executors on one RTX3080Ti) *share* the pool — an expert
    loaded by one executor serves requests from all of them. Pinning is
    therefore counted (several executors may execute the same expert).
    """

    def __init__(self, capacity_bytes: int, coe: CoEModel, group: str = ""):
        self.capacity = capacity_bytes
        self.coe = coe
        self.group = group
        self.resident: Dict[str, int] = {}    # expert -> insertion/use counter
        self.pinned: Dict[str, int] = {}      # expert -> pin count
        self.ready: Set[str] = set()          # transfer complete
        self.loading: Dict[str, float] = {}   # expert -> expected done time
        self.used_bytes = 0
        self._clock = 0

    def __contains__(self, expert_id: str) -> bool:
        return expert_id in self.resident

    def resident_ids(self) -> List[str]:
        return list(self.resident)

    def free_bytes(self) -> int:
        return self.capacity - self.used_bytes

    def fits(self, expert_id: str) -> bool:
        return self.coe.spec(expert_id).mem_bytes <= self.capacity

    def touch(self, expert_id: str):
        self._clock += 1
        if expert_id in self.resident:
            self.resident[expert_id] = self._clock

    def pin(self, expert_id: str):
        self.pinned[expert_id] = self.pinned.get(expert_id, 0) + 1

    def unpin(self, expert_id: str):
        n = self.pinned.get(expert_id, 0) - 1
        if n <= 0:
            self.pinned.pop(expert_id, None)
        else:
            self.pinned[expert_id] = n

    def add(self, expert_id: str):
        size = self.coe.spec(expert_id).mem_bytes
        if size > self.free_bytes():
            raise MemoryError(
                f"pool overflow inserting {expert_id}: {size} > {self.free_bytes()}")
        self._clock += 1
        self.resident[expert_id] = self._clock
        self.used_bytes += size

    def remove(self, expert_id: str):
        if expert_id in self.pinned:
            raise RuntimeError(f"evicting pinned expert {expert_id}")
        self.used_bytes -= self.coe.spec(expert_id).mem_bytes
        self.ready.discard(expert_id)
        del self.resident[expert_id]

    def evictable(self) -> List[str]:
        return [e for e in self.resident
                if e not in self.pinned and e not in self.loading]


def load_latency(spec: TierSpec, mem_bytes: int, in_host_cache: bool) -> float:
    """Expert switch cost from its current tier into device memory."""
    if spec.unified or not in_host_cache:
        return spec.disk_overhead + spec.host_overhead + mem_bytes / spec.disk_bw \
            + (0.0 if spec.unified else mem_bytes / spec.host_to_device_bw)
    return spec.host_overhead + mem_bytes / spec.host_to_device_bw
