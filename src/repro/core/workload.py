"""Paper evaluation workload: circuit-board defect inspection (paper §5.1).

Boards A (352 component types) and B (342): one dedicated classification
expert per component (ResNet101-class), a shared object-detection expert
(YOLOv5m/l-class) for the component types that need alignment verification.
A component image arrives every 4 ms; tasks are 2,500 / 3,500 requests.

Default performance profiles encode the paper's NUMA (RTX3080Ti-class) and
UMA (Apple-M2-class) devices; the real profiler replaces them when measured
numbers are available (``profiler.microbenchmark_arch``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.coe import CoEModel, ExpertSpec, Request, RoutingModule
from repro.core.profiler import ArchProfile, DeviceProfile
from repro.core.serving import ExecutorSpec
from repro.memory import NUMA, UMA, TierSpec
from repro.memory.transfer import predicted_load_latency as load_latency

MB = 1 << 20

# parameter footprints (fp32 serialized, matching the paper's ~60 GB / 300+
# experts: ResNet101 ~44.5M params -> ~178 MB)
ARCH_BYTES = {
    "resnet101": 178 * MB,
    "yolov5m": 85 * MB,
    "yolov5l": 185 * MB,
}

# (K, B) seconds per device kind; CPU is ~8-20x slower (paper Fig. 5)
_EXEC_CONSTANTS = {
    ("resnet101", "gpu"): (0.005, 0.020),
    ("resnet101", "cpu"): (0.055, 0.045),
    ("yolov5m", "gpu"): (0.004, 0.016),
    ("yolov5m", "cpu"): (0.045, 0.040),
    ("yolov5l", "gpu"): (0.007, 0.026),
    ("yolov5l", "cpu"): (0.080, 0.055),
}

# per-item activation bytes (paper §3.3: one ResNet101 batch item costs as
# much memory as ~1.5 experts on the NUMA GPU)
_ACT_BYTES = {
    ("resnet101", "gpu"): 260 * MB,
    ("resnet101", "cpu"): 180 * MB,
    ("yolov5m", "gpu"): 200 * MB,
    ("yolov5m", "cpu"): 140 * MB,
    ("yolov5l", "gpu"): 300 * MB,
    ("yolov5l", "cpu"): 200 * MB,
}

_MAX_BATCH = {"gpu": 8, "cpu": 5}


def _cpu_constants(arch: str, tier: TierSpec,
                   cpu_multiplier: float = 0.0) -> Tuple[float, float]:
    """The CPU service-time line (K, B) for one architecture: derived from
    the device time by ``cpu_multiplier`` when set (the sim-mode hetero
    knob), else the paper's measured CPU constants; NUMA DRAM contention adds
    the same 10% the static table applies."""
    if cpu_multiplier > 0:
        gk, gb = _EXEC_CONSTANTS[(arch, "gpu")]
        k, b = gk * cpu_multiplier, gb * cpu_multiplier
    else:
        k, b = _EXEC_CONSTANTS[(arch, "cpu")]
    if not tier.unified:
        k *= 1.1
    return k, b


def default_arch_profile(arch: str, device: str, tier: TierSpec,
                         cpu_multiplier: float = 0.0) -> ArchProfile:
    k, b = _EXEC_CONSTANTS[(arch, device)]
    mem = ARCH_BYTES[arch]
    cpu_k, cpu_b = _cpu_constants(arch, tier, cpu_multiplier)
    if device == "cpu":
        k, b = cpu_k, cpu_b
    return ArchProfile(
        arch=arch, k=k, b=b, max_batch=_MAX_BATCH[device],
        mem_bytes=mem, act_bytes_per_item=_ACT_BYTES[(arch, device)],
        load_latency_host=load_latency(tier, mem, in_host_cache=True),
        load_latency_disk=load_latency(tier, mem, in_host_cache=False),
        cpu_k=cpu_k, cpu_b=cpu_b,
    )


def device_profile(device: str, tier: TierSpec,
                   cpu_multiplier: float = 0.0) -> DeviceProfile:
    archs = {a: default_arch_profile(a, device, tier, cpu_multiplier)
             for a in ARCH_BYTES}
    return DeviceProfile(device=device, tier=tier, arch_profiles=archs)


# --------------------------------------------------------------------------- #
# CoE model for a circuit board
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class BoardSpec:
    """A circuit-board product: the expert *catalog* covers every component
    type ever used (352/342 dedicated classifiers -> ~60 GB of experts), while
    one concrete board design populates ``n_active`` of them; each board
    instance is scanned component-type by component-type (images of the same
    type are adjacent in the scan), ``avg_quantity`` images per type."""
    name: str
    n_components: int                # catalog size (= #classification experts)
    n_active: int = 120              # component types on this board design
    avg_quantity: float = 3.0        # images per active type per board
    n_detection: int = 24            # shared detection experts
    detection_fraction: float = 0.4  # component types needing verification
    ok_prob: float = 0.95            # classifier outcome triggering detection
    zipf_s: float = 1.1              # skew of per-type quantities


BOARD_A = BoardSpec(name="A", n_components=352)
BOARD_B = BoardSpec(name="B", n_components=342)


def _name_seed(name: str) -> int:
    """Deterministic name hash: ``hash()`` is per-process randomized
    (PYTHONHASHSEED), which silently changed workloads across runs."""
    import zlib
    return zlib.crc32(name.encode()) % 1000


def active_types(board: BoardSpec, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed + _name_seed(board.name))
    return np.sort(rng.choice(board.n_components, board.n_active,
                              replace=False))


def component_distribution(board: BoardSpec, seed: int = 0) -> np.ndarray:
    """Known component-quantity distribution over the catalog (paper §4.5):
    zero off-board, Zipf-skewed quantities across the active types."""
    rng = np.random.RandomState(seed + _name_seed(board.name))
    act = active_types(board, seed)
    ranks = np.arange(1, board.n_active + 1, dtype=np.float64)
    w = ranks ** (-board.zipf_s)
    rng.shuffle(w)
    dist = np.zeros(board.n_components)
    dist[act] = w / w.sum()
    return dist


def board_layout(board: BoardSpec, seed: int = 0):
    """Deterministic component->detection wiring shared by the CoE builder
    and the request generator."""
    rng = np.random.RandomState(seed)
    needs_det = rng.rand(board.n_components) < board.detection_fraction
    det_assign = rng.randint(0, board.n_detection, board.n_components)
    return needs_det, det_assign


def build_board_coe(board: BoardSpec, seed: int = 0) -> CoEModel:
    dist = component_distribution(board, seed)
    needs_det, det_assign = board_layout(board, seed)
    det_arch = ["yolov5m" if i % 2 == 0 else "yolov5l"
                for i in range(board.n_detection)]

    experts: List[ExpertSpec] = []
    chain_prob: Dict[str, Dict[str, float]] = {}
    det_upstream: Dict[int, List[str]] = {i: [] for i in range(board.n_detection)}
    for c in range(board.n_components):
        cid = f"{board.name}_cls{c:03d}"
        deps: Tuple[str, ...] = ()
        if needs_det[c]:
            det_upstream[det_assign[c]].append(cid)
            chain_prob[cid] = {f"{board.name}_det{det_assign[c]:02d}": board.ok_prob}
        experts.append(ExpertSpec(
            id=cid, arch="resnet101", mem_bytes=ARCH_BYTES["resnet101"],
            depends_on=deps))
    for dnum in range(board.n_detection):
        did = f"{board.name}_det{dnum:02d}"
        experts.append(ExpertSpec(
            id=did, arch=det_arch[dnum], mem_bytes=ARCH_BYTES[det_arch[dnum]],
            depends_on=tuple(det_upstream[dnum])))

    def first_expert(data) -> str:
        return f"{board.name}_cls{data['component']:03d}"

    def next_expert(req: Request, eid: str, output) -> Optional[str]:
        d = req.data
        if eid.startswith(f"{board.name}_cls") and d.get("needs_detection") \
                and output == "ok":
            return f"{board.name}_det{d['det_expert']:02d}"
        return None

    routing = RoutingModule(first_expert, next_expert, chain_prob)
    coe = CoEModel(experts, routing)
    # pre-assess usage probabilities from the known component distribution
    # (paper §4.5: predefined routing rules + known quantity distribution)
    coe = coe.assess_usage_probabilities(
        {DistData(c): float(dist[c]) for c in range(board.n_components)})
    return coe


class DistData(dict):
    """Hashable request-data stand-in for probability assessment."""
    def __init__(self, component: int):
        super().__init__(component=component)
        self._c = component

    def __hash__(self):
        return hash(self._c)

    def __eq__(self, other):
        return isinstance(other, DistData) and other._c == self._c


def make_task_requests(board: BoardSpec, n_requests: int,
                       interval: float = 0.004, seed: int = 1,
                       task_id: str = "") -> List[Request]:
    """Paper tasks: continuous stream, one component image every 4 ms.

    The stream is a sequence of *board scans*: per board instance the active
    component types are visited in (shuffled) placement order, with all
    images of one type adjacent, quantities drawn around the known
    distribution. This cyclic sweep is what makes FCFS+LRU thrash (§3.1/3.2)
    while CoServe's arranging merges the same type across queued boards.
    """
    rng = np.random.RandomState(seed)
    dist = component_distribution(board, 0)
    act = active_types(board, 0)
    probs = dist[act]
    needs_det, det_assign = board_layout(board, 0)
    per_board_total = board.n_active * board.avg_quantity

    comps: List[int] = []
    while len(comps) < n_requests:
        order = rng.permutation(act)
        for c in order:
            q = max(1, int(rng.poisson(probs[np.searchsorted(act, c)]
                                       * per_board_total)))
            comps.extend([int(c)] * q)
            if len(comps) >= n_requests:
                break
    comps = comps[:n_requests]

    oks = rng.rand(n_requests) < board.ok_prob
    reqs = []
    for i, (c, ok) in enumerate(zip(comps, oks)):
        reqs.append(Request(
            id=i, expert_id=f"{board.name}_cls{c:03d}",
            arrival_time=i * interval, task_id=task_id or board.name,
            data={"component": int(c), "outcome": "ok" if ok else "defect",
                  "needs_detection": bool(needs_det[c]),
                  "det_expert": int(det_assign[c])}))
    return reqs


# --------------------------------------------------------------------------- #
# executor/pool builders
# --------------------------------------------------------------------------- #

def make_executor_specs(tier: TierSpec, n_gpu: int, n_cpu: int,
                        pool_fraction: float = 0.75,
                        gpu_pool_bytes: Optional[int] = None,
                        cpu_multiplier: float = 0.0
                        ) -> Tuple[Dict[str, int], List[ExecutorSpec]]:
    """Build (pools, executor specs) for a device.

    Executors on the same physical device share one expert pool (the paper's
    multi-executor single-GPU setup); device memory is split pool/batch by
    ``pool_fraction`` (CoServe-Casual default 75/25), with the batch region
    divided between that device's executors. ``gpu_pool_bytes`` overrides the
    accelerator pool size (CoServe-Best: set from the decay-window search).
    ``cpu_multiplier`` > 0 derives the CPU service-time model from the
    device time instead of the static constants (``hetero.cpu_multiplier``).
    """
    pools: Dict[str, int] = {}
    specs: List[ExecutorSpec] = []
    gpu_prof = device_profile("gpu", tier, cpu_multiplier)
    cpu_prof = device_profile("cpu", tier, cpu_multiplier)

    if tier.unified:
        gpu_region = tier.device_bytes * n_gpu // max(1, n_gpu + n_cpu)
        cpu_region = tier.device_bytes - gpu_region
    else:
        gpu_region = tier.device_bytes
        cpu_region = tier.host_cache_bytes // 2   # CPU executors run from DRAM

    if n_gpu:
        pool = gpu_pool_bytes if gpu_pool_bytes is not None \
            else int(gpu_region * pool_fraction)
        pools["gpu"] = pool
        batch_each = (gpu_region - pool) // n_gpu
        for _ in range(n_gpu):
            specs.append(ExecutorSpec("gpu", gpu_prof, batch_each, "gpu"))
    if n_cpu:
        pool = int(cpu_region * pool_fraction)
        pools["cpu"] = pool
        batch_each = (cpu_region - pool) // n_cpu
        for _ in range(n_cpu):
            specs.append(ExecutorSpec("cpu", cpu_prof, batch_each, "cpu"))
    return pools, specs
