"""Execution engines behind the executor state machine.

``SimEngine`` — latencies from offline profiles + the unified memory
hierarchy (``repro.memory``); drives the event-driven simulator at the
paper's scale (hundreds of experts) on this CPU-only box. Every transfer it
performs occupies the hierarchy's *shared* SSD/PCIe channels, so concurrent
loads contend instead of each pretending it owns the link.

``RealEngine`` — actually loads JAX expert params across host/disk tiers and
runs jitted forwards, measuring wall time. Loads queue on real transfer
threads that mirror the tier topology: one thread per transfer channel
(one shared thread in ``links="shared"`` mode — the machine has one storage
link — or one per device pool in ``links="per-device"`` mode), so prefetch
genuinely overlaps host I/O with device compute and concurrent loads
serialize exactly where the simulated channels would. Scheduler and
expert-manager behaviour (and therefore switch counts) are
engine-independent.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.coe import CoEModel, Request
from repro.memory import MemoryHierarchy, TierSpec


class SimEngine:
    """Profiled-latency engine (paper-scale simulation)."""

    def __init__(self, coe: CoEModel, tier: Optional[TierSpec],
                 hierarchy: Optional[MemoryHierarchy] = None):
        self.coe = coe
        self.tier = tier
        # standalone construction (tests, notebooks): derive a hierarchy so
        # the latency model and channels always exist
        self.hierarchy = hierarchy if hierarchy is not None \
            else MemoryHierarchy(coe, tier, pools={})

    # --- latency model (uncontended predictions) ------------------------ #
    def load_latency(self, ex, expert_id: str) -> float:
        if ex is not None and ex.device in ("host", "cpu"):
            h = self.hierarchy
            if h.host_exec_enabled and h.in_host(expert_id):
                return 0.0             # host co-execution: runs in place
            return h.predict_host_load(expert_id)
        group = ex.link_group if ex is not None else ""
        return self.hierarchy.predict_device_load(expert_id, group)

    def exec_latency(self, ex, expert_id: str, n: int) -> float:
        prof = ex.profile(self.coe.spec(expert_id).arch)
        return prof.exec_latency(n)

    # --- side effects --------------------------------------------------- #
    def load(self, ex, expert_id: str, now: float = 0.0) -> float:
        """Begin the transfer on the contended channels; returns the latency
        the executor observes (queueing wait + service legs). The PCIe leg
        rides the executor's own device link in per-device mode."""
        if ex is not None and ex.device in ("host", "cpu"):
            tr = self.hierarchy.begin_host_load(expert_id, now)
        else:
            group = ex.link_group if ex is not None else ""
            tr = self.hierarchy.begin_device_load(expert_id, now, group=group)
        return tr.latency

    def unload(self, ex, expert_id: str) -> None:
        if ex is not None and ex.device in ("host", "cpu"):
            return                      # CPU pool lives in DRAM already
        self.hierarchy.note_evicted(expert_id)

    def execute(self, ex, expert_id: str, batch: List[Request]
                ) -> Tuple[Optional[list], float]:
        # outcome is carried by the synthetic request payload (drives routing)
        outputs = [None if r.data is None else r.data.get("outcome")
                   for r in batch]
        return outputs, self.exec_latency(ex, expert_id, len(batch))


class RingKVCache:
    """One request's ring KV cache for the real decode path.

    Host-side numpy rings in the heads-major layout ``slot_cache_shape``
    emits ([Hkv, W, D]); ``append`` writes slot ``pos % width`` (the ring
    update), ``attend`` runs the Pallas ``decode_attention`` kernel over
    the ring (interpret mode on this CPU-only box). Positions past
    ``width`` overwrite the oldest slot — the kernel's validity mask
    reconstructs absolute positions from the scalar ``pos``.
    """

    def __init__(self, num_heads: int = 4, num_kv_heads: int = 2,
                 head_dim: int = 64, width: int = 64,
                 dtype: str = "float32", window: int = 0):
        self.num_heads = num_heads
        self.num_kv_heads = num_kv_heads
        self.head_dim = head_dim
        self.width = width
        self.window = window
        self.dtype = np.dtype(dtype) if dtype != "bfloat16" else dtype
        shape = (num_kv_heads, width, head_dim)
        if dtype == "bfloat16":
            import jax.numpy as jnp
            self.k = np.zeros(shape, jnp.bfloat16.dtype)
            self.v = np.zeros(shape, jnp.bfloat16.dtype)
        else:
            self.k = np.zeros(shape, self.dtype)
            self.v = np.zeros(shape, self.dtype)
        self.pos = -1                   # last written absolute position

    def append(self, k: np.ndarray, v: np.ndarray) -> int:
        """Write this step's [Hkv, D] k/v at the next ring slot; returns
        the absolute position written."""
        self.pos += 1
        slot = self.pos % self.width
        self.k[:, slot, :] = k.astype(self.k.dtype)
        self.v[:, slot, :] = v.astype(self.v.dtype)
        return self.pos

    def attend(self, q: np.ndarray):
        """[H, D] query against the ring -> [H, D] output (B=1 kernel
        call; members of one continuous batch have different ``pos`` so
        they cannot share a batched call)."""
        import jax.numpy as jnp

        from repro.kernels.decode_attention import decode_attention
        out = decode_attention(
            jnp.asarray(q)[None], jnp.asarray(self.k)[None],
            jnp.asarray(self.v)[None], self.pos,
            window=self.window, interpret=True)
        return np.asarray(out[0])


class HostStore:
    """Host-DRAM + disk parameter store for the real backend.

    Experts start on 'disk' (.npz files) or in host memory; loads into an
    executor deserialize + ``jax.device_put`` the pytree — the real analogue
    of the paper's SSD -> DRAM -> GPU expert switching.
    """

    def __init__(self, root: Optional[str] = None):
        self.host: Dict[str, Any] = {}
        self.disk: Dict[str, str] = {}
        self.root = root

    def put_host(self, expert_id: str, params: Any):
        self.host[expert_id] = params

    def put_disk(self, expert_id: str, params: Any):
        import jax
        assert self.root, "HostStore needs a root dir for disk tier"
        os.makedirs(self.root, exist_ok=True)
        path = os.path.join(self.root, f"{expert_id}.npz")
        leaves, treedef = jax.tree.flatten(params)
        np.savez(path, *[np.asarray(l) for l in leaves])
        self.disk[expert_id] = path
        self._treedefs = getattr(self, "_treedefs", {})
        self._treedefs[expert_id] = treedef

    def fetch(self, expert_id: str) -> Tuple[Any, str]:
        """Returns (host-side params, source tier)."""
        import jax
        if expert_id in self.host:
            return self.host[expert_id], "host"
        path = self.disk[expert_id]
        with np.load(path) as z:
            leaves = [z[k] for k in z.files]
        params = jax.tree.unflatten(self._treedefs[expert_id], leaves)
        self.host[expert_id] = params          # disk read populates host cache
        return params, "disk"


class _TransferWorker:
    """The real backend's single transfer channel: one daemon thread that
    performs fetch + device_put jobs FIFO. Concurrent loads from different
    executors serialize here — the real-hardware analogue of the simulator's
    contended ``TransferChannel``."""

    def __init__(self):
        self._q: "queue.Queue" = queue.Queue()
        self._thread: Optional[threading.Thread] = None

    def _ensure_started(self):
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="coserve-transfer")
            self._thread.start()

    def _run(self):
        while True:
            fn, done = self._q.get()
            try:
                fn()
            except BaseException as e:  # surfaced by wait()
                done["error"] = e
            finally:
                done["event"].set()
                self._q.task_done()

    def submit(self, fn) -> dict:
        self._ensure_started()
        done = {"event": threading.Event(), "error": None}
        self._q.put((fn, done))
        return done

    @staticmethod
    def wait(handle: dict):
        handle["event"].wait()
        if handle["error"] is not None:
            raise handle["error"]


class RealEngine:
    """Runs real JAX experts; latencies are measured wall time.

    ``apply_fns[arch]``: jitted fn (params, batch_array) -> outputs. Expert
    payloads supply ``make_batch(requests) -> array`` and
    ``interpret(outputs) -> list`` hooks via the CoE expert payload dict.

    Transfers ride per-channel transfer threads: ``load()`` enqueues on the
    thread of the link the executor's pool uses (``bind_topology`` maps pool
    group -> channel; unbound or shared-link mode keeps the seed's single
    thread) and returns the *predicted* latency (so scheduling stays
    deterministic), and the executor's ``finish_load`` blocks until the
    transfer really completed. ``measured_load_time`` accumulates the wall
    time the workers actually spent moving timed (post-init) loads; it is
    surfaced in ``Metrics.memory['real_measured_load_s']``.
    """

    def __init__(self, coe: CoEModel, store: HostStore, apply_fns: Dict[str, Any]):
        self.coe = coe
        self.store = store
        self.apply_fns = apply_fns
        self.device_params: Dict[str, Any] = {}
        self._workers: Dict[str, _TransferWorker] = {}
        self._topology = None
        self._hierarchy = None
        self._pending: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self.measured_load_time = 0.0
        # heterogeneous CPU co-execution (policy.host_exec): host/CPU
        # executors run host-resident experts straight from the DRAM store —
        # no transfer thread, no deserialization round-trip
        self.host_exec_enabled = False
        # token-level decode (PR 9): one ring KV cache per mid-generation
        # request, driving the Pallas decode_attention kernel per step.
        # ``decode_attn`` overrides the cache geometry (heads/width/dtype).
        self.decode_caches: Dict[int, RingKVCache] = {}
        self.decode_attn: Dict[str, Any] = {}

    # --- topology binding (one transfer thread per transfer channel) ---- #
    def bind_topology(self, topology, hierarchy=None) -> None:
        """Mirror the tier topology's channels: each PCIe channel, peer
        ingress link (or the SSD link on unified tiers) gets its own FIFO
        transfer thread, so the real backend serializes loads exactly where
        the simulator's contended channels would. ``hierarchy`` (when given)
        lets loads of experts already resident on a sibling pool ride that
        pool's peer channel thread. Called by ``CoServeSystem``."""
        self._topology = topology
        self._hierarchy = hierarchy

    def _channel_name(self, ex, expert_id: str = "") -> str:
        if self._topology is None or ex is None:
            return ""                  # unbound: the seed's single thread
        t = self._topology
        if t.spec.unified or getattr(ex, "device", "") in ("host", "cpu"):
            # one storage link carries the load (host/CPU executors load
            # disk -> DRAM and never own a PCIe channel)
            return t.disk_channel.name
        if expert_id and self._hierarchy is not None \
                and self._hierarchy.peer_source(expert_id,
                                                ex.link_group) is not None:
            return t.peer_for(ex.link_group).name
        return t.pcie_for(ex.link_group).name

    def _worker_for(self, name: str) -> _TransferWorker:
        with self._lock:
            worker = self._workers.get(name)
            if worker is None:
                worker = self._workers[name] = _TransferWorker()
            return worker

    def _host_exec_hit(self, ex, expert_id: str) -> bool:
        return (self.host_exec_enabled and ex is not None
                and getattr(ex, "device", "") in ("host", "cpu")
                and expert_id in self.store.host)

    def load_latency(self, ex, expert_id: str) -> float:
        # prediction for scheduling: profiled value (derived from the
        # TransferEngine formula at profiling time)
        if self._host_exec_hit(ex, expert_id):
            return 0.0                 # host co-execution: runs in place
        spec = self.coe.spec(expert_id)
        prof = ex.profile(spec.arch)
        return prof.load_latency_host if expert_id in self.store.host \
            else prof.load_latency_disk

    def exec_latency(self, ex, expert_id: str, n: int) -> float:
        prof = ex.profile(self.coe.spec(expert_id).arch)
        return prof.exec_latency(n)

    # ------------------------------------------------------------------ #
    def _transfer(self, expert_id: str, timed: bool = True):
        import jax
        t0 = time.perf_counter()
        host_params, _ = self.store.fetch(expert_id)
        dev = jax.tree.map(lambda a: jax.device_put(np.asarray(a)), host_params)
        jax.block_until_ready(jax.tree.leaves(dev))
        with self._lock:
            self.device_params[expert_id] = dev
            if timed:
                self.measured_load_time += time.perf_counter() - t0

    def load(self, ex, expert_id: str, now: float = 0.0) -> float:
        if self._host_exec_hit(ex, expert_id):
            # execute in place on the CPU: the host-store params ARE the
            # executable params — no worker round-trip, nothing pending
            with self._lock:
                self.device_params[expert_id] = self.store.host[expert_id]
            return 0.0
        worker = self._worker_for(self._channel_name(ex, expert_id))
        handle = worker.submit(lambda: self._transfer(expert_id))
        with self._lock:
            self._pending[expert_id] = handle
        return self.load_latency(ex, expert_id)

    def wait_load(self, ex, expert_id: str) -> None:
        """Block until the queued transfer landed (executor ``finish_load``)."""
        with self._lock:
            handle = self._pending.pop(expert_id, None)
        if handle is not None:
            _TransferWorker.wait(handle)

    def unload(self, ex, expert_id: str) -> None:
        self.wait_load(ex, expert_id)    # never drop a half-landed transfer
        with self._lock:
            self.device_params.pop(expert_id, None)

    def warm_place(self, pool, expert_id: str) -> None:
        """Initial placement (system-init phase): transfer without timing."""
        self._transfer(expert_id, timed=False)

    # --- token-level decode (PR 9) -------------------------------------- #
    def decode_step(self, ex, states, now: float = 0.0) -> float:
        """Run one decode step for every member of ``ex``'s continuous
        batch: append this step's k/v to each request's ring cache and run
        the Pallas decode kernel against it (B=1 per member — members sit
        at different ring positions). Inputs are hash-seeded per
        (request, position) so replays are deterministic. Returns measured
        wall seconds — the DecodeRuntime's step latency."""
        t0 = time.perf_counter()
        for st in states:
            rid = st.req.id
            cache = self.decode_caches.get(rid)
            if cache is None:
                cache = self.decode_caches[rid] = \
                    RingKVCache(**self.decode_attn)
            rng = np.random.default_rng(abs(hash((rid, cache.pos + 1)))
                                        % (2 ** 32))
            hkv, d = cache.num_kv_heads, cache.head_dim
            cache.append(rng.standard_normal((hkv, d)),
                         rng.standard_normal((hkv, d)))
            q = rng.standard_normal((cache.num_heads, d))
            st.req.result = cache.attend(q)
        return time.perf_counter() - t0

    def decode_release(self, rid: int) -> None:
        """A request finished (or was orphaned): drop its ring cache."""
        self.decode_caches.pop(rid, None)

    def execute(self, ex, expert_id: str, batch: List[Request]
                ) -> Tuple[list, float]:
        import jax
        spec = self.coe.spec(expert_id)
        payload = spec.payload or {}
        t0 = time.perf_counter()
        params = self.device_params[expert_id]
        make_batch = payload["make_batch"]
        interpret = payload.get("interpret", lambda o: list(o))
        x = make_batch(batch)
        # pad the batch dim to a power-of-two bucket: one XLA compile per
        # bucket instead of one per group size (production bucketing)
        n = x.shape[0]
        bucket = 1 << (n - 1).bit_length()
        if bucket != n:
            pad = np.zeros((bucket - n,) + x.shape[1:], x.dtype)
            x = np.concatenate([x, pad], axis=0)
        out = self.apply_fns[spec.arch](params, x)
        out = jax.block_until_ready(out)
        lat = time.perf_counter() - t0
        return interpret(np.asarray(out)[:n]), lat
