"""Execution engines behind the executor state machine.

``SimEngine`` — latencies from offline profiles + tier model; drives the
event-driven simulator at the paper's scale (hundreds of experts) on this
CPU-only box. ``RealEngine`` — actually loads JAX expert params across
host/disk tiers and runs jitted forwards, measuring wall time. Scheduler and
expert-manager behaviour (and therefore switch counts) are engine-independent.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.coe import CoEModel, Request
from repro.core.memory import HostCache, TierSpec


class SimEngine:
    """Profiled-latency engine (paper-scale simulation)."""

    def __init__(self, coe: CoEModel, tier: TierSpec,
                 host_cache: Optional[HostCache] = None):
        self.coe = coe
        self.tier = tier
        self.host_cache = host_cache   # NUMA: evicted experts cached in DRAM

    # --- latency model ------------------------------------------------- #
    def load_latency(self, ex, expert_id: str) -> float:
        spec = self.coe.spec(expert_id)
        t = self.tier
        if ex.device in ("host", "cpu"):
            return t.disk_overhead + spec.mem_bytes / t.disk_bw
        if t.unified or self.host_cache is None or expert_id not in self.host_cache:
            # disk -> (host) -> device
            lat = t.disk_overhead + t.host_overhead + spec.mem_bytes / t.disk_bw
            if not t.unified:
                lat += spec.mem_bytes / t.host_to_device_bw
            return lat
        return t.host_overhead + spec.mem_bytes / t.host_to_device_bw

    def exec_latency(self, ex, expert_id: str, n: int) -> float:
        prof = ex.profile(self.coe.spec(expert_id).arch)
        return prof.exec_latency(n)

    # --- side effects --------------------------------------------------- #
    def load(self, ex, expert_id: str) -> float:
        lat = self.load_latency(ex, expert_id)
        if self.host_cache is not None and ex.device not in ("host", "cpu"):
            # the transfer passes through (and populates) the DRAM cache
            self.host_cache.insert(expert_id)
            self.host_cache.touch(expert_id)
        return lat

    def unload(self, ex, expert_id: str) -> None:
        if self.host_cache is not None and ex.device not in ("host", "cpu"):
            self.host_cache.insert(expert_id)

    def execute(self, ex, expert_id: str, batch: List[Request]
                ) -> Tuple[Optional[list], float]:
        # outcome is carried by the synthetic request payload (drives routing)
        outputs = [None if r.data is None else r.data.get("outcome")
                   for r in batch]
        return outputs, self.exec_latency(ex, expert_id, len(batch))


class HostStore:
    """Host-DRAM + disk parameter store for the real backend.

    Experts start on 'disk' (.npz files) or in host memory; loads into an
    executor deserialize + ``jax.device_put`` the pytree — the real analogue
    of the paper's SSD -> DRAM -> GPU expert switching.
    """

    def __init__(self, root: Optional[str] = None):
        self.host: Dict[str, Any] = {}
        self.disk: Dict[str, str] = {}
        self.root = root

    def put_host(self, expert_id: str, params: Any):
        self.host[expert_id] = params

    def put_disk(self, expert_id: str, params: Any):
        import jax
        assert self.root, "HostStore needs a root dir for disk tier"
        os.makedirs(self.root, exist_ok=True)
        path = os.path.join(self.root, f"{expert_id}.npz")
        leaves, treedef = jax.tree.flatten(params)
        np.savez(path, *[np.asarray(l) for l in leaves])
        self.disk[expert_id] = path
        self._treedefs = getattr(self, "_treedefs", {})
        self._treedefs[expert_id] = treedef

    def fetch(self, expert_id: str) -> Tuple[Any, str]:
        """Returns (host-side params, source tier)."""
        import jax
        if expert_id in self.host:
            return self.host[expert_id], "host"
        path = self.disk[expert_id]
        with np.load(path) as z:
            leaves = [z[k] for k in z.files]
        params = jax.tree.unflatten(self._treedefs[expert_id], leaves)
        self.host[expert_id] = params          # disk read populates host cache
        return params, "disk"


class RealEngine:
    """Runs real JAX experts; latencies are measured wall time.

    ``apply_fns[arch]``: jitted fn (params, batch_array) -> outputs. Expert
    payloads supply ``make_batch(requests) -> array`` and
    ``interpret(outputs) -> list`` hooks via the CoE expert payload dict.
    """

    def __init__(self, coe: CoEModel, store: HostStore, apply_fns: Dict[str, Any]):
        self.coe = coe
        self.store = store
        self.apply_fns = apply_fns
        self.device_params: Dict[str, Any] = {}

    def load_latency(self, ex, expert_id: str) -> float:
        # prediction for scheduling: profiled value
        spec = self.coe.spec(expert_id)
        prof = ex.profile(spec.arch)
        return prof.load_latency_host if expert_id in self.store.host \
            else prof.load_latency_disk

    def exec_latency(self, ex, expert_id: str, n: int) -> float:
        prof = ex.profile(self.coe.spec(expert_id).arch)
        return prof.exec_latency(n)

    def load(self, ex, expert_id: str) -> float:
        import jax
        t0 = time.perf_counter()
        host_params, _ = self.store.fetch(expert_id)
        dev = jax.tree.map(lambda a: jax.device_put(np.asarray(a)), host_params)
        jax.block_until_ready(jax.tree.leaves(dev))
        self.device_params[expert_id] = dev
        return time.perf_counter() - t0

    def unload(self, ex, expert_id: str) -> None:
        self.device_params.pop(expert_id, None)

    def warm_place(self, pool, expert_id: str) -> None:
        """Initial placement (system-init phase): transfer without timing."""
        self.load(None, expert_id)

    def execute(self, ex, expert_id: str, batch: List[Request]
                ) -> Tuple[list, float]:
        import jax
        spec = self.coe.spec(expert_id)
        payload = spec.payload or {}
        t0 = time.perf_counter()
        params = self.device_params[expert_id]
        make_batch = payload["make_batch"]
        interpret = payload.get("interpret", lambda o: list(o))
        x = make_batch(batch)
        # pad the batch dim to a power-of-two bucket: one XLA compile per
        # bucket instead of one per group size (production bucketing)
        n = x.shape[0]
        bucket = 1 << (n - 1).bit_length()
        if bucket != n:
            pad = np.zeros((bucket - n,) + x.shape[1:], x.dtype)
            x = np.concatenate([x, pad], axis=0)
        out = self.apply_fns[spec.arch](params, x)
        out = jax.block_until_ready(out)
        lat = time.perf_counter() - t0
        return interpret(np.asarray(out)[:n]), lat
