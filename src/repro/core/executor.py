"""Inference executors (paper §4.1): queue + shared model pool + exec/load.

An executor owns a request queue (list of same-expert groups) and two
resources: the execution unit and a load channel. The model pool is *shared*
between executors on the same memory domain (the paper's 3 GPU executors on
one 12 GB device): an expert loaded by one executor serves them all. Load of
the next group's expert overlaps execution of the current batch (the paper's
condition (b): "loaded during the processing of a preceding request"). The
transfers themselves ride the memory hierarchy's contended channels — the
shared SSD fan-in plus the executor's device link (``link_group``, its own
PCIe channel in per-device fleets) — so a load's observed latency includes
any queueing behind peers' traffic on exactly those links.
Both the event-driven simulator and the real-JAX backend drive the same
state machine, so switch counts are backend-independent.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.coe import CoEModel, Request
from repro.core.expert_manager import ExpertManager
from repro.core.profiler import ArchProfile, DeviceProfile
from repro.core.scheduler import (Group, bump_queue, max_executable_batch,
                                  split_batch)
from repro.memory import DevicePool, MemoryHierarchy
from repro.obs import NULL_TRACER, Tracer


class TrackedQueue(list):
    """Executor queue (list of Groups) with a version stamp: every
    structural mutation bumps ``version`` so cached per-queue aggregates
    (pending work, queued-expert counts) invalidate even when callers —
    work stealing, fault injection, tests — mutate the list directly.
    Group-size changes (requests joining an existing Group, batch splits)
    don't go through list methods; those two call sites call ``bump()``."""

    __slots__ = ("version",)

    def __init__(self, iterable=()):
        super().__init__(iterable)
        self.version = 0

    def bump(self):
        self.version += 1

    def append(self, x):
        self.version += 1
        super().append(x)

    def insert(self, i, x):
        self.version += 1
        super().insert(i, x)

    def pop(self, i=-1):
        self.version += 1
        return super().pop(i)

    def remove(self, x):
        self.version += 1
        super().remove(x)

    def clear(self):
        self.version += 1
        super().clear()

    def extend(self, it):
        self.version += 1
        super().extend(it)

    def __delitem__(self, i):
        self.version += 1
        super().__delitem__(i)

    def __setitem__(self, i, v):
        self.version += 1
        super().__setitem__(i, v)

    def __iadd__(self, other):
        self.version += 1
        return super().__iadd__(other)


@dataclasses.dataclass
class ExecStats:
    switches: int = 0            # expert loads into the device pool (post-init)
    evictions: int = 0
    completed: int = 0
    busy_time: float = 0.0
    load_time: float = 0.0       # total transfer occupancy (incl. overlapped)
    stall_time: float = 0.0      # demand-load time the executor sat idle for
    mgmt_time: float = 0.0       # wall time spent in eviction decisions


class Executor:
    def __init__(self, ex_id: str, device: str, coe: CoEModel,
                 device_profile: DeviceProfile, pool: DevicePool,
                 batch_bytes: int, manager: ExpertManager, engine,
                 prefetch: bool = True, protect_queued: bool = True,
                 hierarchy: Optional[MemoryHierarchy] = None,
                 tracer: Optional[Tracer] = None):
        self.id = ex_id
        self.device = device                      # "tpu"/"gpu" | "host"/"cpu"
        self.coe = coe
        self.device_profile = device_profile
        self.pool = pool                          # SHARED memory-domain pool
        self.batch_bytes = batch_bytes
        self.manager = manager
        self.engine = engine
        self.prefetch = prefetch
        self.protect_queued = protect_queued
        self.hierarchy = hierarchy                # cross-tier prefetch hook
        self.tracer = tracer or NULL_TRACER       # flight recorder (obs)

        pool.users = getattr(pool, "users", [])
        pool.users.append(self)

        self.queue: TrackedQueue = TrackedQueue()
        self.busy_until: float = 0.0
        self.current: Optional[Tuple[str, List[Request], Any]] = None
        self.load_in_flight: Optional[Tuple[str, float]] = None  # (expert, done)
        self.stats = ExecStats()
        self.alive = True
        # token-level decode (PR 9): CoServeSystem wires the shared
        # DecodeRuntime here when decode is on; None otherwise (and every
        # decode branch below is a single attribute check)
        self.decode = None
        # fast-path caches (PR 7): queue-work seconds validated against
        # (queue version, residency epoch); queued-group counts validated
        # against queue version alone. ``use_pending_cache = False`` restores
        # naive per-call recomputation (the retained reference path).
        self.use_pending_cache = True
        self._work_cache: Tuple[int, int, float] = (-1, -1, 0.0)
        self._groups_cache: Tuple[int, Dict[str, int]] = (-1, {})

    # ------------------------------------------------------------------ #
    # profile / latency helpers
    # ------------------------------------------------------------------ #
    def profile(self, arch: str) -> ArchProfile:
        return self.device_profile.arch_profiles[arch]

    @property
    def link_group(self) -> str:
        """The device-link key this executor's loads ride: its pool group
        (one PCIe channel per pool in per-device fleets; ignored in
        shared-link mode)."""
        return self.pool.group

    def load_latency(self, expert_id: str) -> float:
        return self.engine.load_latency(self, expert_id)

    def exec_latency(self, expert_id: str, n: int) -> float:
        return self.engine.exec_latency(self, expert_id, n)

    def max_batch_for(self, expert_id: str) -> int:
        prof = self.profile(self.coe.spec(expert_id).arch)
        return max_executable_batch(prof, self.batch_bytes)

    # ------------------------------------------------------------------ #
    # pending time (paper §4.2: queue total inference-time prediction)
    # ------------------------------------------------------------------ #
    def pending_time(self, now: float) -> float:
        return max(0.0, self.busy_until - now) + self.queue_work()

    def _residency_epoch(self):
        """The shared residency epoch that covers everything ``queue_work``
        reads beyond the queue itself (pool membership for the seen-set,
        peer/host residency inside ``load_latency``) — or None when caching
        would be unsound: no hierarchy, an engine priced off different state
        (RealEngine reads its own host store), or caching disabled."""
        h = self.hierarchy
        if self.use_pending_cache and h is not None \
                and getattr(self.engine, "hierarchy", None) is h:
            return h.epoch
        return None

    def queue_work(self) -> float:
        """Total inference-time prediction of the queue (paper §4.2): per
        group the linear exec model, plus one load per distinct non-resident
        expert. This is the ``now``-independent part of ``pending_time``,
        cached against (queue version, residency epoch) so the scheduler's
        per-arrival makespan argmin is O(executors), not O(executors x
        queue). The recompute below IS the naive loop — summation order is
        preserved, so cached and uncached values are bit-identical."""
        epoch = self._residency_epoch()
        if epoch is not None:
            qv, en, work = self._work_cache
            if qv == self.queue.version and en == epoch.n:
                return work
        total = 0.0
        seen: Set[str] = set(self.pool.resident)
        for g in self.queue:
            prof = self.profile(self.coe.spec(g.expert_id).arch)
            if g.expert_id not in seen:
                total += self.load_latency(g.expert_id)
                seen.add(g.expert_id)
            total += prof.exec_latency(len(g))
        if epoch is not None:
            self._work_cache = (self.queue.version, epoch.n, total)
        return total

    def queued_groups(self) -> Dict[str, int]:
        """Per-expert queued-group counts, rebuilt lazily on queue mutation —
        the scheduler's O(1) ``queued_same`` probe and ``reorder_head``'s
        queued-expert index."""
        qv, counts = self._groups_cache
        if qv == getattr(self.queue, "version", -2):
            return counts
        counts = {}
        for g in self.queue:
            counts[g.expert_id] = counts.get(g.expert_id, 0) + 1
        if isinstance(self.queue, TrackedQueue):
            self._groups_cache = (self.queue.version, counts)
        return counts

    def queued_requests(self) -> int:
        return sum(len(g) for g in self.queue)

    # ------------------------------------------------------------------ #
    # load path (eviction via the dependency-aware manager)
    # ------------------------------------------------------------------ #
    def start_load(self, expert_id: str, now: float,
                   strict: bool = False, demand: bool = False
                   ) -> Optional[float]:
        """Begin transferring an expert; returns completion time or None if it
        cannot start (un-evictable residents or busy load channel). ``strict``
        (prefetch path) refuses to displace experts with queued work;
        ``demand`` marks a load the executor is idle-waiting on (stall)."""
        if self.load_in_flight is not None or expert_id in self.pool:
            return None
        if self.decode is not None:
            # kv_aware: idle requests' KV blocks yield device bytes to the
            # incoming expert before any weight eviction is considered
            self.decode.expert_load_pressure(self, expert_id, now)
        t0 = _time.perf_counter()
        protected: Set[str] = set()
        if self.protect_queued or strict:
            # protect experts referenced by ANY executor sharing this pool —
            # evicting a peer's queued expert ping-pongs loads across streams
            for peer in getattr(self.pool, "users", [self]):
                protected.update(g.expert_id for g in peer.queue)
                if peer.current is not None:
                    protected.add(peer.current[0])
            protected.discard(expert_id)
        if self.hierarchy is not None:
            # cost-aware eviction ranks victims by their *residency-aware*
            # reload price (HOST replicas are cheap to bring back, DISK-only
            # experts on a backlogged link are not) — the same
            # contended-channel cost the scheduler scores assignments with
            def cost_fn(eid, _now=now):
                return self.hierarchy.assignment_cost(
                    eid, _now, group=self.link_group, device=self.device)
        else:
            cost_fn = self.load_latency
        victims = self.manager.ensure_loadable(
            self.pool, expert_id, load_cost_fn=cost_fn,
            protected=protected, strict=strict)
        self.stats.mgmt_time += _time.perf_counter() - t0
        if victims is None:
            if not self.pool.fits(expert_id):
                raise MemoryError(
                    f"expert {expert_id} larger than pool {self.pool.group}")
            return None  # everything evictable is pinned/loading; retry later
        tracer = self.tracer
        for v in victims:
            self.engine.unload(self, v)
            self.stats.evictions += 1
            if tracer.enabled:
                tracer.emit(now, "evict", self.id, v, pool=self.pool.group)
        if tracer.enabled:
            # resolved BEFORE the transfer mutates host/pool state, with the
            # same precedence begin_device_load re-resolves: peer > host > disk
            via = self._load_source(expert_id)
        self.pool.add(expert_id)
        # sim: contended channel latency; real: queued on the transfer thread
        lat = self.engine.load(self, expert_id, now)
        self.pool.loading[expert_id] = now + lat
        self.load_in_flight = (expert_id, now + lat)
        self.stats.switches += 1
        self.stats.load_time += lat
        if demand:
            self.stats.stall_time += lat
        if tracer.enabled:
            tracer.emit(now, "load", self.id, expert_id, dur=lat,
                        demand=demand, via=via, pool=self.pool.group,
                        bytes=self.coe.spec(expert_id).mem_bytes)
        return now + lat

    def _load_source(self, expert_id: str) -> str:
        """Which tier this load will be served from ("peer"|"host"|"disk"),
        mirroring ``MemoryHierarchy.begin_device_load``'s resolution order
        (and ``begin_host_load``'s host-exec short-circuit for CPU
        executors)."""
        h = self.hierarchy
        if h is None or self.device in ("host", "cpu"):
            if h is not None and h.host_exec_enabled and h.in_host(expert_id):
                return "host"          # runs in place from DRAM, no disk leg
            return "disk"
        if h.peer_source(expert_id, self.pool.group) is not None:
            return "peer"
        return "host" if h.in_host(expert_id) else "disk"

    def finish_load(self, expert_id: str):
        assert self.load_in_flight and self.load_in_flight[0] == expert_id
        self.load_in_flight = None
        self.pool.loading.pop(expert_id, None)
        wait = getattr(self.engine, "wait_load", None)
        if wait is not None:            # real backend: join the transfer thread
            wait(self, expert_id)
        self.pool.ready.add(expert_id)

    # ------------------------------------------------------------------ #
    # execution path
    # ------------------------------------------------------------------ #
    def can_execute_head(self) -> bool:
        return bool(self.queue) and self.queue[0].expert_id in self.pool.ready

    def start_next_batch(self, now: float) -> Optional[float]:
        """Pop a batch from the head group and execute; returns finish time."""
        if self.current is not None or not self.can_execute_head():
            return None
        head = self.queue[0]
        eid = head.expert_id
        batch = split_batch(head, self.max_batch_for(eid))
        if not head.requests:
            self.queue.pop(0)
        else:
            bump_queue(self.queue)   # head group shrank in place
        outputs, lat = self.engine.execute(self, eid, batch)
        self.pool.pin(eid)
        self.pool.touch(eid)
        self.current = (eid, batch, outputs)
        self.busy_until = now + lat
        self.stats.busy_time += lat
        if self.tracer.full:
            on = "host" if self.device in ("host", "cpu") else "device"
            self.tracer.emit(now, "exec", self.id, eid, dur=lat,
                             requests=[r.id for r in batch], n=len(batch),
                             on=on)
        if self.hierarchy is not None:
            # dependency-aware cross-tier prefetch: while this expert runs,
            # promote its likely downstream experts disk -> host
            self.hierarchy.on_execute(eid, now)
        return self.busy_until

    def finish_batch(self, now: float) -> Tuple[str, List[Request], Any]:
        eid, batch, outputs = self.current
        self.current = None
        self.pool.unpin(eid)
        self.stats.completed += len(batch)
        for i, r in enumerate(batch):
            r.done_time = now
            r.result = outputs[i] if outputs is not None else None
        return eid, batch, outputs

    # next expert worth prefetching: first queued group whose expert is not
    # resident (the shared pool tracks in-flight loads from peers)
    def prefetch_candidate(self) -> Optional[str]:
        for g in self.queue:
            if g.expert_id not in self.pool:
                return g.expert_id
        return None
