"""Dependency-aware request scheduling (paper §4.2).

Four stages, exactly as the paper defines them:
  1. *Prediction* — additional latency of placing a request on each queue:
     execution is the linear model K·n+B (K if it joins an existing group);
     switching is zero if the expert is resident (a) or already queued (b),
     else the *residency-aware* assignment cost: the uncontended service
     time from the tier the expert actually occupies (DEVICE on this pool /
     HOST / DISK) plus the backlog of the specific link(s) the load would
     ride — the same contended channels the TransferEngine charges and the
     prefetcher gates on, replacing the seed's executor-local
     ``load_latency`` guess.
  2. *Assigning* — minimise the makespan over executor queues; ties broken by
     the smallest added latency for the new request (Fig. 8).
  3. *Arranging* — place the request directly behind queued requests that use
     the same expert, so an expert loads at most once per group (Fig. 9).
  4. *Splitting* — batches capped by min(profiled max batch, memory-bound
     batch) at execution time (Fig. 9, right).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, TYPE_CHECKING

from repro.core.coe import Request
from repro.obs import NULL_TRACER

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.executor import Executor


@dataclasses.dataclass
class Group:
    """Consecutive same-expert requests in a queue (batched together)."""
    expert_id: str
    requests: List[Request]
    deadline: Optional[float] = None   # earliest member deadline (SLO mode)

    def __len__(self):
        return len(self.requests)


def bump_queue(queue) -> None:
    """Record an in-place Group mutation (requests joined or split) on an
    executor queue. ``TrackedQueue`` versions every *list* mutation itself,
    but a Group growing or shrinking in place changes the queue's predicted
    work without touching the list — the two sites that do that call this.
    No-op for plain lists (tests sometimes swap one in)."""
    bump = getattr(queue, "bump", None)
    if bump is not None:
        bump()


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    assign: str = "makespan"     # makespan | round_robin | single
    arrange: bool = True         # group same-expert requests (paper §4.2)
    lookahead: int = 0           # beyond-paper: dequeue-time window re-sort


class RequestScheduler:
    """Assigns arriving requests to executor queues and arranges them."""

    def __init__(self, executors: Sequence["Executor"],
                 policy: SchedulerPolicy = SchedulerPolicy()):
        self.executors = list(executors)
        self.policy = policy
        self._rr = 0
        self.tracer = NULL_TRACER    # set by CoServeSystem when tracing
        # optional SLO hook (repro.serve): maps a request to its absolute
        # deadline. When set, new groups are placed earliest-deadline-first
        # within the queue instead of appended; None preserves paper order.
        self.priority_fn: Optional[Callable[[Request], float]] = None

    # ------------------------------------------------------------------ #
    # prediction (paper §4.2 "Prediction of additional inference latency")
    # ------------------------------------------------------------------ #
    def additional_latency(self, ex: "Executor", req: Request,
                           now: float = 0.0) -> float:
        spec = ex.coe.spec(req.expert_id)
        prof = ex.profile(spec.arch)
        # O(1) queued-same probe against the executor's lazily-rebuilt
        # queued-group index (the naive reference rescans the whole queue)
        queued_same = req.expert_id in ex.queued_groups()
        if queued_same and self.policy.arrange:
            exec_lat = prof.k                      # joins an existing batch
        else:
            exec_lat = prof.k + prof.b
        return exec_lat + self.switch_cost(ex, req.expert_id, now,
                                           queued_same=queued_same)

    def switch_cost(self, ex: "Executor", expert_id: str, now: float,
                    queued_same: bool = False) -> float:
        """Residency-aware switch cost of running ``expert_id`` on ``ex``.

        Zero for condition (b) (already queued: the load is paid once per
        group) and for a settled resident of this executor's pool
        (condition (a)). A copy still LOADING into the pool costs its
        remaining in-flight time, not zero and not a full reload. Otherwise
        the memory hierarchy prices the load from where the expert really is
        (a sibling pool over the peer fabric / HOST / DISK) plus the queue
        of the specific link(s) this executor's device would ride — so an
        executor behind a congested PCIe channel or peer ingress port
        genuinely looks more expensive than a replica-holding one, and all
        consumers (scheduler, TransferEngine, prefetcher) agree on the same
        contended-channel state.

        Heterogeneous CPU co-execution (``policy.host_exec``) also lives in
        that hierarchy cost: a host-DRAM-resident expert is ~free to switch
        onto a host/CPU executor (it runs in place), so the makespan argmin
        over the executor set prices min(execute_on_host,
        load_then_execute_on_device) per arrival with no extra branch here —
        the CPU arm simply wins when its switch cost plus its (slower)
        exec latency beats every device arm's load-plus-exec.
        """
        if queued_same:
            return 0.0
        pool = ex.pool
        if expert_id in pool:
            done = pool.loading.get(expert_id)
            if done is None or expert_id in pool.ready:
                return 0.0
            return max(0.0, done - now)
        h = ex.hierarchy
        if h is not None:
            return h.assignment_cost(expert_id, now, group=ex.link_group,
                                     device=ex.device)
        return ex.load_latency(expert_id)

    # ------------------------------------------------------------------ #
    # assigning (paper §4.2 "Request assigning")
    # ------------------------------------------------------------------ #
    def assign(self, req: Request, now: float) -> "Executor":
        if self.policy.assign == "single" or len(self.executors) == 1:
            ex = self.executors[0]
        elif self.policy.assign == "round_robin":
            ex = self.executors[self._rr % len(self.executors)]
            self._rr += 1
        else:
            ex = self._assign_makespan(req, now)
        self._arrange(ex, req)
        if self.tracer.full:
            self.tracer.emit(now, "sched", "scheduler", req.expert_id,
                             request=req.id, executor=ex.id,
                             mode=self.policy.assign)
        return ex

    def _assign_makespan(self, req: Request, now: float) -> "Executor":
        """Argmin over executors of (makespan if assigned here, added
        latency, index). The naive reference recomputes the max over all
        *other* queues per candidate — O(n^2) per arrival; here the top-2
        pending times give that exclusion max in O(1): the largest pending
        time unless the candidate IS the argmax, else the second largest.
        Identical keys, identical argmin (pinned against the reference)."""
        pending = [ex.pending_time(now) for ex in self.executors]
        hi1 = hi2 = float("-inf")
        hi1_i = -1
        for i, p in enumerate(pending):
            if p > hi1:
                hi2 = hi1
                hi1, hi1_i = p, i
            elif p > hi2:
                hi2 = p
        # ``additional_latency``/``switch_cost`` inlined: the methods stay
        # (reference tests, steal heuristics, the manager) but paying two
        # dispatches plus a catalog lookup per executor per arrival is the
        # residual hot spot at 128 devices — same branches, same values
        eid = req.expert_id
        arch = self.executors[0].coe.spec(eid).arch
        arrange = self.policy.arrange
        best, best_key = None, None
        for i, ex in enumerate(self.executors):
            prof = ex.device_profile.arch_profiles[arch]
            queued_same = eid in ex.queued_groups()
            exec_lat = prof.k if (queued_same and arrange) \
                else prof.k + prof.b
            if queued_same:
                sc = 0.0
            else:
                pool = ex.pool
                if eid in pool:
                    done = pool.loading.get(eid)
                    sc = 0.0 if done is None or eid in pool.ready \
                        else max(0.0, done - now)
                elif ex.hierarchy is not None:
                    sc = ex.hierarchy.assignment_cost(
                        eid, now, group=pool.group, device=ex.device)
                else:
                    sc = ex.load_latency(eid)
            add = exec_lat + sc
            new_total = pending[i] + add
            others = hi2 if i == hi1_i else hi1
            makespan = new_total if new_total >= others else others
            key = (makespan, add, i)
            if best_key is None or key < best_key:
                best, best_key = ex, key
        return best

    # ------------------------------------------------------------------ #
    # arranging (paper §4.2 "Request arranging")
    # ------------------------------------------------------------------ #
    def _arrange(self, ex: "Executor", req: Request):
        deadline = self.priority_fn(req) if self.priority_fn else None
        if self.policy.arrange:
            for g in reversed(ex.queue):
                if g.expert_id == req.expert_id:
                    g.requests.append(req)
                    bump_queue(ex.queue)   # group grew in place
                    if deadline is not None:
                        g.deadline = deadline if g.deadline is None \
                            else min(g.deadline, deadline)
                    return
        elif ex.queue and ex.queue[-1].expert_id == req.expert_id:
            # FCFS baselines still batch *consecutive* same-expert arrivals
            ex.queue[-1].requests.append(req)
            bump_queue(ex.queue)           # group grew in place
            if deadline is not None:
                g = ex.queue[-1]
                g.deadline = deadline if g.deadline is None \
                    else min(g.deadline, deadline)
            return
        group = Group(expert_id=req.expert_id, requests=[req],
                      deadline=deadline)
        if deadline is not None:
            # earliest-deadline-first insertion; stable among equal deadlines
            # (deadline-less groups sort last), so urgent tenants overtake
            # slack ones without starving them
            for i, g in enumerate(ex.queue):
                if g.deadline is None or g.deadline > deadline:
                    ex.queue.insert(i, group)
                    return
        ex.queue.append(group)

    # ------------------------------------------------------------------ #
    # beyond-paper: bounded lookahead re-sort at dequeue time — pull a
    # same-expert group from within the window to the head when the head
    # expert is not resident but a later one is (saves a switch).
    # ------------------------------------------------------------------ #
    def reorder_head(self, ex: "Executor", now: float = 0.0):
        w = self.policy.lookahead
        if not w or len(ex.queue) < 2:
            return
        head = ex.queue[0]
        if head.expert_id in ex.pool:
            return
        # queued-expert index: intersect the queue's expert set with the
        # pool's residents once, instead of probing pool membership per
        # window slot — the common all-cold window exits here
        hits = ex.queued_groups().keys() & ex.pool.resident.keys()
        if not hits:
            return
        for i in range(1, min(w + 1, len(ex.queue))):
            if ex.queue[i].expert_id in hits:
                ex.queue.insert(0, ex.queue.pop(i))
                if self.tracer.full:
                    # reorders were invisible to the flight recorder before
                    self.tracer.emit(now, "sched", "scheduler",
                                     ex.queue[0].expert_id, executor=ex.id,
                                     mode="reorder", slot=i)
                return


def split_batch(group: Group, max_exec_batch: int) -> List[Request]:
    """Pop at most ``max_exec_batch`` requests from the group head
    (paper §4.2 "Request splitting")."""
    take = min(len(group.requests), max(1, max_exec_batch))
    batch = group.requests[:take]
    del group.requests[:take]
    return batch


def max_executable_batch(profile, batch_bytes_available: int) -> int:
    """min(profiled max batch, what activation memory accommodates)."""
    by_mem = batch_bytes_available // max(1, profile.act_bytes_per_item)
    return max(1, min(profile.max_batch, by_mem))
