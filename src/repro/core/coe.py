"""CoE model abstractions: experts, dependencies, routing (paper §2.1).

A CoE model is a pool of *independent* expert models plus an *independent*
routing module. Because routing is user-defined (or separately trained), the
expert dependency graph and per-expert usage probabilities are available
*before* serving — the property CoServe exploits that MoE systems cannot.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ExpertSpec:
    """One expert model in the CoE pool."""
    id: str
    arch: str                          # performance-profile key (same-arch
    #                                    experts are profiled once, paper §4.5)
    mem_bytes: int
    depends_on: Tuple[str, ...] = ()   # preliminary (upstream) experts
    usage_prob: float = 0.0            # pre-assessed P(use) (paper §4.5)
    payload: Any = None                # backend handle (params factory, etc.)

    @property
    def is_dependent(self) -> bool:
        return bool(self.depends_on)


@dataclasses.dataclass
class Request:
    """One inference request targeting a specific expert."""
    id: int
    expert_id: str
    arrival_time: float = 0.0
    task_id: str = ""
    data: Any = None
    parent_id: Optional[int] = None    # set for chained (follow-up) requests
    done_time: Optional[float] = None
    result: Any = None
    # --- online serving metadata (repro.serve) ------------------------- #
    tenant: str = ""                   # multi-tenant attribution key
    deadline: Optional[float] = None   # absolute SLO deadline (arrival + SLO)
    root_arrival_time: Optional[float] = None  # first arrival of the chain:
    #                                    follow-ups inherit it so end-to-end
    #                                    latency spans the whole expert chain

    def e2e_arrival(self) -> float:
        """Arrival time of the chain root (end-to-end latency anchor)."""
        return self.root_arrival_time \
            if self.root_arrival_time is not None else self.arrival_time


class RoutingModule:
    """User-defined routing rules (paper §2.1, §4.5).

    ``first_expert`` maps a raw input to its first expert;
    ``next_expert`` maps (request, expert, output) to a follow-up expert id or
    None. ``chain_prob[e1][e2]`` is the probability that running e1 produces a
    follow-up on e2 (used to pre-assess usage probabilities and prefetch).
    """

    def __init__(self,
                 first_expert_fn: Callable[[Any], str],
                 next_expert_fn: Optional[Callable[[Request, str, Any], Optional[str]]] = None,
                 chain_prob: Optional[Mapping[str, Mapping[str, float]]] = None):
        self._first = first_expert_fn
        self._next = next_expert_fn or (lambda req, eid, out: None)
        self.chain_prob = {k: dict(v) for k, v in (chain_prob or {}).items()}

    def first_expert(self, data: Any) -> str:
        return self._first(data)

    def next_expert(self, req: Request, expert_id: str, output: Any) -> Optional[str]:
        return self._next(req, expert_id, output)


class CoEModel:
    """Expert pool + routing + derived dependency/probability metadata."""

    def __init__(self, experts: Sequence[ExpertSpec], routing: RoutingModule):
        self.experts: Dict[str, ExpertSpec] = {e.id: e for e in experts}
        if len(self.experts) != len(experts):
            raise ValueError("duplicate expert ids")
        self.routing = routing
        # cached usage-descending catalog order (``by_usage`` is called per
        # placement proposal and per replay warm-up — the sort dominated
        # search profiles); None until first use, dropped on catalog mutation
        self._by_usage_cache: Optional[List[ExpertSpec]] = None
        self._by_usage_len = -1
        # downstream map: upstream expert -> experts that depend on it
        self.downstream: Dict[str, List[str]] = {e.id: [] for e in experts}
        for e in experts:
            for up in e.depends_on:
                if up not in self.experts:
                    raise ValueError(f"{e.id} depends on unknown expert {up}")
                self.downstream[up].append(e.id)

    def __len__(self) -> int:
        return len(self.experts)

    def spec(self, expert_id: str) -> ExpertSpec:
        return self.experts[expert_id]

    def total_bytes(self) -> int:
        return sum(e.mem_bytes for e in self.experts.values())

    # ------------------------------------------------------------------ #
    # usage probabilities (paper §4.5: compute from routing rules + the
    # known input distribution, or estimate from a sample run)
    # ------------------------------------------------------------------ #
    def assess_usage_probabilities(
            self, input_distribution: Mapping[Any, float]) -> "CoEModel":
        """Return a copy whose experts carry P(use) derived from the routing
        rules and a known distribution over raw inputs."""
        probs: Dict[str, float] = {eid: 0.0 for eid in self.experts}
        for data, p in input_distribution.items():
            first = self.routing.first_expert(data)
            probs[first] += p
        # propagate through chains: P(e2) += P(e1) * chain_prob[e1][e2]
        order = self._topo_order()
        for eid in order:
            for nxt, cp in self.routing.chain_prob.get(eid, {}).items():
                probs[nxt] += probs[eid] * cp
        experts = [dataclasses.replace(e, usage_prob=probs[e.id])
                   for e in self.experts.values()]
        return CoEModel(experts, self.routing)

    def estimate_usage_from_samples(self, sample_inputs: Sequence[Any]) -> "CoEModel":
        """Paper's fallback for ambiguous (trained) routers: run routing over
        a small sample set and count first-expert frequencies + chains."""
        counts = {eid: 0.0 for eid in self.experts}
        for data in sample_inputs:
            counts[self.routing.first_expert(data)] += 1.0
        n = max(1, len(sample_inputs))
        dist = {eid: c / n for eid, c in counts.items()}
        order = self._topo_order()
        for eid in order:
            for nxt, cp in self.routing.chain_prob.get(eid, {}).items():
                dist[nxt] = dist.get(nxt, 0.0) + dist[eid] * cp
        experts = [dataclasses.replace(e, usage_prob=dist.get(e.id, 0.0))
                   for e in self.experts.values()]
        return CoEModel(experts, self.routing)

    def _topo_order(self) -> List[str]:
        seen: Dict[str, int] = {}
        out: List[str] = []

        def visit(eid: str):
            state = seen.get(eid, 0)
            if state == 1:
                raise ValueError("dependency cycle in CoE graph")
            if state == 2:
                return
            seen[eid] = 1
            for down in self.downstream.get(eid, []):
                visit(down)
            seen[eid] = 2
            out.append(eid)

        for eid in self.experts:
            visit(eid)
        out.reverse()
        return out

    # sorted by usage probability, descending (init placement, paper §4.1)
    def by_usage(self) -> List[ExpertSpec]:
        """Cached: specs are immutable dataclass copies and the catalog dict
        is fixed at construction, so the sort is computed once. A changed
        catalog *size* invalidates automatically; code that swaps specs
        in-place at the same size must call ``invalidate_catalog_cache``.
        Returns a fresh list so callers may mutate their copy."""
        if self._by_usage_cache is None \
                or self._by_usage_len != len(self.experts):
            self._by_usage_cache = sorted(
                self.experts.values(), key=lambda e: (-e.usage_prob, e.id))
            self._by_usage_len = len(self.experts)
        return list(self._by_usage_cache)

    def invalidate_catalog_cache(self):
        """Drop derived catalog order after an in-place ``experts`` mutation
        that kept the size unchanged (tests / notebooks)."""
        self._by_usage_cache = None
        self._by_usage_len = -1
