"""Pinned naive reference for the PR-7 scheduler/cost fast paths.

The fast paths (top-2 makespan argmin, queued-group index, epoch-validated
pending-time and peer-holder caches) must be decision-for-decision identical
to the straightforward implementations they replaced. This module *retains*
those implementations verbatim so the equivalence is testable forever, not
just against a git hash:

  ``ReferenceScheduler``      ``_assign_makespan`` as the O(n^2)
                              max-with-exclusion loop, ``additional_latency``
                              with the full queue rescan, ``reorder_head``
                              with the per-slot pool probe.
  ``reference_pending_time``  the uncached queue-work loop (same summation
                              order as ``Executor.queue_work``, so cached
                              and naive values are bit-identical).
  ``apply_reference``         swap a built ``CoServeSystem`` onto the naive
                              paths and disable every cache — the property
                              tests' control arm and ``bench_simperf``'s
                              pre-optimization baseline column.

Keep this module dependency-light and boring: it is the measuring stick,
not a serving mode.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Set

from repro.core.scheduler import RequestScheduler

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.executor import Executor
    from repro.core.serving import CoServeSystem


def reference_pending_time(ex: "Executor", now: float) -> float:
    """Naive ``pending_time``: busy remainder plus the queue-work loop
    recomputed from scratch (one load per distinct non-resident expert plus
    per-group exec latency), every call."""
    total = 0.0
    seen: Set[str] = set(ex.pool.resident)
    for g in ex.queue:
        prof = ex.profile(ex.coe.spec(g.expert_id).arch)
        if g.expert_id not in seen:
            total += ex.load_latency(g.expert_id)
            seen.add(g.expert_id)
        total += prof.exec_latency(len(g))
    return max(0.0, ex.busy_until - now) + total


class ReferenceScheduler(RequestScheduler):
    """``RequestScheduler`` with the pre-fast-path hot loops."""

    def additional_latency(self, ex: "Executor", req, now: float = 0.0
                           ) -> float:
        spec = ex.coe.spec(req.expert_id)
        prof = ex.profile(spec.arch)
        queued_same = any(g.expert_id == req.expert_id for g in ex.queue)
        if queued_same and self.policy.arrange:
            exec_lat = prof.k                      # joins an existing batch
        else:
            exec_lat = prof.k + prof.b
        return exec_lat + self.switch_cost(ex, req.expert_id, now,
                                           queued_same=queued_same)

    def _assign_makespan(self, req, now: float) -> "Executor":
        pending = [ex.pending_time(now) for ex in self.executors]
        adds = [self.additional_latency(ex, req, now)
                for ex in self.executors]
        best, best_key = None, None
        for i, ex in enumerate(self.executors):
            new_total = pending[i] + adds[i]
            makespan = max([new_total]
                           + [pending[j] for j in range(len(pending))
                              if j != i])
            key = (makespan, adds[i], i)
            if best_key is None or key < best_key:
                best, best_key = ex, key
        return best

    def reorder_head(self, ex: "Executor", now: float = 0.0):
        w = self.policy.lookahead
        if not w or len(ex.queue) < 2:
            return
        head = ex.queue[0]
        if head.expert_id in ex.pool:
            return
        for i in range(1, min(w + 1, len(ex.queue))):
            if ex.queue[i].expert_id in ex.pool:
                ex.queue.insert(0, ex.queue.pop(i))
                return


def apply_reference(system: "CoServeSystem") -> "CoServeSystem":
    """Route ``system`` through the naive reference paths in place: swap the
    scheduler for a ``ReferenceScheduler`` (carrying over tracer, priority
    hook and round-robin cursor) and disable the hierarchy's peer-holder
    cache and every executor's pending-time cache, so all hot-path work is
    recomputed per probe exactly as before PR 7."""
    old = system.scheduler
    ref = ReferenceScheduler(list(old.executors), old.policy)
    ref.tracer = old.tracer
    ref.priority_fn = old.priority_fn
    ref._rr = old._rr
    system.scheduler = ref
    system.hierarchy.cost_cache_enabled = False
    for ex in system.executors:
        ex.use_pending_cache = False
    return system
