"""Offline profiler (paper §4.4–§4.5).

Produces, per (device kind x expert architecture):
  - max batch size      (avg-latency plateau over a batch sweep, Fig. 5)
  - execution latency   (K, B of ``latency = K*n + B``, Fig. 12)
  - load latency        (expert switch cost per source tier)
  - memory footprint    (params + per-item activation bytes -> memory score)
and, per device, the expert-pool/batch-memory split via the decay-window
search on the usage-probability CDF (Eq. 1–3, Fig. 11/18).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.coe import CoEModel
from repro.memory import TierSpec
from repro.memory.transfer import predicted_load_latency


@dataclasses.dataclass
class ArchProfile:
    """Performance matrix entry for one expert architecture on one processor.
    Same-architecture experts share one profile (paper §4.5)."""
    arch: str
    k: float                  # marginal latency per request in a batch [s]
    b: float                  # batch setup latency [s]
    max_batch: int
    mem_bytes: int            # parameter bytes
    act_bytes_per_item: int   # intermediate-result bytes per batched item
    load_latency_host: float = 0.0   # host cache -> device
    load_latency_disk: float = 0.0   # disk -> device
    # CPU service-time model (heterogeneous co-execution): what the SAME
    # architecture costs on the host CPU pool — measured by
    # ``microbenchmark_arch(run_batch_cpu=...)`` in real mode, derived from
    # the device time via ``hetero.cpu_multiplier`` in sim. 0.0 = unprofiled
    # (host co-execution then keeps the static CPU constants).
    cpu_k: float = 0.0
    cpu_b: float = 0.0

    def exec_latency(self, n: int) -> float:
        return self.k * n + self.b if n > 0 else 0.0

    def cpu_exec_latency(self, n: int) -> float:
        """Linear CPU service-time model K·n+B of this architecture on the
        host pool (0.0 when no CPU profile was taken)."""
        return self.cpu_k * n + self.cpu_b if n > 0 else 0.0


@dataclasses.dataclass
class DeviceProfile:
    """All profiling results for one executor device kind."""
    device: str                       # "tpu" | "host" (paper: GPU | CPU)
    tier: TierSpec
    arch_profiles: Dict[str, ArchProfile]
    pool_bytes: int = 0               # expert-loading share of device memory
    batch_bytes: int = 0              # activation share

    def profile(self, arch: str) -> ArchProfile:
        return self.arch_profiles[arch]


# --------------------------------------------------------------------------- #
# microbenchmarks (paper §4.5)
# --------------------------------------------------------------------------- #

def fit_latency_line(batch_sizes: Sequence[int], latencies: Sequence[float]
                     ) -> Tuple[float, float]:
    """Least-squares fit of latency = K*n + B."""
    a = np.vstack([np.asarray(batch_sizes, float), np.ones(len(batch_sizes))]).T
    k, b = np.linalg.lstsq(a, np.asarray(latencies, float), rcond=None)[0]
    return float(k), float(b)


def find_max_batch(batch_sizes: Sequence[int], latencies: Sequence[float],
                   plateau_eps: float = 0.03) -> int:
    """Max batch = where average (per-item) latency plateaus (paper Fig. 5):
    the first batch size whose avg-latency improvement over the previous
    sweep point falls below ``plateau_eps`` (relative)."""
    avg = [l / n for n, l in zip(batch_sizes, latencies)]
    for i in range(1, len(avg)):
        if avg[i - 1] <= 0:
            continue
        improvement = (avg[i - 1] - avg[i]) / avg[i - 1]
        if improvement < plateau_eps:
            return batch_sizes[i - 1]
    return batch_sizes[-1]


def microbenchmark_arch(
        arch: str,
        run_batch: Callable[[int], float],
        mem_bytes: int,
        act_bytes_per_item: int,
        tier: TierSpec,
        batch_sizes: Sequence[int] = (1, 2, 3, 4, 6, 8, 12, 16),
        repeats: int = 3,
        run_batch_cpu: Optional[Callable[[int], float]] = None,
) -> ArchProfile:
    """Profile one architecture with a real runner (``run_batch(n)`` executes
    a batch of n and returns seconds; called on real samples).
    ``run_batch_cpu`` (heterogeneous co-execution) runs the same batch on the
    host CPU pool; when given, the profile carries a measured CPU
    service-time line (``cpu_k``/``cpu_b``) next to the device one."""
    lats = []
    for n in batch_sizes:
        samples = [run_batch(n) for _ in range(repeats)]
        lats.append(float(np.median(samples)))
    k, b = fit_latency_line(batch_sizes, lats)
    max_batch = find_max_batch(batch_sizes, lats)
    cpu_k = cpu_b = 0.0
    if run_batch_cpu is not None:
        cpu_lats = []
        for n in batch_sizes:
            samples = [run_batch_cpu(n) for _ in range(repeats)]
            cpu_lats.append(float(np.median(samples)))
        cpu_k, cpu_b = fit_latency_line(batch_sizes, cpu_lats)
    return ArchProfile(
        arch=arch, k=k, b=b, max_batch=max_batch, mem_bytes=mem_bytes,
        act_bytes_per_item=act_bytes_per_item,
        cpu_k=cpu_k, cpu_b=cpu_b,
        # per-tier switch costs come from the one TransferEngine formula
        load_latency_host=predicted_load_latency(tier, mem_bytes,
                                                 in_host_cache=True),
        load_latency_disk=predicted_load_latency(tier, mem_bytes,
                                                 in_host_cache=False),
    )


# --------------------------------------------------------------------------- #
# memory allocation (paper §4.4)
# --------------------------------------------------------------------------- #

def allocate_limited_compute(device_bytes: int, coe: CoEModel,
                             profile: ArchProfile) -> Tuple[int, int]:
    """Weak processors: reserve activation memory for the max batch, give all
    the rest to the expert pool."""
    batch_bytes = profile.max_batch * profile.act_bytes_per_item
    return device_bytes - batch_bytes, batch_bytes


@dataclasses.dataclass
class DecayWindowResult:
    n_experts: int
    window: Tuple[int, int]
    history: List[Tuple[int, float]]    # (upper_bound, throughput) samples
    linear_error: float


def decay_window_search(
        throughput_fn: Callable[[int], float],
        max_experts: int,
        initial_window: int = 15,
        error_margin: float = 0.05,
        fit_points: int = 3,
        rng: Optional[np.random.RandomState] = None,
) -> DecayWindowResult:
    """Sliding decay window on the expert-usage CDF (paper Eq. 1–3, Fig. 11).

    ``throughput_fn(n)`` runs sample inference with the top-n experts loaded
    (a smaller representative dataset) and returns throughput. The window
    shrinks by ``decay = 1 - initial_window/100`` each slide; sliding stops
    when the measured throughput falls below the linear-fit prediction by
    more than ``error_margin``; the result is drawn inside the final window.
    """
    rng = rng or np.random.RandomState(0)
    decay = 1.0 - initial_window / 100.0
    window_size = float(initial_window)
    lower, upper = 0, initial_window
    history: List[Tuple[int, float]] = []
    linear_error = 0.0

    while upper < max_experts:
        n = min(upper, max_experts)
        history.append((n, throughput_fn(n)))
        if len(history) >= fit_points + 1:
            xs = np.array([h[0] for h in history[:-1]], float)
            ys = np.array([h[1] for h in history[:-1]], float)
            k, b = np.polyfit(xs, ys, 1)
            predicted = k * history[-1][0] + b
            actual = history[-1][1]
            if predicted > 0:
                linear_error = (predicted - actual) / predicted
                if linear_error > error_margin:
                    break
        window_size = max(1.0, window_size * decay)
        lower = upper
        upper = upper + int(round(window_size))
    else:
        lower, upper = max(0, max_experts - int(round(window_size))), max_experts

    upper = min(upper, max_experts)
    lower = min(lower, upper)
    # The paper samples uniformly inside the final window ("differences ...
    # are negligible"). When the batch-memory cliff is sharp that assumption
    # fails, so we pick the best MEASURED boundary inside the window instead
    # — strictly better and free (beyond-paper; recorded in EXPERIMENTS.md).
    in_window = [(n, t) for n, t in history if lower <= n <= upper]
    if in_window:
        n_experts = max(in_window, key=lambda h: h[1])[0]
    else:
        n_experts = int(rng.randint(lower, upper + 1)) if upper > lower else upper
    n_experts = max(1, n_experts)
    return DecayWindowResult(n_experts=n_experts, window=(lower, upper),
                             history=history, linear_error=float(linear_error))


def pool_split_from_expert_count(coe: CoEModel, n_experts: int,
                                 device_bytes: int) -> Tuple[int, int]:
    """Reserve pool bytes for the top-n experts by usage; rest to batches."""
    top = coe.by_usage()[:n_experts]
    pool = sum(e.mem_bytes for e in top)
    pool = min(pool, device_bytes)
    return pool, device_bytes - pool
