"""CoServe system facade (paper §4.1): offline -> init -> online phases.

``CoServeSystem`` wires the CoE model, offline profiles, executors, the
dependency-aware scheduler and expert manager. ``SystemPolicy`` presets
reproduce the paper's systems:

  CoServe        : makespan assign + arranging + two-stage eviction + overlap
  CoServe None   : FIFO eviction, no arranging, round-robin assign (ablation)
  Samba-CoE      : single executor, FCFS, LRU (tiered DRAM cache on NUMA)
  Samba-CoE FIFO : FIFO eviction variant
  Samba-CoE Par. : N executors, round-robin FCFS, LRU
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.coe import CoEModel, Request
from repro.core.decode import DecodeConfig, DecodeRuntime
from repro.core.engines import SimEngine
from repro.core.executor import Executor
from repro.core.expert_manager import ExpertManager
from repro.core.profiler import DeviceProfile
from repro.core.scheduler import RequestScheduler, SchedulerPolicy
from repro.fleet import PlacementPlan, validate_pool_groups
from repro.memory import MemoryHierarchy, PrefetchConfig, TierSpec
from repro.obs import NULL_TRACER, Tracer


@dataclasses.dataclass(frozen=True)
class SystemPolicy:
    name: str = "coserve"
    assign: str = "makespan"          # makespan | round_robin | single
    arrange: bool = True
    evict: str = "dependency_prob"    # dependency_prob | lru | fifo | prob | cost_benefit
    prefetch: bool = True             # overlap device loads with execution
    host_prefetch: bool = True        # dependency-aware disk->host promotion
    prefetch_trigger: str = "exec"    # exec (upstream starts executing) |
    #                                   queue (upstream joins a queue: wider
    #                                   window, more speculative SSD traffic)
    protect_queued: bool = True       # demand loads evict queue-referenced
    #                                   experts only as a last resort
    host_cache_policy: str = "prob"
    work_stealing: bool = False       # beyond-paper straggler mitigation
    lookahead: int = 0                # beyond-paper dequeue-time window
    host_exec: bool = False           # heterogeneous CPU co-execution:
    #                                   host-DRAM-resident experts run in
    #                                   place on host/CPU executors instead
    #                                   of paying a disk reload (the
    #                                   scheduler prices min(execute-on-host,
    #                                   load-then-execute-on-device))


COSERVE = SystemPolicy()
COSERVE_NONE = SystemPolicy(name="coserve_none", assign="round_robin",
                            arrange=False, evict="fifo", prefetch=True,
                            protect_queued=False)
COSERVE_EM = SystemPolicy(name="coserve_em", assign="round_robin",
                          arrange=False, evict="dependency_prob", prefetch=True)
COSERVE_EM_RA = SystemPolicy(name="coserve_em_ra", assign="round_robin",
                             arrange=True, evict="dependency_prob", prefetch=True)
SAMBA = SystemPolicy(name="samba_coe", assign="single", arrange=False,
                     evict="lru", prefetch=False, host_prefetch=False,
                     protect_queued=False, host_cache_policy="lru")
SAMBA_FIFO = SystemPolicy(name="samba_coe_fifo", assign="single",
                          arrange=False, evict="fifo", prefetch=False,
                          host_prefetch=False, protect_queued=False,
                          host_cache_policy="lru")
SAMBA_PARALLEL = SystemPolicy(name="samba_coe_parallel", assign="round_robin",
                              arrange=False, evict="lru", prefetch=False,
                              host_prefetch=False, protect_queued=False,
                              host_cache_policy="lru")


def nearest_rank(sorted_xs: Sequence[float], q: float) -> float:
    """Nearest-rank quantile: element ceil(q*n) (1-indexed) of sorted data."""
    n = len(sorted_xs)
    return sorted_xs[min(n - 1, max(0, math.ceil(q * n) - 1))]


def latency_percentiles(latencies: Sequence[float]) -> Dict[str, float]:
    """Exact p50/p95/p99 over a finished run (nearest-rank)."""
    if not latencies:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    xs = sorted(latencies)
    return {"p50": nearest_rank(xs, 0.50), "p95": nearest_rank(xs, 0.95),
            "p99": nearest_rank(xs, 0.99)}


@dataclasses.dataclass
class Metrics:
    completed: int = 0
    switches: int = 0
    evictions: int = 0
    makespan: float = 0.0
    throughput: float = 0.0
    avg_latency: float = 0.0
    p50_latency: float = 0.0
    p95_latency: float = 0.0
    p99_latency: float = 0.0
    stall_time: float = 0.0           # demand-load time executors idled on
    sched_time: float = 0.0           # wall time in scheduling (overhead, Fig.19)
    mgmt_time: float = 0.0            # wall time in expert management
    events_processed: int = 0         # simulator heap events popped
    wall_s: float = 0.0               # wall-clock time of the run loop
    per_executor: Dict[str, Any] = dataclasses.field(default_factory=dict)
    per_tenant: Dict[str, Any] = dataclasses.field(default_factory=dict)
    memory: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #                                 # hierarchy snapshot (channels, prefetch)
    decode: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #                                 # token-level decode snapshot (tokens,
    #                                 # TTFT/token percentiles, KV traffic);
    #                                 # empty when decode is off


@dataclasses.dataclass
class ExecutorSpec:
    device: str                        # "tpu"/"gpu" | "host"/"cpu"
    profile: DeviceProfile
    batch_bytes: int
    pool_group: str = ""               # memory domain; defaults to ``device``


class CoServeSystem:
    def __init__(self, coe: CoEModel, executor_specs: Sequence[ExecutorSpec],
                 pools: Dict[str, int],
                 policy: SystemPolicy = COSERVE, tier: Optional[TierSpec] = None,
                 engine=None, links: str = "shared",
                 placement: Optional[PlacementPlan] = None,
                 replication: int = 0, tracer: Optional[Tracer] = None,
                 decode: Optional[DecodeConfig] = None):
        """``pools`` maps memory-domain name -> expert-pool bytes. Executors
        with the same ``pool_group`` share one ModelPool (one physical
        device's memory), as in the paper's multi-executor single-GPU setup.
        ``links`` picks the host->device channel layout (``shared`` |
        ``per-device``); ``placement`` supplies an explicit expert->pool
        plan (default: ``PlacementPlan.build`` — the paper's round-robin
        sweep plus ``replication`` planned copies of the hottest experts).
        """
        self.coe = coe
        self.policy = policy
        self.tier = tier
        self.tracer = tracer or NULL_TRACER   # flight recorder (repro.obs)
        # spec-level guard: one pool group is one physical device's memory —
        # conflicting device kinds must not share a residency set
        self.pool_devices = validate_pool_groups(executor_specs)
        # the unified tiered-memory subsystem owns host tier, device pools,
        # contended transfer channels and the cross-tier prefetcher
        self.hierarchy = MemoryHierarchy(
            coe, tier, pools, host_policy=policy.host_cache_policy,
            prefetch=PrefetchConfig(enabled=policy.host_prefetch,
                                    trigger=policy.prefetch_trigger),
            links=links,
            link_groups=[g for g in pools
                         if self.pool_devices.get(g) not in ("host", "cpu")])
        self.host_cache = self.hierarchy.host          # seed-compat alias
        self.pools = self.hierarchy.pools
        # channel-leg events (xfer) are emitted where the legs are issued
        self.hierarchy.transfer.tracer = self.tracer
        # heterogeneous CPU co-execution: the hierarchy prices host-resident
        # experts as free-to-run on CPU executors, and engines short-circuit
        # their "load" (off by default — hetero=off costs are bit-identical)
        self.hierarchy.host_exec_enabled = policy.host_exec
        self.engine = engine or SimEngine(coe, tier, hierarchy=self.hierarchy)
        if policy.host_exec and hasattr(self.engine, "host_exec_enabled"):
            self.engine.host_exec_enabled = True
        bind = getattr(self.engine, "bind_topology", None)
        if bind is not None:     # real backend: one transfer thread per link
            bind(self.hierarchy.topology, self.hierarchy)
        self.manager = ExpertManager(coe, policy=policy.evict)
        self.executors: List[Executor] = []
        for i, spec in enumerate(executor_specs):
            group = spec.pool_group or spec.device
            self.hierarchy.register_batch_bytes(group, spec.batch_bytes)
            self.executors.append(Executor(
                ex_id=f"{spec.device}{i}", device=spec.device, coe=coe,
                device_profile=spec.profile, pool=self.pools[group],
                batch_bytes=spec.batch_bytes, manager=self.manager,
                engine=self.engine, prefetch=policy.prefetch,
                protect_queued=policy.protect_queued,
                hierarchy=self.hierarchy, tracer=self.tracer))
        self.scheduler = RequestScheduler(
            self.executors,
            SchedulerPolicy(assign=policy.assign, arrange=policy.arrange,
                            lookahead=policy.lookahead))
        self.scheduler.tracer = self.tracer
        # token-level decode (PR 9): one shared DecodeRuntime drives every
        # executor's continuous batch and owns KV-block residency. None (the
        # default) keeps the stage-level simulation bit-identical.
        self.decode: Optional[DecodeRuntime] = None
        if decode is not None:
            self.decode = DecodeRuntime(decode, self.hierarchy,
                                        tracer=self.tracer,
                                        engine=self.engine)
            self.hierarchy.kv = self.decode
            for ex in self.executors:
                ex.decode = self.decode
        self.sched_time = 0.0
        # observed per-expert load (assignment counts): the online signal
        # placement rebalancing and the "observed" eviction policy use
        # instead of static pre-assessed P(use)
        self.expert_load: Dict[str, int] = {}
        self.manager.observed_load = self.expert_load
        if self.hierarchy.host is not None:
            self.hierarchy.host.observed_load = self.expert_load
        # system initialisation (paper §4.1 steps 1–3) through the explicit
        # plan: round-robin by descending usage probability until pools are
        # full, plus any planned replicas
        self.placement = placement if placement is not None \
            else PlacementPlan.build(coe, pools, replication=replication)
        self.placement.validate()
        self._apply_placement()
        # cachesan: REPRO_CACHE_SANITIZE=1 shadow-validates the
        # epoch-guarded caches on every system built anywhere (the CI
        # equivalence leg) — lazy import, the hook costs one env read
        if os.environ.get("REPRO_CACHE_SANITIZE"):
            from repro.analysis.cachesan import install_from_env
            install_from_env(self)

    # ------------------------------------------------------------------ #
    def _apply_placement(self):
        """Warm the device pools to the plan's layout (init phase: transfers
        are untimed, exactly like the seed's placement loop)."""
        for eid, group in self.placement.layout():
            pool = self.pools.get(group)
            if pool is None:
                continue               # plan built for a pool we don't have
            if eid not in pool and self.coe.spec(eid).mem_bytes \
                    <= pool.free_bytes():
                pool.add(eid)
                pool.ready.add(eid)
                if hasattr(self.engine, "warm_place"):
                    self.engine.warm_place(pool, eid)

    # ------------------------------------------------------------------ #
    def live_executors(self) -> List[Executor]:
        return [e for e in self.executors if e.alive]

    def queue_depth(self) -> int:
        """Total queued requests across live executors — the one definition
        shared by telemetry, admission control and the autoscaler."""
        return sum(e.queued_requests() for e in self.live_executors())

    def assign(self, req: Request, now: float) -> Executor:
        t0 = time.perf_counter()
        ex = self.scheduler.assign(req, now)
        self.sched_time += time.perf_counter() - t0
        self.expert_load[req.expert_id] = \
            self.expert_load.get(req.expert_id, 0) + 1
        if self.tracer.full:
            # queue-arrival record: timeline reconstruction joins this with
            # exec batch membership to recover per-stage queue waits
            self.tracer.emit(now, "assign", "scheduler", req.expert_id,
                             request=req.id, executor=ex.id,
                             tenant=req.tenant, parent=req.parent_id)
        # queue-arrival prefetch trigger: the request's expert just joined a
        # queue, so its likely downstream experts can start promoting now
        # (inert unless policy.prefetch_trigger == "queue")
        self.hierarchy.on_enqueue(req.expert_id, now)
        return ex

    def route_followup(self, req: Request, expert_id: str, output) -> Optional[Request]:
        nxt = self.coe.routing.next_expert(req, expert_id, output)
        if nxt is None:
            return None
        # root_arrival_time propagates verbatim: online requests (stamped by
        # the gateway) measure end-to-end across the chain; offline requests
        # keep the seed's per-stage anchor so paper-reproduction latency
        # numbers are unchanged
        return Request(id=-req.id - 1_000_000, expert_id=nxt,
                       arrival_time=req.arrival_time, task_id=req.task_id,
                       data=req.data, parent_id=req.id,
                       tenant=req.tenant, deadline=req.deadline,
                       root_arrival_time=req.root_arrival_time)

    # --- fault tolerance / elasticity ---------------------------------- #
    def fail_executor(self, ex: Executor, now: float) -> List[Request]:
        """Mark dead; return orphaned requests for re-scheduling."""
        ex.alive = False
        orphans: List[Request] = []
        if ex.current is not None:
            eid, batch, _ = ex.current
            orphans.extend(batch)
            ex.current = None
            ex.pool.unpin(eid)
        if ex.load_in_flight is not None:
            # roll the half-finished transfer out of the shared pool —
            # otherwise peers wait forever on an expert that never turns ready
            eid, _ = ex.load_in_flight
            ex.load_in_flight = None
            ex.pool.loading.pop(eid, None)
            if eid in ex.pool and eid not in ex.pool.ready:
                ex.pool.remove(eid)
        for g in ex.queue:
            orphans.extend(g.requests)
        ex.queue.clear()
        if self.decode is not None:
            # mid-decode members lose their KV (it cannot be recovered from
            # a dead executor) and restart from assignment like any orphan
            orphans.extend(self.decode.fail_executor(ex))
        if getattr(ex.pool, "users", None) and ex in ex.pool.users:
            ex.pool.users.remove(ex)
        self.scheduler.executors = self.live_executors()
        # orphans re-enter through assign(): un-count them so the observed
        # per-expert load (rebalance_placement's replica signal) stays one
        # count per served stage — a scale-down must not inflate its victim
        # queue's experts at exactly the moment the signal is consumed
        for r in orphans:
            n = self.expert_load.get(r.expert_id, 0) - 1
            if n > 0:
                self.expert_load[r.expert_id] = n
            else:
                self.expert_load.pop(r.expert_id, None)
        return orphans

    def add_executor(self, spec: ExecutorSpec) -> Executor:
        group = spec.pool_group or spec.device
        if group not in self.pools:
            raise KeyError(f"unknown pool group {group!r}")
        self.pool_devices = validate_pool_groups([spec], self.pool_devices)
        ex = Executor(
            ex_id=f"{spec.device}{len(self.executors)}", device=spec.device,
            coe=self.coe, device_profile=spec.profile,
            pool=self.pools[group], batch_bytes=spec.batch_bytes,
            manager=self.manager, engine=self.engine,
            prefetch=self.policy.prefetch,
            protect_queued=self.policy.protect_queued,
            hierarchy=self.hierarchy, tracer=self.tracer)
        if self.decode is not None:
            ex.decode = self.decode
        self.executors.append(ex)
        self.scheduler.executors = self.live_executors()
        return ex

    # --- fleet placement reconfiguration -------------------------------- #
    def rebalance_placement(self, now: float, max_loads: int = 4
                            ) -> List[Tuple[Executor, str, float]]:
        """Re-plan replication with pools weighted by live executor count
        (a scale event shifted capacity) and experts ranked by *observed*
        per-expert load rather than static P(use), then pull the plan's
        hottest missing experts onto their pools through idle executors'
        contended load path (one in-flight load per pool, bounded by
        ``max_loads`` — a peer fabric turns these into cheap pool -> pool
        copies). Returns (executor, expert, done_time) for each issued load;
        the caller (autoscaler / injection) schedules their LOAD_DONE
        events."""
        weights: Dict[str, float] = {}
        for ex in self.live_executors():
            weights[ex.pool.group] = weights.get(ex.pool.group, 0.0) + 1.0
        self.placement.rebalance(weights,
                                 expert_weights=self.expert_load or None)
        issued: List[Tuple[Executor, str, float]] = []
        for group, pool in self.pools.items():
            if len(issued) >= max_loads:
                break
            idle = [e for e in self.live_executors()
                    if e.pool is pool and e.load_in_flight is None]
            if not idle:
                continue
            carrier = idle[0]
            for eid in self.placement.planned(group):
                if eid in pool:
                    continue
                if self.coe.spec(eid).mem_bytes > pool.free_bytes():
                    continue           # replicas fill free space, never evict
                done = carrier.start_load(eid, now, strict=True)
                if done is not None:
                    issued.append((carrier, eid, done))
                break                  # one in-flight load per pool
        return issued

    # --- beyond-paper: work stealing ------------------------------------ #
    def try_steal(self, thief: Executor, now: float) -> bool:
        """Cost-aware stealing: an idle executor takes a whole group from the
        most-loaded queue only when its own cost (execution + any expert load)
        is smaller than BOTH the time removed from the victim and the idle
        gap — a blind tail-steal un-does the dependency-aware grouping by
        paying a switch the victim would not have paid."""
        if not self.policy.work_stealing or thief.queue:
            return False
        cands = [e for e in self.live_executors()
                 if e is not thief and len(e.queue) >= 2]
        if not cands:
            return False
        victim = max(cands, key=lambda e: e.pending_time(now))
        gap = victim.pending_time(now) - thief.pending_time(now)
        if gap <= 0:
            return False
        best, best_cost = None, None
        for i in range(len(victim.queue) - 1, 0, -1):   # never steal the head
            g = victim.queue[i]
            arch = self.coe.spec(g.expert_id).arch
            cost = thief.profile(arch).exec_latency(len(g))
            if g.expert_id not in thief.pool:
                cost += thief.load_latency(g.expert_id)
            saved = victim.profile(arch).exec_latency(len(g))
            if g.expert_id not in victim.pool:
                saved += victim.load_latency(g.expert_id)
            if cost < saved and cost < gap \
                    and (best_cost is None or cost < best_cost):
                best, best_cost = i, cost
        if best is None:
            return False
        thief.queue.append(victim.queue.pop(best))
        return True

    # ------------------------------------------------------------------ #
    def collect_metrics(self, completed: List[Request], makespan: float) -> Metrics:
        m = Metrics()
        m.completed = len(completed)
        m.switches = sum(e.stats.switches for e in self.executors)
        m.evictions = sum(e.stats.evictions for e in self.executors)
        m.makespan = makespan
        m.throughput = m.completed / makespan if makespan > 0 else 0.0
        lats = [r.done_time - r.e2e_arrival() for r in completed
                if r.done_time is not None]
        m.avg_latency = sum(lats) / len(lats) if lats else 0.0
        pct = latency_percentiles(lats)
        m.p50_latency = pct["p50"]
        m.p95_latency = pct["p95"]
        m.p99_latency = pct["p99"]
        by_tenant: Dict[str, List[float]] = {}
        for r in completed:
            if r.done_time is not None:
                by_tenant.setdefault(r.tenant, []).append(
                    r.done_time - r.e2e_arrival())
        m.per_tenant = {
            t: {"completed": len(ls),
                "avg_latency": sum(ls) / len(ls),
                **latency_percentiles(ls)}
            for t, ls in by_tenant.items()}
        m.stall_time = sum(e.stats.stall_time for e in self.executors)
        m.sched_time = self.sched_time
        m.mgmt_time = sum(e.stats.mgmt_time for e in self.executors)
        m.per_executor = {
            e.id: dataclasses.asdict(e.stats) for e in self.executors}
        m.memory = self.hierarchy.snapshot()
        m.memory["pool_devices"] = dict(self.pool_devices)
        m.memory["placement"] = self.placement.snapshot()
        measured = getattr(self.engine, "measured_load_time", None)
        if measured is not None:      # real backend: worker wall time
            m.memory["real_measured_load_s"] = round(measured, 4)
        if self.decode is not None:
            m.decode = self.decode.metrics_snapshot()
        return m
