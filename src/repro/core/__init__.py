"""CoServe core: the paper's contribution (scheduling, expert management,
offline profiling, serving runtime) as a composable library. The storage
hierarchy itself (tiers, pools, transfer channels, cross-tier prefetch)
lives in ``repro.memory``; the seed's names are re-exported here."""
from repro.core.coe import CoEModel, ExpertSpec, Request, RoutingModule
from repro.core.scheduler import (Group, RequestScheduler, SchedulerPolicy,
                                  max_executable_batch, split_batch)
from repro.core.expert_manager import ExpertManager
from repro.core.memory import (NUMA, TPU_V5E, UMA, HostCache, ModelPool,
                               TierSpec, load_latency)
from repro.core.profiler import (ArchProfile, DeviceProfile,
                                 decay_window_search, find_max_batch,
                                 fit_latency_line, microbenchmark_arch,
                                 pool_split_from_expert_count)
from repro.core.serving import (COSERVE, COSERVE_EM, COSERVE_EM_RA,
                                COSERVE_NONE, SAMBA, SAMBA_FIFO,
                                SAMBA_PARALLEL, CoServeSystem, ExecutorSpec,
                                Metrics, SystemPolicy, latency_percentiles)
from repro.core.simulator import Simulation, run_real
from repro.core.engines import HostStore, RealEngine, SimEngine
from repro.core.reference import (ReferenceScheduler, apply_reference,
                                  reference_pending_time)
from repro.memory import (MemoryHierarchy, PrefetchConfig, Residency,
                          TransferChannel, TransferEngine)

__all__ = [
    "CoEModel", "ExpertSpec", "Request", "RoutingModule",
    "Group", "RequestScheduler", "SchedulerPolicy", "max_executable_batch",
    "split_batch", "ExpertManager", "NUMA", "UMA", "TPU_V5E", "HostCache",
    "ModelPool", "TierSpec", "load_latency", "ArchProfile", "DeviceProfile",
    "decay_window_search", "find_max_batch", "fit_latency_line",
    "microbenchmark_arch", "pool_split_from_expert_count", "COSERVE",
    "COSERVE_EM", "COSERVE_EM_RA", "COSERVE_NONE", "SAMBA", "SAMBA_FIFO",
    "SAMBA_PARALLEL", "CoServeSystem", "ExecutorSpec", "Metrics",
    "SystemPolicy", "Simulation", "run_real", "HostStore", "RealEngine",
    "SimEngine", "latency_percentiles", "MemoryHierarchy", "PrefetchConfig",
    "Residency", "TransferChannel", "TransferEngine",
    "ReferenceScheduler", "apply_reference", "reference_pending_time",
]
