"""Session: run a built deployment and collect its results/artifacts.

Source of truth: the only mode dispatcher — what "run this spec" means for
each ``serving.mode`` x ``serving.engine`` combination (offline simulation,
real-JAX execution, streaming online gateway) is defined here once, and the
result dict for each mode keeps the exact schema the old ``launch.serve``
runners printed (pinned by the CLI-equivalence tests).

    spec = DeploymentSpec.load("deploy.json")
    sess = Session(spec)
    result = sess.run()          # the mode's result dict
    sess.metrics()               # the underlying Metrics object
    sess.save_trace("trace.json")   # observed traffic -> artifact
    sess.save_plan("plan.json")     # the placement actually served
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.api import artifacts
from repro.api.build import (POLICIES, BuildContext, build_context,
                             real_board_layout)
from repro.api.spec import DeploymentSpec
from repro.core.coe import Request
from repro.core.serving import ExecutorSpec, Metrics
from repro.core.simulator import Simulation, run_real
from repro.fleet import trace_from_counts


class Session:
    """One deployment, built and ready to serve. Building is eager (the
    spec is the contract; errors surface at construction), running is
    single-shot — simulations and telemetry accumulate state, so build a
    fresh Session per run."""

    def __init__(self, spec: DeploymentSpec, placement=None):
        """``placement`` overrides the spec's placement section with an
        explicit ``PlacementPlan`` object (benchmark suites score
        externally-searched plans through it)."""
        self.spec = spec
        self.ctx: BuildContext = build_context(spec, placement=placement)
        self.system = self.ctx.system
        self._metrics: Optional[Metrics] = None
        self._pending: List[Request] = []
        self._ran = False

    # ------------------------------------------------------------------ #
    def submit(self, requests: List[Request]):
        """Queue an explicit offline workload instead of the spec's one
        (sim mode only — online modes generate their own streams)."""
        if self.spec.serving.mode == "online":
            raise ValueError(
                "submit() is for offline workloads; online mode streams "
                "arrivals from workload.tenants")
        self._pending.extend(requests)

    def metrics(self) -> Metrics:
        if self._metrics is None:
            raise RuntimeError("run() the session first")
        return self._metrics

    def snapshot(self) -> dict:
        """Memory/placement state: the finished run's snapshot once run()
        completed, the freshly-built system's otherwise."""
        if self._metrics is not None:
            return dict(self._metrics.memory)
        snap = self.system.hierarchy.snapshot()
        snap["placement"] = self.system.placement.snapshot()
        return snap

    # ------------------------------------------------------------------ #
    # artifacts
    # ------------------------------------------------------------------ #
    def save_trace(self, path: str, length: int = 512):
        """Dump the traffic this run observed (per-expert assignment
        counts) as a replayable WorkloadTrace — tomorrow's
        ``fleet.placement="search"`` + ``fleet.trace_path`` input."""
        if not self.system.expert_load:
            raise RuntimeError(
                "no observed load to dump — run() the session first")
        artifacts.save_trace(
            trace_from_counts(self.system.expert_load, length=length), path)

    def save_plan(self, path: str):
        """Dump the placement plan this system actually served (searched,
        loaded, or the greedy sweep) for ``fleet.placement="plan"`` reuse."""
        artifacts.save_plan(self.system.placement, path)

    def save_events(self, path: str) -> dict:
        """Export the flight recorder's ring buffer as Chrome trace JSON
        (Perfetto-loadable; see docs/observability.md). Needs
        ``observability.trace`` set to "summary" or "full"."""
        tracer = self.system.tracer
        if not tracer.enabled:
            raise RuntimeError(
                'no events recorded — set observability.trace to "summary" '
                'or "full" (or pass --trace-events on the CLI)')
        from repro.obs.export import save_events
        return save_events(tracer, path, metrics=self._metrics)

    # ------------------------------------------------------------------ #
    # running
    # ------------------------------------------------------------------ #
    def run(self) -> dict:
        """Serve the spec's workload to completion; returns the mode's
        result dict (the schema the CLI prints)."""
        if self._ran:
            raise RuntimeError(
                "Session.run() is single-shot: the simulation and telemetry "
                "accumulate state — build a fresh Session per run")
        self._ran = True
        mode, engine = self.spec.serving.mode, self.spec.serving.engine
        if mode == "sim":
            out = self._run_sim()
        elif mode == "real":
            out = self._run_real()
        else:
            out = self._run_online_real() if engine == "real" \
                else self._run_online()
        if self.spec.observability.trace_path:
            self.save_events(self.spec.observability.trace_path)
        return out

    # ------------------------------------------------------------------ #
    def _effective_devices(self) -> int:
        """Single-assign baselines normalize to one device (build_layout)."""
        if POLICIES[self.spec.policy.name].assign == "single":
            return 1
        return self.spec.fleet.devices

    def _run_sim(self) -> dict:
        spec = self.spec
        sim = Simulation(self.system)
        sim.submit(self._pending if self._pending else self.ctx.requests)
        m = self._metrics = sim.run()
        boards = [spec.model.board] if spec.model.kind == "board" else \
            list(dict.fromkeys(t.board for t in spec.workload.tenants))
        out = {"mode": "sim", "board": "+".join(boards),
               "tier": self.ctx.tier.name,
               "policy": spec.policy.name,
               "devices": self._effective_devices(),
               "links": spec.fleet.links, "completed": m.completed,
               "throughput": round(m.throughput, 2), "switches": m.switches,
               "makespan_s": round(m.makespan, 2),
               "avg_latency_s": round(m.avg_latency, 4),
               "stall_s": round(m.stall_time, 3),
               "placement": m.memory.get("placement", {}),
               "pcie_links": {name: ch.get("wait_time_s")
                              for name, ch in m.memory.get(
                                  "channels", {}).get("pcie_channels",
                                                      {}).items()},
               "peer_links": {name: ch.get("wait_time_s")
                              for name, ch in m.memory.get(
                                  "channels", {}).get("peer_channels",
                                                      {}).items()},
               "host_prefetch": m.memory.get("prefetch", {})}
        if m.decode:
            out["decode"] = m.decode
        if self.ctx.search_report is not None:
            out["placement_search"] = self.ctx.search_report
        return out

    def _real_requests(self) -> List[Request]:
        """The real-mode request stream (seed semantics: RandomState(1))."""
        coe = self.ctx.coe
        rng = np.random.RandomState(1)
        n_components = sum(1 for e in coe.experts if e.startswith("cls"))
        needs_det, det_assign = real_board_layout(
            n_components, sum(1 for e in coe.experts if e.startswith("det")))
        reqs = []
        for i in range(self.spec.workload.requests):
            c = int(rng.randint(n_components))
            reqs.append(Request(
                id=i, expert_id=f"cls{c:03d}",
                data={"component": c, "x": rng.randn(64).astype(np.float32),
                      "needs_detection": bool(needs_det[c]),
                      "det_expert": int(det_assign[c])}))
        return reqs

    def _run_real(self) -> dict:
        reqs = self._pending if self._pending else self._real_requests()
        m = self._metrics = run_real(self.system, reqs)
        out = {"mode": "real", "policy": self.spec.policy.name,
               "completed": m.completed,
               "throughput": round(m.throughput, 2), "switches": m.switches,
               "makespan_s": round(m.makespan, 3)}
        if m.decode:
            out["decode"] = m.decode
        return out

    # ------------------------------------------------------------------ #
    def _gateway(self, tenants):
        from repro.serve import (AdmissionConfig, AdmissionController,
                                 Autoscaler, AutoscalerConfig, OnlineGateway)

        spec = self.spec
        admission = None
        if spec.serving.admission != "none":
            mean_rate = sum(t.rate for t in tenants) / len(tenants)
            # the token bucket defaults its refill to the tenant mix's mean
            # per-tenant rate, so the policy actually bites under a burst
            bucket_rate = spec.serving.bucket_rate \
                if spec.serving.bucket_rate is not None else mean_rate
            admission = AdmissionController(AdmissionConfig(
                policy=spec.serving.admission,
                max_queue=spec.serving.max_queue,
                bucket_rate=bucket_rate,
                bucket_burst=spec.serving.bucket_burst))

        autoscaler = None
        single = POLICIES[spec.policy.name].assign == "single" \
            and spec.model.kind != "tiny"   # real engine: seed behaviour
        #                                     keeps the autoscaler wired
        fleet = len(self.system.executors)
        bounds = spec.serving.autoscale_bounds(fleet_size=fleet)
        # single-assign policies route everything to executor 0: scaling the
        # fleet could never receive work, so the autoscaler is disabled
        if bounds is not None and not single:
            if self.ctx.executor_specs is not None:
                scale_spec = self.ctx.executor_specs[0]
            else:   # tiny real system: rebuild the spec from executor 0
                ex0 = self.system.executors[0]
                scale_spec = ExecutorSpec("gpu", ex0.device_profile,
                                          ex0.batch_bytes, "gpu")
            autoscaler = Autoscaler(AutoscalerConfig(
                spec=scale_spec, min_executors=bounds[0],
                max_executors=bounds[1]))
        return OnlineGateway(self.system, tenants, admission=admission,
                             autoscaler=autoscaler,
                             slo_priority=spec.serving.slo_priority,
                             tick_interval=spec.serving.tick)

    def _run_online(self) -> dict:
        spec = self.spec
        tenants = self.ctx.tenants
        gw = self._gateway(tenants)
        self.report = gw.run(max_requests=spec.workload.requests)
        self._metrics = self.report.metrics
        out = {"mode": "online", "engine": "sim", "tier": self.ctx.tier.name,
               "policy": spec.policy.name,
               "devices": self._effective_devices(),
               "links": spec.fleet.links,
               "replication": spec.fleet.replication,
               "tenants": {t.name: {"board": t.board.name,
                                    "rate_rps": t.rate,
                                    "process": t.process,
                                    "slo_s": t.slo_seconds}
                           for t in tenants}}
        if self.ctx.search_report is not None:
            out["placement_search"] = self.ctx.search_report
        out.update(self.report.to_json())
        return out

    def _run_online_real(self) -> dict:
        """The online gateway over the RealEngine: actual JAX expert loads
        and jitted forwards advance the clock by measured wall time. The
        tiny local CoE's source always draws components uniformly at random,
        so the tenant is served (and reported) as request_class="random"."""
        from repro.serve import make_gaps

        spec = self.spec
        coe = self.ctx.coe
        tenant = dataclasses.replace(self.ctx.tenants[0],
                                     request_class="random")
        n_components = sum(1 for e in coe.experts if e.startswith("cls"))
        n_detection = sum(1 for e in coe.experts if e.startswith("det"))
        needs_det, det_assign = real_board_layout(n_components, n_detection)

        def source():
            rng = np.random.RandomState(tenant.seed)
            gaps = make_gaps(tenant.process, tenant.rate, rng)
            t = 0.0
            for i in range(spec.workload.requests):
                t += next(gaps)
                c = int(rng.randint(n_components))
                yield Request(
                    id=i, expert_id=f"cls{c:03d}", arrival_time=t,
                    task_id=tenant.name, tenant=tenant.name,
                    deadline=t + tenant.slo_seconds, root_arrival_time=t,
                    data={"component": c,
                          "x": rng.randn(64).astype(np.float32),
                          "needs_detection": bool(needs_det[c]),
                          "det_expert": int(det_assign[c])})

        gw = self._gateway([tenant])
        self.report = gw.run(source=source())
        self._metrics = self.report.metrics
        out = {"mode": "online", "engine": "real",
               "policy": spec.policy.name,
               "tenants": {tenant.name: {"rate_rps": tenant.rate,
                                         "process": tenant.process,
                                         "request_class":
                                             tenant.request_class,
                                         "slo_s": tenant.slo_seconds}}}
        out.update(self.report.to_json())
        return out
