"""The user-facing API: one declarative spec in, one serving system out.

    from repro.api import DeploymentSpec, Session, build_system

    spec = DeploymentSpec.load("examples/specs/sim.json")
    result = Session(spec).run()

``DeploymentSpec`` (repro.api.spec) is the single serializable description
of a deployment; ``build_system`` (repro.api.build) turns it into a wired
``CoServeSystem``; ``Session`` (repro.api.session) runs it and produces
metrics and artifacts; ``save_trace``/``load_trace`` and
``save_plan``/``load_plan`` (repro.api.artifacts) round-trip workload
traces and placement plans so searched configurations are reusable files,
not one-off in-memory state. ``repro.launch.serve`` is a thin CLI adapter
over this package.
"""
from repro.api.artifacts import load_plan, load_trace, save_plan, save_trace
from repro.api.build import (POLICIES, BuildContext, build_catalog,
                             build_context, build_layout, build_real_system,
                             build_system, make_requests, make_tenants,
                             resolve_policy, resolve_tier)
from repro.api.session import Session
from repro.api.spec import (BoardSection, DeploymentSpec, FleetSection,
                            MemorySection, ModelSpec, ObservabilitySection,
                            PolicySection, ServingSection, SpecError,
                            TenantSection, WorkloadSection)

__all__ = [
    "BoardSection", "BuildContext", "DeploymentSpec", "FleetSection",
    "MemorySection", "ModelSpec", "ObservabilitySection", "POLICIES",
    "PolicySection", "Session",
    "ServingSection", "SpecError", "TenantSection", "WorkloadSection",
    "build_catalog", "build_context", "build_layout", "build_real_system",
    "build_system", "load_plan", "load_trace", "make_requests",
    "make_tenants", "resolve_policy", "resolve_tier", "save_plan",
    "save_trace",
]
