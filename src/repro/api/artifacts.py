"""Run artifacts: workload traces and placement plans as JSON files.

Source of truth: the only file format for ``WorkloadTrace`` and
``PlacementPlan`` persistence (the objects own their ``to_dict`` /
``from_dict``; this module owns the envelope and the io). Closing ROADMAP
"Trace capture end-to-end": a serving run dumps the traffic it observed
(``Session.save_trace`` / ``serve --dump-trace``), the placement search
replays that file tomorrow (``fleet.trace_path``), and the searched plan
itself is saved (``Session.save_plan`` / ``serve --save-plan``) and applied
verbatim on the next launch (``fleet.placement="plan"``) — no re-search, no
re-derivation from static priors.

Every artifact is a small JSON envelope ``{"kind": ..., "version": 1,
"payload": {...}}`` so loading the wrong file kind fails with a message
instead of a KeyError.
"""
from __future__ import annotations

import json
from typing import TYPE_CHECKING, Mapping, Optional

from repro.fleet import PlacementPlan, WorkloadTrace

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.coe import CoEModel

TRACE_KIND = "coserve.workload_trace"
PLAN_KIND = "coserve.placement_plan"
ARTIFACT_VERSION = 1


def _dump(kind: str, payload: dict, path: str):
    with open(path, "w") as f:
        json.dump({"kind": kind, "version": ARTIFACT_VERSION,
                   "payload": payload}, f, indent=2, sort_keys=True)
        f.write("\n")


def _read(kind: str, path: str) -> dict:
    try:
        with open(path) as f:
            d = json.load(f)
    except OSError as e:
        what = "trace" if kind == TRACE_KIND else "plan"
        raise ValueError(
            f"cannot read {what} artifact {path}: {e.strerror or e} — "
            f"{what}s are written by "
            f"{'save_trace/--dump-trace' if kind == TRACE_KIND else 'save_plan/--save-plan'}"
        ) from None
    except json.JSONDecodeError as e:
        raise ValueError(f"{path} is not valid JSON: {e}") from None
    got = d.get("kind") if isinstance(d, dict) else None
    if got != kind:
        raise ValueError(
            f"{path} is not a {kind!r} artifact (found kind={got!r}) — "
            "traces come from save_trace/--dump-trace, plans from "
            "save_plan/--save-plan")
    if d.get("version") != ARTIFACT_VERSION:
        raise ValueError(
            f"{path}: artifact schema v{d.get('version')!r}, this build "
            f"reads v{ARTIFACT_VERSION}")
    return d["payload"]


# --------------------------------------------------------------------------- #
def save_trace(trace: WorkloadTrace, path: str):
    """Persist a workload trace (observed traffic or expected chains)."""
    _dump(TRACE_KIND, trace.to_dict(), path)


def load_trace(path: str) -> WorkloadTrace:
    return WorkloadTrace.from_dict(_read(TRACE_KIND, path))


def save_plan(plan: PlacementPlan, path: str):
    """Persist a placement plan (searched or greedy) with its pool shape."""
    _dump(PLAN_KIND, plan.to_dict(), path)


def load_plan(path: str, coe: "CoEModel",
              capacities: Optional[Mapping[str, int]] = None
              ) -> PlacementPlan:
    """Rebuild a saved plan against ``coe``; when ``capacities`` is given
    (the pools of the fleet about to apply it), a shape mismatch fails with
    a re-search hint instead of silently misplacing experts."""
    try:
        return PlacementPlan.from_dict(coe, _read(PLAN_KIND, path),
                                       capacities=capacities)
    except ValueError as e:
        raise ValueError(f"{path}: {e}") from None
