"""DeploymentSpec: one declarative, serializable config for every run.

Source of truth: the only user-facing description of a CoServe deployment —
what model catalog to serve (``ModelSpec``), on what fleet shape
(``FleetSection``), over which storage hierarchy (``MemorySection``), with
which scheduling/eviction policy (``PolicySection``), through which serving
mode (``ServingSection``), under what traffic (``WorkloadSection``).
``repro.api.build.build_system`` turns a spec into a ``CoServeSystem``;
``repro.api.session.Session`` runs it; ``repro.launch.serve`` is a thin
flag -> spec adapter on top.

Design contract (pinned by tests):

  * frozen dataclasses, validated eagerly — a constructed spec is a valid
    spec, and every validation error says which field and what to do;
  * lossless serialization — ``DeploymentSpec.from_dict(s.to_dict()) == s``
    for any spec, and ``save``/``load`` round-trips through JSON byte-stably,
    so a run's full configuration is a reproducible, diffable artifact
    (the SN40L "whole allocation as one compiled artifact" argument);
  * strict parsing — unknown keys are rejected with the known-key list, so
    a typo'd field fails loudly instead of silently using a default.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Mapping, Optional, Tuple

from repro.memory.policies import POLICY_NAMES
from repro.memory.tiers import LINK_MODES
from repro.obs.tracer import DEFAULT_CAPACITY, TRACE_LEVELS
from repro.serve.arrivals import PROCESSES, REQUEST_CLASSES

MODES = ("sim", "real", "online")
ENGINES = ("sim", "real")
MODEL_KINDS = ("board", "tenants", "tiny")
TIER_PRESETS = ("numa", "uma", "tpu_v5e")
PREFETCH_MODES = (None, "off", "device", "all")
PREFETCH_TRIGGERS = (None, "exec", "queue")
PLACEMENTS = ("greedy", "search", "plan")
ADMISSIONS = ("none", "queue_depth", "deadline", "token_bucket")
POLICY_PRESETS = ("coserve", "coserve_none", "samba", "samba_fifo",
                  "samba_parallel")
PRESET_BOARD_NAMES = ("A", "B")

SCHEMA_VERSION = 1


class SpecError(ValueError):
    """A DeploymentSpec field (or combination) is invalid. The message
    always names the offending ``section.field`` and what to change."""


def _check(cond: bool, where: str, msg: str):
    if not cond:
        raise SpecError(f"{where}: {msg}")


def _choice(value, where: str, choices):
    shown = [c for c in choices if c is not None]
    _check(value in choices, where,
           f"got {value!r}, expected one of {shown}"
           + (" (or omit it)" if None in choices else ""))


# --------------------------------------------------------------------------- #
# serialization machinery (shared by every section)
# --------------------------------------------------------------------------- #

def _to_jsonable(obj):
    if dataclasses.is_dataclass(obj):
        return {f.name: _to_jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, (tuple, list)):
        return [_to_jsonable(v) for v in obj]
    return obj


# field -> cast applied on from_dict (None values pass through untouched for
# Optional fields; nested sections declare their class instead of a cast)
_CASTS = {int: lambda v: int(v), float: lambda v: float(v),
          str: lambda v: str(v), bool: lambda v: bool(v)}


def _section_from_dict(cls, d: Mapping, where: str):
    """Strict dict -> section: unknown keys fail with the known-key list,
    missing keys take the field default, scalars are cast to the declared
    type (so hand-written JSON ``25`` satisfies a float field)."""
    if not isinstance(d, Mapping):
        raise SpecError(f"{where}: expected an object, got {type(d).__name__}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - set(fields))
    if unknown:
        raise SpecError(
            f"{where}: unknown key(s) {unknown} — known keys: "
            f"{sorted(fields)}")
    kwargs = {}
    types = cls._FIELD_TYPES
    for name, value in d.items():
        spec_t = types[name]
        path = f"{where}.{name}"
        if value is None:
            kwargs[name] = None
            continue
        if isinstance(spec_t, tuple):          # (element_type,): tuple field
            elem = spec_t[0]
            if not isinstance(value, (list, tuple)):
                raise SpecError(f"{path}: expected a list")
            if dataclasses.is_dataclass(elem):
                kwargs[name] = tuple(
                    _section_from_dict(elem, v, f"{path}[{i}]")
                    for i, v in enumerate(value))
            else:
                try:
                    kwargs[name] = tuple(_CASTS[elem](v) for v in value)
                except (TypeError, ValueError):
                    raise SpecError(
                        f"{path}: expected a list of "
                        f"{elem.__name__}") from None
        elif dataclasses.is_dataclass(spec_t):
            kwargs[name] = _section_from_dict(spec_t, value, path)
        else:
            try:
                kwargs[name] = _CASTS[spec_t](value)
            except (TypeError, ValueError):
                raise SpecError(
                    f"{path}: cannot read {value!r} as "
                    f"{spec_t.__name__}") from None
    try:
        return cls(**kwargs)
    except SpecError:
        raise
    except (TypeError, ValueError) as e:
        raise SpecError(f"{where}: {e}") from None


class _Section:
    """Shared to_dict/from_dict surface. Subclasses set ``_FIELD_TYPES``:
    field -> python scalar type, nested section class, or 1-tuple of the
    element class for tuple-of-section fields."""
    _FIELD_TYPES: Dict[str, object] = {}

    def to_dict(self) -> dict:
        return _to_jsonable(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "_Section":
        return _section_from_dict(cls, d, cls.__name__)


# --------------------------------------------------------------------------- #
# sections
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class BoardSection(_Section):
    """A custom circuit-board catalog (mirrors ``workload.BoardSpec``) —
    declared once under ``model.boards`` and referenced by name from
    ``model.board`` or ``workload.tenants[].board``."""
    name: str
    n_components: int
    n_active: int = 120
    avg_quantity: float = 3.0
    n_detection: int = 24
    detection_fraction: float = 0.4
    ok_prob: float = 0.95
    zipf_s: float = 1.1

    _FIELD_TYPES = {"name": str, "n_components": int, "n_active": int,
                    "avg_quantity": float, "n_detection": int,
                    "detection_fraction": float, "ok_prob": float,
                    "zipf_s": float}

    def __post_init__(self):
        _check(bool(self.name), "model.boards[].name", "must be non-empty")
        _check(self.name not in PRESET_BOARD_NAMES, "model.boards[].name",
               f"{self.name!r} shadows the built-in board "
               f"{PRESET_BOARD_NAMES} — pick another name")
        _check(self.n_components >= 1, f"model.boards[{self.name}]",
               "n_components must be >= 1")
        _check(1 <= self.n_active <= self.n_components,
               f"model.boards[{self.name}]",
               f"n_active must be in [1, n_components={self.n_components}]")
        _check(self.n_detection >= 1, f"model.boards[{self.name}]",
               "n_detection must be >= 1")


@dataclasses.dataclass(frozen=True)
class ModelSpec(_Section):
    """What expert catalog to serve.

    ``kind="board"``   one circuit board (``board`` names a preset A/B or a
                       custom entry in ``boards``) — the paper's sim workload.
    ``kind="tenants"`` the union catalog of every ``workload.tenants[]``
                       board, usage-weighted by tenant rate (or by
                       ``tenant_weights`` when the provisioning assumption
                       deliberately differs from the traffic).
    ``kind="tiny"``    the small real-JAX MLP catalog (host/disk tiers,
                       jitted forwards) — ``--mode real`` / ``--engine real``.
    """
    kind: str = "board"
    board: str = "A"
    boards: Tuple[BoardSection, ...] = ()
    tenant_weights: Tuple[float, ...] = ()   # kind="tenants": provisioning
    #                                          weights; empty = tenant rates
    # kind="tiny" catalog knobs (defaults = launch.serve real mode)
    tiny_components: int = 24
    tiny_detection: int = 4
    tiny_pool_experts: int = 6
    tiny_executors: int = 2
    tiny_d_hidden: int = 256

    _FIELD_TYPES = {"kind": str, "board": str, "boards": (BoardSection,),
                    "tenant_weights": (float,), "tiny_components": int,
                    "tiny_detection": int, "tiny_pool_experts": int,
                    "tiny_executors": int, "tiny_d_hidden": int}

    def __post_init__(self):
        _choice(self.kind, "model.kind", MODEL_KINDS)
        names = [b.name for b in self.boards]
        _check(len(names) == len(set(names)), "model.boards",
               f"duplicate board names in {names}")
        for f in ("tiny_components", "tiny_detection", "tiny_pool_experts",
                  "tiny_executors", "tiny_d_hidden"):
            _check(getattr(self, f) >= 1, f"model.{f}", "must be >= 1")
        object.__setattr__(self, "tenant_weights",
                           tuple(float(w) for w in self.tenant_weights))
        _check(all(w > 0 for w in self.tenant_weights),
               "model.tenant_weights", "weights must be positive")

    def board_names(self) -> Tuple[str, ...]:
        return tuple(b.name for b in self.boards) + PRESET_BOARD_NAMES


@dataclasses.dataclass(frozen=True)
class FleetSection(_Section):
    """Fleet shape + expert placement policy (``repro.fleet``)."""
    devices: int = 1
    gpu_per_device: int = 3
    cpu: int = 1
    links: str = "shared"            # shared | per-device
    replication: int = 0             # planned copies of the hottest experts
    peer_bw_gbps: float = 0.0        # NVLink/ICI-class pool->pool fabric
    placement: str = "greedy"        # greedy | search | plan
    trace_path: str = ""             # search: replay this saved WorkloadTrace
    #                                  instead of deriving one from the spec
    plan_path: str = ""              # plan: apply this saved PlacementPlan

    _FIELD_TYPES = {"devices": int, "gpu_per_device": int, "cpu": int,
                    "links": str, "replication": int, "peer_bw_gbps": float,
                    "placement": str, "trace_path": str, "plan_path": str}

    def __post_init__(self):
        _check(self.devices >= 1, "fleet.devices", "must be >= 1")
        _check(self.gpu_per_device >= 0 and self.cpu >= 0,
               "fleet.gpu_per_device/cpu", "executor counts must be >= 0")
        _choice(self.links, "fleet.links", LINK_MODES)
        _check(self.replication >= 0, "fleet.replication", "must be >= 0")
        _check(self.peer_bw_gbps >= 0, "fleet.peer_bw_gbps", "must be >= 0")
        _choice(self.placement, "fleet.placement", PLACEMENTS)
        _check(not (self.placement == "plan" and not self.plan_path),
               "fleet.plan_path",
               'placement="plan" needs the path of a saved placement plan '
               "(repro.api.save_plan / serve --save-plan)")
        _check(not (self.plan_path and self.placement != "plan"),
               "fleet.plan_path",
               f'only read when placement="plan" (got '
               f'placement={self.placement!r}) — remove it or switch')
        _check(not (self.trace_path and self.placement != "search"),
               "fleet.trace_path",
               f'only read when placement="search" (got '
               f'placement={self.placement!r}) — remove it or switch')

    def is_default_shape(self) -> bool:
        """True when no fleet/placement knob deviates from the single-device
        shared-link paper topology (the only shape real engines support)."""
        return (self.devices == 1 and self.links == "shared"
                and not self.replication and not self.peer_bw_gbps
                and self.placement == "greedy")


@dataclasses.dataclass(frozen=True)
class MemorySection(_Section):
    """Storage-hierarchy numbers + cross-tier prefetch behaviour. ``tier``
    names a preset (numa | uma | tpu_v5e); any explicit field overrides the
    preset's value (``repro.memory.TierSpec``). Bandwidths are bytes/sec."""
    tier: str = "numa"
    name: str = ""                         # override TierSpec.name
    disk_bw: Optional[float] = None
    host_to_device_bw: Optional[float] = None
    host_overhead: Optional[float] = None
    disk_overhead: Optional[float] = None
    host_cache_bytes: Optional[int] = None
    device_bytes: Optional[int] = None
    unified: Optional[bool] = None
    prefetch: Optional[str] = None         # off | device | all | None=policy
    prefetch_trigger: Optional[str] = None  # exec | queue | None=policy

    _FIELD_TYPES = {"tier": str, "name": str, "disk_bw": float,
                    "host_to_device_bw": float, "host_overhead": float,
                    "disk_overhead": float, "host_cache_bytes": int,
                    "device_bytes": int, "unified": bool, "prefetch": str,
                    "prefetch_trigger": str}

    def __post_init__(self):
        _choice(self.tier, "memory.tier", TIER_PRESETS)
        _choice(self.prefetch, "memory.prefetch", PREFETCH_MODES)
        _choice(self.prefetch_trigger, "memory.prefetch_trigger",
                PREFETCH_TRIGGERS)
        for f in ("disk_bw", "host_to_device_bw"):
            v = getattr(self, f)
            _check(v is None or v > 0, f"memory.{f}", "must be positive")
        for f in ("host_overhead", "disk_overhead", "host_cache_bytes",
                  "device_bytes"):
            v = getattr(self, f)
            _check(v is None or v >= 0, f"memory.{f}", "must be >= 0")


@dataclasses.dataclass(frozen=True)
class PolicySection(_Section):
    """System policy: a named preset (paper systems) + targeted overrides."""
    name: str = "coserve"
    evict: Optional[str] = None      # eviction policy override (e.g.
    #                                  "observed": rank victims by live load)

    _FIELD_TYPES = {"name": str, "evict": str}

    def __post_init__(self):
        _choice(self.name, "policy.name", POLICY_PRESETS)
        _choice(self.evict, "policy.evict", (None,) + POLICY_NAMES)


@dataclasses.dataclass(frozen=True)
class HeteroSection(_Section):
    """Heterogeneous CPU co-execution: host-DRAM-resident experts execute in
    place on the CPU executors instead of stalling on a disk/PCIe load, and
    the scheduler prices min(execute_on_host, load_then_execute_on_device)
    per arrival. Off by default — every cost and decision stream is then
    bit-identical to the cache-only host tier."""
    host_exec: bool = False          # run host-resident experts on the CPU
    cpu_multiplier: float = 0.0      # sim: derive the CPU service-time model
    #                                  as device-time x this (0 = the static
    #                                  measured CPU constants; real mode
    #                                  measures via run_batch_cpu instead)
    host_place: bool = False         # placement search may plan deliberate
    #                                  CPU residents (the host_place move);
    #                                  needs fleet.placement="search"

    _FIELD_TYPES = {"host_exec": bool, "cpu_multiplier": float,
                    "host_place": bool}

    def __post_init__(self):
        _check(self.cpu_multiplier >= 0, "hetero.cpu_multiplier",
               "must be >= 0 (0 uses the static CPU constants)")
        _check(not (self.host_place and not self.host_exec),
               "hetero.host_place",
               "planning deliberate CPU residents only pays off when they "
               "can execute in place — set hetero.host_exec=true too")


@dataclasses.dataclass(frozen=True)
class DecodeSection(_Section):
    """Token-level continuous batching with paged KV residency
    (``repro.core.decode``). Off by default — every decision stream and
    metric is then bit-identical to the stage-level simulation. When on,
    a request's terminal stage becomes prefill + a per-token decode loop,
    and its KV blocks occupy device bytes next to expert weights."""
    enabled: bool = False
    tokens: int = 24                 # mean generated tokens per request
    tokens_dist: str = "fixed"       # fixed | geometric
    block_tokens: int = 16           # tokens per paged KV block
    token_bytes: int = 262144        # KV bytes per token across layers
    kv_budget_fraction: float = 0.5  # max pool fraction KV may occupy
    kv_evict: str = "kv_aware"       # kv_aware | weight_only
    max_decode_batch: int = 8        # continuous-batch membership cap
    step_k: float = 0.002            # per-member seconds per decode step
    step_b: float = 0.0005           # fixed per-step overhead seconds

    _FIELD_TYPES = {"enabled": bool, "tokens": int, "tokens_dist": str,
                    "block_tokens": int, "token_bytes": int,
                    "kv_budget_fraction": float, "kv_evict": str,
                    "max_decode_batch": int, "step_k": float,
                    "step_b": float}

    def __post_init__(self):
        _check(self.tokens >= 1, "decode.tokens", "must be >= 1")
        _choice(self.tokens_dist, "decode.tokens_dist",
                ("fixed", "geometric"))
        _check(self.block_tokens >= 1, "decode.block_tokens", "must be >= 1")
        _check(self.token_bytes >= 1, "decode.token_bytes", "must be >= 1")
        _check(0 < self.kv_budget_fraction <= 1,
               "decode.kv_budget_fraction", "must be in (0, 1]")
        _choice(self.kv_evict, "decode.kv_evict",
                ("kv_aware", "weight_only"))
        _check(self.max_decode_batch >= 1, "decode.max_decode_batch",
               "must be >= 1")
        _check(self.step_k >= 0, "decode.step_k", "must be >= 0")
        _check(self.step_b >= 0, "decode.step_b", "must be >= 0")


@dataclasses.dataclass(frozen=True)
class ServingSection(_Section):
    """How requests reach the system: batch sim, real JAX execution, or the
    streaming online gateway with admission/SLO/autoscaling."""
    mode: str = "sim"                # sim | real | online
    engine: str = "sim"              # online mode: sim | real
    admission: str = "none"          # none | queue_depth | deadline |
    #                                  token_bucket
    max_queue: int = 200
    bucket_rate: Optional[float] = None
    bucket_burst: float = 50.0
    autoscale: str = "auto"          # "min,max" | "auto" | "none"
    slo_priority: bool = True
    tick: float = 0.5

    _FIELD_TYPES = {"mode": str, "engine": str, "admission": str,
                    "max_queue": int, "bucket_rate": float,
                    "bucket_burst": float, "autoscale": str,
                    "slo_priority": bool, "tick": float}

    def __post_init__(self):
        _choice(self.mode, "serving.mode", MODES)
        _choice(self.engine, "serving.engine", ENGINES)
        _choice(self.admission, "serving.admission", ADMISSIONS)
        _check(self.max_queue >= 1, "serving.max_queue", "must be >= 1")
        _check(self.bucket_rate is None or self.bucket_rate > 0,
               "serving.bucket_rate", "must be positive")
        _check(self.bucket_burst > 0, "serving.bucket_burst",
               "must be positive")
        _check(self.tick > 0, "serving.tick", "must be positive")
        self.autoscale_bounds(fleet_size=1)   # eager format check

    def autoscale_bounds(self, fleet_size: int):
        """(min, max) executors, or None when scaling is disabled."""
        if self.autoscale == "none":
            return None
        if self.autoscale == "auto":
            return (fleet_size, 2 * fleet_size)
        try:
            lo, hi = map(int, self.autoscale.split(","))
        except ValueError:
            raise SpecError(
                f"serving.autoscale: expected 'min,max', 'auto' or 'none', "
                f"got {self.autoscale!r}") from None
        _check(0 < lo <= hi, "serving.autoscale",
               f"need 0 < min <= max, got {lo},{hi}")
        return (lo, hi)


@dataclasses.dataclass(frozen=True)
class TenantSection(_Section):
    """One traffic source (mirrors ``repro.serve.TenantSpec``). ``seed``
    defaults to the spec-level seed plus the tenant's position."""
    name: str
    board: str = "A"
    rate: float = 25.0
    arrival: str = "poisson"         # poisson | bursty | diurnal | step
    request_class: str = "scan"      # scan | random
    slo_seconds: float = 2.0
    seed: Optional[int] = None

    _FIELD_TYPES = {"name": str, "board": str, "rate": float, "arrival": str,
                    "request_class": str, "slo_seconds": float, "seed": int}

    def __post_init__(self):
        _check(bool(self.name), "workload.tenants[].name",
               "must be non-empty")
        _choice(self.arrival, f"workload.tenants[{self.name}].arrival",
                PROCESSES)
        _choice(self.request_class,
                f"workload.tenants[{self.name}].request_class",
                REQUEST_CLASSES)
        _check(self.rate > 0, f"workload.tenants[{self.name}].rate",
               "must be positive")
        _check(self.slo_seconds > 0,
               f"workload.tenants[{self.name}].slo_seconds",
               "must be positive")


@dataclasses.dataclass(frozen=True)
class WorkloadSection(_Section):
    """Offered traffic: total request budget, the sim-mode arrival cadence,
    and the online tenant mix."""
    requests: int = 2500
    interval_s: float = 0.004        # sim-mode inter-arrival (paper: 4 ms)
    tenants: Tuple[TenantSection, ...] = ()

    _FIELD_TYPES = {"requests": int, "interval_s": float,
                    "tenants": (TenantSection,)}

    def __post_init__(self):
        _check(self.requests >= 1, "workload.requests", "must be >= 1")
        _check(self.interval_s > 0, "workload.interval_s", "must be positive")
        names = [t.name for t in self.tenants]
        _check(len(names) == len(set(names)), "workload.tenants",
               f"duplicate tenant names in {names} — per-tenant SLOs and "
               "telemetry are keyed by name")


@dataclasses.dataclass(frozen=True)
class ObservabilitySection(_Section):
    """Flight-recorder settings (``repro.obs``). ``trace="summary"`` records
    memory-system events (loads/evictions/transfers/sheds/scales);
    ``"full"`` adds per-request events (assign/sched/exec/admit), enough to
    reconstruct per-request timelines. ``trace_path`` auto-exports the ring
    buffer as Chrome trace JSON after ``Session.run``."""
    trace: str = "off"               # off | summary | full
    buffer_events: int = DEFAULT_CAPACITY   # ring-buffer capacity
    trace_path: str = ""             # export target ("" = no auto-export)
    sanitize: bool = False           # cachesan: shadow-validate the
    #                                  epoch-guarded caches against naive
    #                                  recompute (debug; see docs/analysis.md)

    _FIELD_TYPES = {"trace": str, "buffer_events": int, "trace_path": str,
                    "sanitize": bool}

    def __post_init__(self):
        _choice(self.trace, "observability.trace", TRACE_LEVELS)
        _check(self.buffer_events >= 1, "observability.buffer_events",
               "must be >= 1")
        _check(not (self.trace_path and self.trace == "off"),
               "observability.trace_path",
               'set trace="summary" or "full" to record events '
               "(trace_path has nothing to export at trace=\"off\")")


# --------------------------------------------------------------------------- #
# the spec
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class DeploymentSpec(_Section):
    """One deployment, declaratively. See docs/configuration.md for the
    full schema and one annotated example per mode."""
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    fleet: FleetSection = dataclasses.field(default_factory=FleetSection)
    memory: MemorySection = dataclasses.field(default_factory=MemorySection)
    policy: PolicySection = dataclasses.field(default_factory=PolicySection)
    serving: ServingSection = dataclasses.field(
        default_factory=ServingSection)
    workload: WorkloadSection = dataclasses.field(
        default_factory=WorkloadSection)
    observability: ObservabilitySection = dataclasses.field(
        default_factory=ObservabilitySection)
    hetero: HeteroSection = dataclasses.field(default_factory=HeteroSection)
    decode: DecodeSection = dataclasses.field(default_factory=DecodeSection)
    seed: int = 0
    version: int = SCHEMA_VERSION

    _FIELD_TYPES = {"model": ModelSpec, "fleet": FleetSection,
                    "memory": MemorySection, "policy": PolicySection,
                    "serving": ServingSection, "workload": WorkloadSection,
                    "observability": ObservabilitySection,
                    "hetero": HeteroSection, "decode": DecodeSection,
                    "seed": int, "version": int}

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        _check(self.version == SCHEMA_VERSION, "version",
               f"this build reads DeploymentSpec schema v{SCHEMA_VERSION}, "
               f"got v{self.version}")
        mode, engine = self.serving.mode, self.serving.engine
        kind = self.model.kind
        real_exec = mode == "real" or (mode == "online" and engine == "real")

        if mode == "sim":
            _check(kind in ("board", "tenants"), "model.kind",
                   f'serving.mode="sim" serves a board catalog — use '
                   f'kind="board" (or "tenants" for a multi-board catalog '
                   f'driven by workload.tenants), got {kind!r}')
        elif mode == "real":
            _check(kind == "tiny", "model.kind",
                   f'serving.mode="real" runs the tiny real-JAX catalog — '
                   f'set kind="tiny", got {kind!r}')
        elif engine == "sim":
            _check(kind == "tenants", "model.kind",
                   f'serving.mode="online" with the sim engine serves the '
                   f'tenant mix — set kind="tenants", got {kind!r}')
        else:
            _check(kind == "tiny", "model.kind",
                   f'serving.engine="real" serves the tiny real-JAX catalog '
                   f'— set kind="tiny", got {kind!r}')

        if kind == "tenants" or (mode == "online" and engine == "real"):
            _check(len(self.workload.tenants) >= 1, "workload.tenants",
                   "this mode needs at least one tenant")
        if mode == "online" and engine == "real":
            _check(len(self.workload.tenants) == 1, "workload.tenants",
                   'serving.engine="real" serves a single tenant over the '
                   "tiny local CoE (multi-tenant mixes need the sim engine)")
        _check(not (real_exec and not self.fleet.is_default_shape()),
               "fleet",
               "devices/links/replication/peer_bw_gbps/placement drive the "
               'simulated fleet; serving.mode="real" and engine="real" run '
               "the single-device shared-link topology")

        if self.hetero.host_exec and kind != "tiny":
            _check(self.fleet.cpu >= 1, "hetero.host_exec",
                   "host co-execution needs at least one CPU executor — "
                   f"set fleet.cpu >= 1 (got {self.fleet.cpu})")
            _check(self.policy.name not in ("samba", "samba_fifo"),
                   "hetero.host_exec",
                   f"the single-executor baseline {self.policy.name!r} "
                   "normalizes to one device executor and can never route "
                   "to the CPU — use a multi-executor policy")
        _check(not (self.hetero.host_place
                    and self.fleet.placement != "search"),
               "hetero.host_place",
               "deliberate CPU residents are planned by the placement "
               f'search — set fleet.placement="search" (got '
               f"{self.fleet.placement!r})")

        _check(not (self.decode.enabled and mode == "online"),
               "decode.enabled",
               "token-level decode drives the offline simulator and the "
               'real engine — serving.mode="online" stays stage-level '
               "(the gateway's admission/SLO anchors are per-stage)")

        known = self.model.board_names()
        if kind == "board":
            _check(self.model.board in known, "model.board",
                   f"unknown board {self.model.board!r} — declare it under "
                   f"model.boards or use one of {list(known)}")
        if kind == "tenants":
            for t in self.workload.tenants:
                _check(t.board in known,
                       f"workload.tenants[{t.name}].board",
                       f"unknown board {t.board!r} — declare it under "
                       f"model.boards or use one of {list(known)}")
            _check(not self.model.tenant_weights
                   or len(self.model.tenant_weights)
                   == len(self.workload.tenants),
                   "model.tenant_weights",
                   f"got {len(self.model.tenant_weights)} weights for "
                   f"{len(self.workload.tenants)} tenants — one per tenant "
                   "(or empty to weight by tenant rates)")

    # ------------------------------------------------------------------ #
    def tenant_seed(self, index: int) -> int:
        t = self.workload.tenants[index]
        return t.seed if t.seed is not None else self.seed + index

    # ------------------------------------------------------------------ #
    def save(self, path: str):
        """Write the spec as stable, diffable JSON (sorted keys)."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "DeploymentSpec":
        try:
            with open(path) as f:
                d = json.load(f)
        except OSError as e:
            raise SpecError(
                f"cannot read spec file {path}: {e.strerror or e} — "
                "create one with serve --dump-config or "
                "DeploymentSpec.save") from None
        except json.JSONDecodeError as e:
            raise SpecError(f"{path} is not valid JSON: {e}") from None
        try:
            return cls.from_dict(d)
        except SpecError as e:
            raise SpecError(f"{path}: {e}") from None
