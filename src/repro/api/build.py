"""build_system: one DeploymentSpec in, one wired CoServeSystem out.

Source of truth: the only constructor wiring from a declarative spec to
running objects — tier resolution, catalog construction, fleet layout,
policy overrides, placement (greedy sweep, cost-model search, or a saved
plan artifact). ``launch.serve``, the benchmark suites and the examples all
build through here instead of hand-wiring
``CoServeSystem``/``FleetSpec``/``MemoryHierarchy`` their own way; the
flag-for-flag equivalence with the pre-spec wiring is pinned by
``tests/test_deployment_spec.py``.
"""
from __future__ import annotations

import dataclasses
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.api.artifacts import load_plan, load_trace
from repro.api.spec import DeploymentSpec, SpecError
from repro.core.coe import CoEModel, ExpertSpec, Request, RoutingModule
from repro.core.decode import DecodeConfig
from repro.core.profiler import DeviceProfile, microbenchmark_arch
from repro.core.serving import (COSERVE, COSERVE_NONE, SAMBA, SAMBA_FIFO,
                                SAMBA_PARALLEL, CoServeSystem, ExecutorSpec,
                                SystemPolicy)
from repro.core.workload import (BOARD_A, BOARD_B, BoardSpec, build_board_coe,
                                 make_executor_specs, make_task_requests)
from repro.fleet import (FleetSpec, PlacementPlan, SearchConfig, build_fleet,
                         search_placement, trace_from_requests,
                         trace_from_usage, validate_pool_groups)
from repro.memory import NUMA, TPU_V5E, UMA, TierSpec
from repro.obs import NULL_TRACER, Tracer

POLICIES: Dict[str, SystemPolicy] = {
    "coserve": COSERVE,
    "coserve_none": COSERVE_NONE,
    "samba": SAMBA,
    "samba_fifo": SAMBA_FIFO,
    "samba_parallel": SAMBA_PARALLEL,
}

_TIER_PRESETS = {"numa": NUMA, "uma": UMA, "tpu_v5e": TPU_V5E}

_TIER_OVERRIDES = ("disk_bw", "host_to_device_bw", "host_overhead",
                   "disk_overhead", "host_cache_bytes", "device_bytes",
                   "unified")


# --------------------------------------------------------------------------- #
# resolution: spec sections -> concrete objects
# --------------------------------------------------------------------------- #

def resolve_tier(spec: DeploymentSpec) -> TierSpec:
    """The run's TierSpec: the named preset, any explicit memory-section
    overrides, plus the peer (NVLink/ICI-class) fabric from
    ``fleet.peer_bw_gbps``."""
    tier = _TIER_PRESETS[spec.memory.tier]
    changes = {f: getattr(spec.memory, f) for f in _TIER_OVERRIDES
               if getattr(spec.memory, f) is not None}
    if spec.memory.name:
        changes["name"] = spec.memory.name
    if changes:
        tier = dataclasses.replace(tier, **changes)
    if spec.fleet.peer_bw_gbps:
        tier = dataclasses.replace(tier, peer_bw=spec.fleet.peer_bw_gbps * 1e9)
    return tier


def resolve_policy(spec: DeploymentSpec) -> SystemPolicy:
    """Named preset + the memory-section prefetch overrides + the eviction
    override (``off``/``device``/``all`` semantics match the old
    ``--prefetch`` flag exactly)."""
    policy = POLICIES[spec.policy.name]
    mode = spec.memory.prefetch
    if mode == "off":
        policy = dataclasses.replace(policy, prefetch=False,
                                     host_prefetch=False)
    elif mode == "device":
        policy = dataclasses.replace(policy, host_prefetch=False)
    elif mode == "all":
        policy = dataclasses.replace(policy, prefetch=True,
                                     host_prefetch=True)
    if spec.memory.prefetch_trigger is not None:
        policy = dataclasses.replace(
            policy, prefetch_trigger=spec.memory.prefetch_trigger)
    if spec.policy.evict is not None:
        policy = dataclasses.replace(policy, evict=spec.policy.evict)
    if spec.hetero.host_exec:
        policy = dataclasses.replace(policy, host_exec=True)
    return policy


def resolve_decode(spec: DeploymentSpec) -> Optional[DecodeConfig]:
    """The run's DecodeConfig, or None for stage-level serving. The token
    sampler is seeded from the spec seed so decode-on runs replay exactly."""
    d = spec.decode
    if not d.enabled:
        return None
    return DecodeConfig(tokens=d.tokens, tokens_dist=d.tokens_dist,
                        block_tokens=d.block_tokens,
                        token_bytes=d.token_bytes,
                        kv_budget_fraction=d.kv_budget_fraction,
                        kv_evict=d.kv_evict,
                        max_decode_batch=d.max_decode_batch,
                        step_k=d.step_k, step_b=d.step_b, seed=spec.seed)


def board_specs(spec: DeploymentSpec) -> Dict[str, BoardSpec]:
    """Every board the spec may reference: customs + the A/B presets."""
    boards = {b.name: BoardSpec(**b.to_dict()) for b in spec.model.boards}
    boards.setdefault("A", BOARD_A)
    boards.setdefault("B", BOARD_B)
    return boards


def make_tenants(spec: DeploymentSpec):
    """``repro.serve.TenantSpec`` objects for the workload's tenant mix,
    with per-tenant seeds derived from the spec seed unless pinned."""
    from repro.serve import TenantSpec

    boards = board_specs(spec)
    return [TenantSpec(name=t.name, board=boards[t.board], rate=t.rate,
                       process=t.arrival, request_class=t.request_class,
                       slo_seconds=t.slo_seconds, seed=spec.tenant_seed(i))
            for i, t in enumerate(spec.workload.tenants)]


def build_catalog(spec: DeploymentSpec) -> CoEModel:
    """The expert catalog (sim engines): one board, or the usage-weighted
    union of the tenant boards. ``kind="tiny"`` catalogs are built together
    with their real engine in ``build_real_system``."""
    if spec.model.kind == "board":
        return build_board_coe(board_specs(spec)[spec.model.board])
    if spec.model.kind == "tenants":
        from repro.serve.arrivals import merge_board_coe

        boards = board_specs(spec)
        weights = list(spec.model.tenant_weights) \
            or [t.rate for t in spec.workload.tenants]
        return merge_board_coe([boards[t.board]
                                for t in spec.workload.tenants], weights)
    raise SpecError('model.kind="tiny" catalogs are built by '
                    "build_real_system (they need a real engine)")


def build_layout(spec: DeploymentSpec, tier: TierSpec
                 ) -> Tuple[Dict[str, int], List[ExecutorSpec]]:
    """(pools, executor specs) for the spec's fleet shape. Single-assign
    policies (the Samba baselines) normalize to one executor on one device,
    exactly like the old CLI: building a fleet for a baseline that only ever
    uses executors[0] would distort the comparison."""
    n_gpu, n_cpu = spec.fleet.gpu_per_device, spec.fleet.cpu
    devices = spec.fleet.devices
    if POLICIES[spec.policy.name].assign == "single":
        n_gpu, n_cpu, devices = 1, 0, 1
    mult = spec.hetero.cpu_multiplier
    if devices > 1:
        fleet = FleetSpec(n_devices=devices, gpu_per_device=n_gpu,
                          n_cpu=n_cpu, links=spec.fleet.links)
        return build_fleet(tier, fleet, cpu_multiplier=mult)
    return make_executor_specs(tier, n_gpu, n_cpu, cpu_multiplier=mult)


def make_requests(spec: DeploymentSpec) -> List[Request]:
    """The materialized offline workload (sim mode): the paper task stream
    for one board, or ``workload.requests`` arrivals of the tenant mix."""
    if spec.model.kind == "board":
        return make_task_requests(board_specs(spec)[spec.model.board],
                                  spec.workload.requests,
                                  interval=spec.workload.interval_s)
    from repro.serve import multi_tenant_stream

    return list(multi_tenant_stream(make_tenants(spec),
                                    spec.workload.requests))


# --------------------------------------------------------------------------- #
# placement
# --------------------------------------------------------------------------- #

def _resolve_placement(spec: DeploymentSpec, coe: CoEModel, pools, specs,
                       tier: TierSpec,
                       requests: Optional[List[Request]]
                       ) -> Tuple[Optional[PlacementPlan], Optional[dict]]:
    """(plan, search report). ``greedy`` defers to CoServeSystem's own
    sweep; ``search`` seeds with the greedy sweep and searches under the
    spec's replication budget over a trace (saved artifact > materialized
    requests > static P(use)); ``plan`` applies a saved artifact verbatim —
    yesterday's search, no re-search."""
    fleet = spec.fleet
    if fleet.placement == "plan":
        return load_plan(fleet.plan_path, coe, capacities=pools), None
    if fleet.placement != "search":
        return None, None
    if fleet.trace_path:
        trace = load_trace(fleet.trace_path)
    elif requests is not None:
        trace = trace_from_requests(coe, requests[:512])
    else:
        # online path: no requests exist yet — search over the expected load
        # (pre-assessed P(use), already weighted by tenant rates)
        trace = trace_from_usage(coe, length=512)
    greedy = PlacementPlan.build(coe, pools, replication=fleet.replication)
    config = SearchConfig(seed=spec.seed, replication=fleet.replication)
    if spec.hetero.host_place:
        # the CPU arm's service-time penalty comes from the profiled CPU
        # service-time model, not a hand-picked constant
        config = dataclasses.replace(
            config, host_place=True, host_exec_factor=_host_exec_factor(specs))
    res = search_placement(
        coe, pools, trace, tier, links=fleet.links,
        pool_devices=validate_pool_groups(specs), seed_plan=greedy,
        config=config)
    return res.plan, res.snapshot()


def _host_exec_factor(specs) -> float:
    """CPU service time as a multiple of device time, read off the profiled
    ``ArchProfile.cpu_k`` line of the first accelerator spec (falls back to
    the SearchConfig default when no CPU profile was taken)."""
    for s in specs:
        if s.device in ("host", "cpu"):
            continue
        profs = s.profile.arch_profiles
        prof = profs.get("resnet101") or next(iter(profs.values()), None)
        if prof is not None and prof.k > 0 and prof.cpu_k > 0:
            return prof.cpu_k / prof.k
    return SearchConfig().host_exec_factor


# --------------------------------------------------------------------------- #
# the real-JAX tiny system (moved verbatim from launch.serve)
# --------------------------------------------------------------------------- #

def _tiny_apply_fns():
    import jax
    import jax.numpy as jnp

    def mlp(params, x):
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    return {"tiny_cls": jax.jit(mlp), "tiny_det": jax.jit(mlp)}


def _tiny_params(key, d_in: int, d_h: int, d_out: int):
    import jax
    ks = jax.random.split(key, 2)
    return {"w1": jax.random.normal(ks[0], (d_in, d_h)) * 0.1,
            "b1": np.zeros((d_h,), np.float32),
            "w2": jax.random.normal(ks[1], (d_h, d_out)) * 0.1,
            "b2": np.zeros((d_out,), np.float32)}


def real_board_layout(n_components: int, n_detection: int):
    """Deterministic component->detection wiring of the tiny real-JAX CoE.
    One seeded stream, drawn in this exact order — request generators must
    use this helper (not fresh RandomState(0) draws) to match the catalog's
    declared dependencies."""
    rng = np.random.RandomState(0)
    det_assign = rng.randint(0, n_detection, n_components)
    needs_det = rng.rand(n_components) < 0.5
    return needs_det, det_assign


def build_real_system(n_components: int = 24, n_detection: int = 4,
                      pool_experts: int = 6, n_executors: int = 2,
                      store_root: Optional[str] = None,
                      policy: SystemPolicy = COSERVE,
                      d_hidden: int = 256,
                      tracer: Optional[Tracer] = None,
                      decode: Optional[DecodeConfig] = None,
                      ) -> Tuple[CoServeSystem, CoEModel]:
    """A small CoE of real JAX MLP experts over host+disk tiers."""
    import jax

    from repro.core.engines import HostStore, RealEngine

    apply_fns = _tiny_apply_fns()
    store = HostStore(root=store_root or tempfile.mkdtemp(prefix="coserve_"))
    needs_det, det_assign = real_board_layout(n_components, n_detection)

    payload = {
        "make_batch": lambda reqs: np.stack([r.data["x"] for r in reqs]),
        "interpret": lambda out: ["ok" if o == 0 else "defect"
                                  for o in np.argmax(out, -1)],
    }
    experts: List[ExpertSpec] = []
    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, n_components + n_detection)
    mem = (64 * d_hidden + d_hidden * 2 + d_hidden + 2) * 4
    for c in range(n_components):
        eid = f"cls{c:03d}"
        params = _tiny_params(keys[c], 64, d_hidden, 2)
        # half the catalog starts on the disk tier, half in host DRAM
        (store.put_disk if c % 2 else store.put_host)(eid, params)
        experts.append(ExpertSpec(
            id=eid, arch="tiny_cls", mem_bytes=mem, payload=payload,
            usage_prob=1.0 / n_components))
    for dnum in range(n_detection):
        eid = f"det{dnum:02d}"
        params = _tiny_params(keys[n_components + dnum], 64, d_hidden, 2)
        store.put_disk(eid, params)
        ups = tuple(f"cls{c:03d}" for c in range(n_components)
                    if needs_det[c] and det_assign[c] == dnum)
        experts.append(ExpertSpec(
            id=eid, arch="tiny_det", mem_bytes=mem, payload=payload,
            depends_on=ups, usage_prob=0.2))

    def first_expert(data) -> str:
        return f"cls{data['component']:03d}"

    def next_expert(req: Request, eid: str, output) -> Optional[str]:
        if eid.startswith("cls") and req.data.get("needs_detection") \
                and output == "ok":
            return f"det{req.data['det_expert']:02d}"
        return None

    coe = CoEModel(experts, RoutingModule(first_expert, next_expert))
    engine = RealEngine(coe, store, apply_fns)

    # offline profiling with the real runner (paper §4.5)
    import time as _t

    def run_batch_factory(arch_params):
        def run_batch(n: int) -> float:
            x = np.zeros((n, 64), np.float32)
            fn = apply_fns["tiny_cls"]
            fn(arch_params, x)  # warm
            t0 = _t.perf_counter()
            jax.block_until_ready(fn(arch_params, x))
            return _t.perf_counter() - t0
        return run_batch

    tier = TierSpec(name="local", unified=True, host_cache_bytes=0,
                    device_bytes=pool_experts * mem + 4 * mem)
    sample = _tiny_params(jax.random.PRNGKey(9), 64, d_hidden, 2)

    # CPU service-time line, measured with the same runner pinned to the
    # host backend (paper §4.1's heterogeneous serving premise)
    cpu_dev = jax.devices("cpu")[0]
    cpu_sample = jax.device_put(sample, cpu_dev)

    def run_batch_cpu(n: int) -> float:
        x = jax.device_put(np.zeros((n, 64), np.float32), cpu_dev)
        fn = apply_fns["tiny_cls"]
        fn(cpu_sample, x)  # warm
        t0 = _t.perf_counter()
        jax.block_until_ready(fn(cpu_sample, x))
        return _t.perf_counter() - t0

    prof = microbenchmark_arch("tiny_cls", run_batch_factory(sample), mem,
                               act_bytes_per_item=64 * 4, tier=tier,
                               batch_sizes=(1, 2, 4, 8), repeats=2,
                               run_batch_cpu=run_batch_cpu)
    det_prof = dataclasses.replace(prof, arch="tiny_det")
    dev_prof = DeviceProfile(device="gpu", tier=tier,
                             arch_profiles={"tiny_cls": prof,
                                            "tiny_det": det_prof})
    pools = {"gpu": pool_experts * mem}
    specs = [ExecutorSpec("gpu", dev_prof, 4 * mem, "gpu")
             for _ in range(n_executors)]
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier,
                           engine=engine, tracer=tracer, decode=decode)
    return system, coe


# --------------------------------------------------------------------------- #
# the public entry point
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class BuildContext:
    """Everything ``build_system`` wired up, for callers (the Session) that
    need more than the system object itself."""
    spec: DeploymentSpec
    system: CoServeSystem
    coe: CoEModel
    tier: Optional[TierSpec]
    requests: Optional[List[Request]]       # sim mode: materialized workload
    search_report: Optional[dict]           # placement == "search"
    tenants: list                           # online modes: TenantSpec list
    executor_specs: Optional[List[ExecutorSpec]] = None  # layout (sim path)
    tracer: Tracer = NULL_TRACER            # flight recorder (observability)


def build_context(spec: DeploymentSpec,
                  placement: Optional[PlacementPlan] = None) -> BuildContext:
    """Wire a full system (plus the run context) from a spec. ``placement``
    overrides the spec's placement section with an explicit plan object —
    the hook benchmark suites use to score externally-searched plans."""
    mode, engine = spec.serving.mode, spec.serving.engine
    policy = resolve_policy(spec)
    obs = spec.observability
    tracer = NULL_TRACER if obs.trace == "off" \
        else Tracer(level=obs.trace, capacity=obs.buffer_events)

    if spec.model.kind == "tiny":
        m = spec.model
        system, coe = build_real_system(
            n_components=m.tiny_components, n_detection=m.tiny_detection,
            pool_experts=m.tiny_pool_experts, n_executors=m.tiny_executors,
            d_hidden=m.tiny_d_hidden, policy=policy, tracer=tracer,
            decode=resolve_decode(spec))
        if obs.sanitize:
            from repro.analysis.cachesan import CacheSanitizer
            CacheSanitizer().install(system)
        tenants = make_tenants(spec) if mode == "online" else []
        return BuildContext(spec=spec, system=system, coe=coe, tier=None,
                            requests=None, search_report=None,
                            tenants=tenants, tracer=tracer)

    tier = resolve_tier(spec)
    coe = build_catalog(spec)
    pools, specs = build_layout(spec, tier)
    requests = make_requests(spec) if mode == "sim" else None
    search_report = None
    if placement is None:
        placement, search_report = _resolve_placement(
            spec, coe, pools, specs, tier, requests)
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier,
                           links=spec.fleet.links,
                           replication=spec.fleet.replication,
                           placement=placement, tracer=tracer,
                           decode=resolve_decode(spec))
    if obs.sanitize:
        from repro.analysis.cachesan import CacheSanitizer
        CacheSanitizer().install(system)
    tenants = make_tenants(spec) if spec.workload.tenants else []
    return BuildContext(spec=spec, system=system, coe=coe, tier=tier,
                        requests=requests, search_report=search_report,
                        tenants=tenants, executor_specs=specs,
                        tracer=tracer)


def build_system(spec: DeploymentSpec,
                 placement: Optional[PlacementPlan] = None) -> CoServeSystem:
    """One spec in, one wired ``CoServeSystem`` out."""
    return build_context(spec, placement=placement).system
