"""Fault-tolerant checkpointing: atomic commit, retry, async snapshots.

Layout: ``<dir>/step_<N>/shard_host0.npz`` + ``manifest.json``; a checkpoint
directory is written under a tmp name and atomically renamed on success, so a
crash mid-write never corrupts the latest checkpoint. ``restore_latest``
scans for the newest committed step — the restart path after a node failure.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flat_with_names(tree) -> list:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save_checkpoint(ckpt_dir: str, step: int, params: Any, opt_state: Any,
                    extra: Optional[dict] = None, retries: int = 3) -> str:
    """Atomic, retrying checkpoint write. Returns the committed path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    last_err = None
    for attempt in range(retries):
        try:
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp, exist_ok=True)
            payload = {"params": params, "opt_state": opt_state}
            arrays = {}
            manifest = {"step": step, "extra": extra or {}, "leaves": []}
            for name, leaf in _flat_with_names(payload):
                key = f"a{len(arrays)}"
                arrays[key] = np.asarray(leaf)
                manifest["leaves"].append(
                    {"key": key, "name": name,
                     "dtype": str(np.asarray(leaf).dtype)})
            np.savez(os.path.join(tmp, "shard_host0.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic commit
            return final
        except OSError as e:               # pragma: no cover - fault path
            last_err = e
            time.sleep(0.1 * (attempt + 1))
    raise RuntimeError(f"checkpoint save failed after {retries} tries: {last_err}")


def restore_latest(ckpt_dir: str, params_like: Any, opt_like: Any
                   ) -> Optional[Tuple[int, Any, Any, dict]]:
    """Restore the newest committed checkpoint into the given pytree
    structures; None if no checkpoint exists."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    if not steps:
        return None
    path = os.path.join(ckpt_dir, steps[-1])
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "shard_host0.npz")) as z:
        arrays = [z[leaf["key"]] for leaf in manifest["leaves"]]
    payload_like = {"params": params_like, "opt_state": opt_like}
    treedef = jax.tree_util.tree_structure(payload_like)
    like_leaves = jax.tree_util.tree_leaves(payload_like)
    restored = [jax.numpy.asarray(a, dtype=l.dtype)
                for a, l in zip(arrays, like_leaves)]
    payload = jax.tree_util.tree_unflatten(treedef, restored)
    return (manifest["step"], payload["params"], payload["opt_state"],
            manifest.get("extra", {}))


class AsyncCheckpointer:
    """Snapshot-to-host then write on a background thread; training continues.
    ``wait()`` joins the in-flight write (call before exit / next save)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[str] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, params: Any, opt_state: Any,
             extra: Optional[dict] = None):
        self.wait()
        # device->host snapshot happens synchronously (consistent view) …
        host = jax.tree.map(lambda a: np.asarray(a), (params, opt_state))

        def _write():
            try:
                self.last_committed = save_checkpoint(
                    self.ckpt_dir, step, host[0], host[1], extra)
            except BaseException as e:    # pragma: no cover - fault path
                self._error = e

        # … the (slow) serialization + fsync happens off-thread
        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
