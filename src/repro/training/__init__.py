from repro.training.optimizer import adamw_init, adamw_update, OptState
from repro.training.train_loop import (cross_entropy_loss, make_train_step,
                                       make_whisper_train_step)

__all__ = ["adamw_init", "adamw_update", "OptState", "cross_entropy_loss",
           "make_train_step", "make_whisper_train_step"]
