"""AdamW in plain JAX (pytree-structured, sharding-transparent).

Moments are stored in fp32 with the same logical axes as their parameters,
so the FSDP/TP sharding of the model extends to the optimizer state for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), gnorm
