"""Train-step construction: loss, grad, AdamW, optional grad compression.

``make_train_step(cfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` under a mesh (remat policy comes from ``cfg.remat`` inside the
model's period scan; donation is applied by the callers).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def cross_entropy_loss(logits, labels, logical_vocab: int = 0):
    """Next-token CE (labels already shifted by the data pipeline)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    aux_weight: float = 0.01, compressor=None,
                    accum_steps: int = 1):
    """Decoder-LM train step (all non-enc-dec architectures).

    ``accum_steps > 1`` splits the batch into microbatches accumulated via
    ``lax.scan`` before one optimizer update — the standard lever for
    fitting a large global batch per chip (activation memory scales with
    the microbatch while the numerics match the full-batch step).
    """

    def loss_fn(params, batch):
        logits, aux = transformer.forward(
            params, batch["tokens"], cfg,
            positions=batch.get("positions"), mode="train")
        ce = cross_entropy_loss(logits, batch["labels"], cfg.logical_vocab_size)
        return ce + aux_weight * aux, (ce, aux)

    def grads_of(params, batch):
        (_, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, ce, aux

    def train_step(params, opt_state, batch):
        if accum_steps > 1:
            def split(t):
                b = t.shape[0]
                if b % accum_steps:
                    raise ValueError(
                        f"batch {b} not divisible by accum_steps {accum_steps}")
                return t.reshape(accum_steps, b // accum_steps, *t.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                g_acc, ce_acc, aux_acc = acc
                g, ce, aux = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, ce_acc + ce, aux_acc + aux), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, ce_sum, aux_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            ce, aux = ce_sum / accum_steps, aux_sum / accum_steps
        else:
            grads, ce, aux = grads_of(params, batch)
        if compressor is not None:
            grads, opt_state = compressor(grads, opt_state)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": ce, "aux_loss": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_whisper_train_step(cfg: ModelConfig,
                            opt_cfg: AdamWConfig = AdamWConfig()):
    """Enc-dec train step: teacher-forced decoder over audio embeddings."""

    def loss_fn(params, batch):
        logits = encdec.decode_train(params, batch["tokens"],
                                     batch["audio_embeds"], cfg)
        return cross_entropy_loss(logits, batch["labels"],
                                  cfg.logical_vocab_size)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_train_state(key, cfg: ModelConfig):
    init = encdec.init_params if cfg.is_encoder_decoder else transformer.init_params
    params = init(key, cfg)
    return params, adamw_init(params)
