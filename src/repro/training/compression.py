"""Gradient compression for the DP all-reduce path: int8 + error feedback.

Per-leaf symmetric int8 quantisation with an error-feedback residual carried
across steps (Karimireddy et al.): quantisation error is added back into the
next step's gradient, so compression bias vanishes asymptotically. The
quant/dequant pair sits where the DP all-reduce happens, modelling an 4x
traffic reduction on the gradient reduce-scatter.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_init(params: Any) -> Any:
    """Zero residual pytree (fp32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_dequant(g: jax.Array) -> jax.Array:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Returns (compressed grads, new residual)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        gq = _quant_dequant(g)
        return gq, g - gq

    out = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_res


def compressed_bytes(grads: Any) -> int:
    """Traffic after compression (int8 payload + fp32 scale per leaf)."""
    total = 0
    for g in jax.tree.leaves(grads):
        total += g.size + 4
    return total
