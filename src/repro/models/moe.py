"""Grouped top-k MoE layer (capacity-based, batched-gather dispatch).

Tokens are split into groups of ``moe_group_size``; dispatch/combine are
*batched* gathers/scatters over the group dim, so GSPMD partitions them
index-parallel (the group axis carries the token sharding) — no global
scatter, no dense [t, E, C] dispatch einsum (zero FLOP overhead). Per-group
capacity bounds memory exactly as in GShard; overflow tokens are dropped
(capacity_factor 1.25).

Expert weights carry an "experts" logical axis -> true expert parallelism
when E divides the model axis (moonshot 64e: groups shard "data", experts
"model", the buf reshard is the MoE all-to-all); otherwise groups take both
mesh axes and experts compute group-locally with FSDP+TP weights (mixtral 8e
on a 16-way axis).

Measured motivation (EXPERIMENTS.md SSPerf): the naive global scatter/gather
dispatch replicated f32[2M, 6144] token tensors under GSPMD — 48 GiB each,
216 GiB temp for one mixtral train layer.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding.logical import logical_constraint


def init_moe(key, cfg, dtype):
    d = cfg.d_model
    s = cfg.moe_ep_split
    e = cfg.moe_num_experts * s                      # virtual experts (B4)
    ff = (cfg.moe_d_ff or cfg.d_ff) // s
    kr, k1, k3 = jax.random.split(key, 3)
    return {
        "router": dense_init(kr, (d, cfg.moe_num_experts), dtype),
        # gate/up fused along a LOCAL pair dim [e, d, 2, ff]: one einsum and
        # ONE input-grad partial-sum all-reduce in the TP backward instead of
        # two, while ff stays cleanly model-sharded (§Perf iteration B3)
        "w_in": dense_init(k1, (e, d, 2, ff), dtype),
        "w_down": dense_init(k3, (e, ff, d), dtype, fan_in=ff),
    }


MOE_AXES = {
    "router": ("embed", None),
    "w_in": ("experts", "embed", None, "moe_mlp"),
    "w_down": ("experts", "moe_mlp", "embed"),
}

GROUP_SIZE = 4096  # tokens per dispatch group


def expert_capacity(group_size: int, cfg) -> int:
    if group_size <= 64:
        # tiny groups (smoke tests): exactly dropless
        return group_size
    # GShard capacity everywhere else — decode groups included: a 128-token
    # decode batch at cap=group_size made every expert process every token,
    # e/k x the useful FLOPs (§Perf iteration A3)
    cap = math.ceil(group_size * cfg.moe_top_k / cfg.moe_num_experts
                    * cfg.moe_capacity_factor)
    return max(8, min(group_size, ((cap + 7) // 8) * 8))


def moe_block(params, x, cfg, compute_dtype=jnp.bfloat16, router_stats=None):
    """Returns (out [B,S,d], aux_loss scalar, expert_load [E])."""
    b, s, d = x.shape
    t = b * s
    k = cfg.moe_top_k
    e = cfg.moe_num_experts

    gsize = min(GROUP_SIZE, t)
    pad_t = (-t) % gsize
    xf = x.reshape(t, d)
    if pad_t:
        xf = jnp.pad(xf, ((0, pad_t), (0, 0)))
    g = (t + pad_t) // gsize
    xg = xf.reshape(g, gsize, d)
    xg = logical_constraint(xg, "moe_groups", "moe_tokens", "embed_act")

    logits = (xg @ params["router"].astype(compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # [g, t, E]
    top_w, top_i = jax.lax.top_k(probs, k)                      # [g, t, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- load-balancing auxiliary loss (Switch-style) ---
    onehot_top1 = jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32)
    frac_tokens = onehot_top1.reshape(-1, e).mean(axis=0)
    mean_probs = probs.reshape(-1, e).mean(axis=0)
    aux = e * jnp.sum(frac_tokens * mean_probs)

    # --- per-group slot assignment: position of each (token, choice) within
    #     its expert, computed over the group-local flattened (t*k) stream ---
    cap = expert_capacity(gsize, cfg)
    oh = jax.nn.one_hot(top_i.reshape(g, gsize * k), e, dtype=jnp.int32)
    expert_load = oh.sum(axis=(0, 1))                           # [E]
    pos = jnp.cumsum(oh, axis=1) - 1                            # [g, t*k, E]
    slot = jnp.sum(pos * oh, axis=-1)                           # [g, t*k]
    flat_e = top_i.reshape(g, gsize * k)
    in_cap = slot < cap
    token_ids = jnp.broadcast_to(
        jnp.arange(gsize, dtype=jnp.int32)[None, :, None],
        (g, gsize, k)).reshape(g, gsize * k)

    # --- virtual-expert EP expansion (B4): every (token, choice) goes to all
    #     s half-width virtual experts of its chosen expert; both halves see
    #     identical token sets so slots/capacity carry over unchanged ---
    sp = cfg.moe_ep_split
    kk = k * sp
    e_v = e * sp
    if sp > 1:
        flat_e = (flat_e[..., None] * sp
                  + jnp.arange(sp, dtype=jnp.int32)).reshape(g, gsize * kk)
        slot = jnp.repeat(slot, sp, axis=-1)
        in_cap = jnp.repeat(in_cap, sp, axis=-1)
        token_ids = jnp.repeat(token_ids, sp, axis=-1)

    # --- dispatch: build token-id table [g, Ev*cap] then batched-gather ---
    sentinel = gsize                                            # -> zero row
    buf_pos = flat_e * cap + jnp.where(in_cap, slot, e_v * cap)  # OOB -> drop
    table = jnp.full((g, e_v * cap + 1), sentinel, jnp.int32)
    table = jax.vmap(lambda tb, bp, ti: tb.at[bp].set(ti, mode="drop"))(
        table, buf_pos, token_ids)[:, :e_v * cap]

    xg_pad = jnp.pad(xg, ((0, 0), (0, 1), (0, 0)))              # zero row
    buf = jnp.take_along_axis(xg_pad, table[..., None], axis=1)  # [g, Ev*c, d]
    buf = buf.reshape(g, e_v, cap, d)
    buf = logical_constraint(buf, "moe_groups", "experts", None, "embed_act")

    # --- expert FFN (batched over experts; EP when E divides the axis) ---
    from repro.models.layers import cast_param
    wi = cast_param(params["w_in"], compute_dtype, *MOE_AXES["w_in"])
    wd = cast_param(params["w_down"], compute_dtype, *MOE_AXES["w_down"])
    gu = jnp.einsum("gecd,edxf->gecxf", buf, wi)      # [g,e,c,2,ff] fused
    gu = logical_constraint(gu, "moe_groups", "experts", None, None,
                            "moe_mlp")
    h = jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]
    h = logical_constraint(h, "moe_groups", "experts", None, "moe_mlp")
    out_buf = jnp.einsum("gecf,efd->gecd", h, wd)
    out_buf = logical_constraint(out_buf, "moe_groups", "experts", None,
                                 "embed_act")

    # --- combine: batched-gather back to token order, weight, sum over k
    #     (and over the s virtual halves, whose partial outputs add) ---
    out_flat = out_buf.reshape(g, e_v * cap, d)
    out_pad = jnp.pad(out_flat, ((0, 0), (0, 1), (0, 0)))       # zero row
    gather_pos = jnp.where(in_cap, flat_e * cap + slot, e_v * cap)
    gathered = jnp.take_along_axis(out_pad, gather_pos[..., None], axis=1)
    w_comb = top_w if sp == 1 else jnp.repeat(top_w, sp, axis=-1)
    gathered = gathered.reshape(g, gsize, kk, d) \
        * w_comb[..., None].astype(compute_dtype)
    yg = gathered.sum(axis=2)                                   # [g, t, d]
    yg = logical_constraint(yg, "moe_groups", "moe_tokens", "embed_act")

    y = yg.reshape(g * gsize, d)
    if pad_t:
        y = y[:t]
    out = y.reshape(b, s, d)
    out = logical_constraint(out, "batch", "seq_q", "embed_act")
    return out, aux, expert_load
