"""Model configuration covering every assigned architecture family.

One frozen dataclass describes dense / MoE / hybrid / SSM / enc-dec / VLM
backbones; ``block_pattern()`` expands it into the per-period layer layout the
transformer stack scans over (jamba's 1:7 attn:mamba interleave with MoE every
other layer collapses into a period of 8 slots scanned 4 times).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSlot:
    """One layer inside a scan period."""
    mixer: str       # "attn" | "mamba"
    ffn: Optional[str]  # "mlp" | "moe" | None (mamba1 blocks have no FFN)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0              # per-expert FFN width (0 -> d_ff)
    moe_period: int = 1            # MoE every k-th layer (jamba: 2)
    moe_offset: int = 0
    moe_capacity_factor: float = 1.25
    # virtual-expert EP (§Perf iteration B4): split each expert into
    # ``moe_ep_split`` half-width virtual experts so the expert count divides
    # the model axis (mixtral 8e x split 2 = 16 on a 16-way axis). SwiGLU is
    # elementwise in ff, so the split is mathematically exact. Set per-cell
    # by the launcher from the mesh; 1 = off.
    moe_ep_split: int = 1

    # --- hybrid / ssm ---
    attn_period: int = 1           # jamba: attention every 8th layer
    attn_offset: int = 0           # jamba: offset 4
    ssm_state_dim: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 2

    # --- attention details ---
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 = full attention
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim split

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500        # whisper 30 s -> 1500 frames (stub frontend)

    # --- misc ---
    mlp_type: str = "swiglu"       # swiglu | gelu
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    tie_embeddings: bool = False
    logical_vocab_size: int = 0    # unpadded vocab (0 -> vocab_size)
    max_position: int = 1 << 20
    norm_eps: float = 1e-5

    # --- runtime knobs (not architecture) ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = ""       # "" -> same as compute_dtype
    attn_chunk: int = 1024         # KV chunk for the XLA online-softmax path
    ssm_chunk: int = 256           # chunk length for the chunked mamba scan
    remat: bool = True             # checkpoint each scan body in training
    attn_impl: str = "xla"        # xla | pallas
    scan_layers: bool = True

    # ------------------------------------------------------------------ #
    @property
    def kv_dtype(self) -> str:
        return self.kv_cache_dtype or self.compute_dtype

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid always; attention iff windowed."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    @property
    def has_decode(self) -> bool:
        return True  # every assigned arch has a decoder (whisper is enc-dec)

    def period(self) -> int:
        """Scan-period length: lcm of the structural periods."""
        p = 1
        if self.family == "hybrid":
            p = self.attn_period
        if self.moe_num_experts and self.moe_period > 1:
            p = _lcm(p, self.moe_period)
        return p

    def block_pattern(self) -> Tuple[BlockSlot, ...]:
        """Layer layout of one scan period."""
        slots = []
        for i in range(self.period()):
            if self.family == "ssm":
                slots.append(BlockSlot(mixer="mamba", ffn=None))
                continue
            if self.family == "hybrid":
                is_attn = (i % self.attn_period) == self.attn_offset
                mixer = "attn" if is_attn else "mamba"
            else:
                mixer = "attn"
            if self.moe_num_experts and (i % self.moe_period) == self.moe_offset:
                ffn = "moe"
            else:
                ffn = "mlp"
            slots.append(BlockSlot(mixer=mixer, ffn=ffn))
        return tuple(slots)

    def num_periods(self) -> int:
        p = self.period()
        if self.num_layers % p:
            raise ValueError(f"{self.name}: {self.num_layers} layers not divisible by period {p}")
        return self.num_layers // p

    # --- parameter counting (for roofline MODEL_FLOPS and memory budgeting) ---
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        total = 0
        # embeddings (+ untied head)
        vocab = self.logical_vocab_size or self.vocab_size
        total += vocab * d * (1 if self.tie_embeddings else 2)
        for slot in self.block_pattern():
            n = self.num_periods()
            if slot.mixer == "attn":
                qkv = d * hd * (self.num_heads + 2 * self.num_kv_heads)
                total += n * (qkv + self.num_heads * hd * d + d)
            else:  # mamba
                di, st, rk = self.d_inner, self.ssm_state_dim, self.dt_rank
                total += n * (d * 2 * di + di * self.ssm_conv_width
                              + di * (rk + 2 * st) + rk * di + di * st + di
                              + di * d + d)
            if slot.ffn == "mlp":
                mult = 3 if self.mlp_type == "swiglu" else 2
                total += n * (mult * d * self.d_ff + d)
            elif slot.ffn == "moe":
                e = self.moe_top_k if active_only else self.moe_num_experts
                ff = self.moe_d_ff or self.d_ff
                mult = 3 if self.mlp_type == "swiglu" else 2
                total += n * (d * self.moe_num_experts  # router (always dense)
                              + e * mult * d * ff + d)
        if self.is_encoder_decoder:
            # encoder self-attn + mlp, decoder cross-attn (approx: reuse attn size)
            enc = self.encoder_layers * (
                d * hd * (self.num_heads + 2 * self.num_kv_heads)
                + self.num_heads * hd * d + 2 * d * self.d_ff + 2 * d)
            xattn = self.num_layers * (
                d * hd * (self.num_heads + 2 * self.num_kv_heads)
                + self.num_heads * hd * d + d)
            total += enc + xattn
        total += d  # final norm
        return total

    def flops_per_token(self, seq_len: int, decode: bool = False) -> float:
        """Model FLOPs per token: 6N (+attention term) train, 2N decode."""
        n_active = self.param_count(active_only=True)
        base = (2.0 if decode else 6.0) * n_active
        # attention score FLOPs (per token, against seq_len context)
        attn_ctx = min(seq_len, self.sliding_window) if self.sliding_window else seq_len
        n_attn_layers = sum(1 for s in self.block_pattern() if s.mixer == "attn") \
            * self.num_periods()
        factor = 2.0 if decode else 6.0  # fwd only vs fwd+bwd
        base += factor * 2 * n_attn_layers * self.num_heads * self.resolved_head_dim * attn_ctx
        return base


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)
