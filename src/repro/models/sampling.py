"""Token sampling and simple autoregressive generation loops."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


def sample_token(logits, key, temperature: float = 0.0, top_k: int = 0):
    """logits: [B, V] -> tokens [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def generate(params, prompt, cfg: ModelConfig, max_new_tokens: int,
             cache_width: int = 0, temperature: float = 0.0, key=None):
    """Greedy/temperature generation; returns [B, max_new_tokens]."""
    b, s = prompt.shape
    width = cache_width or (s + max_new_tokens)
    key = key if key is not None else jax.random.PRNGKey(0)
    logits, cache = transformer.prefill(params, prompt, cfg, width)
    tok = sample_token(logits, key, temperature)

    def body(carry, i):
        tok, cache, key = carry
        key, sub = jax.random.split(key)
        logits, cache = transformer.decode_step(
            params, tok[:, None], s + i, cache, cfg)
        nxt = sample_token(logits, sub, temperature)
        return (nxt, cache, key), nxt

    (_, _, _), toks = jax.lax.scan(body, (tok, cache, key),
                                   jnp.arange(max_new_tokens - 1))
    return jnp.concatenate([tok[:, None], toks.T], axis=1)
