"""Core layer primitives: norms, RoPE/M-RoPE, GQA attention (chunked
online-softmax prefill + ring-buffer decode), SwiGLU/GELU MLPs.

All functions are pure; parameters are plain dicts of jnp arrays. Activation
sharding is expressed through ``logical_constraint`` so the same model code
lowers for every mesh via the logical-rule tables.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.logical import logical_constraint

# --------------------------------------------------------------------------- #
# initialisation helpers
# --------------------------------------------------------------------------- #

def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


@jax.custom_vjp
def _sharding_barrier(x):
    return jax.lax.optimization_barrier(x)


def _sharding_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _sharding_barrier_bwd(_, g):
    return (g,)


_sharding_barrier.defvjp(_sharding_barrier_fwd, _sharding_barrier_bwd)


def cast_param(p, compute_dtype, *axes):
    """Cast a (possibly fp32, FSDP-sharded) parameter to the compute dtype
    *before* any gather: the sharding constraint + optimization barrier pin
    the convert to the param's sharding, so XLA's FSDP all-gather moves bf16,
    not fp32 — 2x on weight-gather traffic and peak temp
    (EXPERIMENTS.md SSPerf). ``optimization_barrier`` has no differentiation
    rule, so the barrier goes through a custom_vjp whose cotangent is the
    identity — the cast's own grad path (bf16 -> fp32 accumulation) is
    untouched."""
    if p.dtype == compute_dtype:
        return p
    out = p.astype(compute_dtype)
    if axes:
        out = logical_constraint(out, *axes)
        out = _sharding_barrier(out)
    return out


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #

def rmsnorm(x, scale, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dtype)


def layernorm(x, scale, bias, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def apply_norm(x, params, norm_type, eps):
    if norm_type == "layernorm":
        return layernorm(x, params["scale"], params["bias"], eps)
    return rmsnorm(x, params["scale"], eps)


def init_norm(d, norm_type, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


NORM_AXES = {"scale": (None,), "bias": (None,)}


# --------------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------------- #

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float, sections: Tuple[int, ...] = ()):
    """Rotate-half RoPE.

    x: [B, S, H, hd]; positions: [B, S] (standard) or [3, B, S] (M-RoPE with
    ``sections`` splitting the half-dim into temporal/height/width bands).
    """
    b, s, h, hd = x.shape
    half = hd // 2
    freqs = jnp.asarray(rope_frequencies(hd, theta))          # [half]
    if sections:
        assert sum(sections) == half, (sections, half)
        assert positions.ndim == 3, "M-RoPE requires position triples [3,B,S]"
        # band i of the half-dim rotates with positions[i]
        section_ids = np.repeat(np.arange(len(sections)), sections)  # [half]
        pos = positions.astype(jnp.float32)                    # [3,B,S]
        pos_per_band = pos[section_ids]                        # [half,B,S]
        angles = jnp.einsum("dbs,d->bsd", pos_per_band, freqs)  # [B,S,half]
    else:
        pos = positions.astype(jnp.float32)                    # [B,S]
        angles = pos[..., None] * freqs                        # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #

NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 1024, q_offset=0, kv_len=None):
    """Online-softmax attention streamed over KV chunks (XLA flash).

    q: [B, S, Hq, hd]; k, v: [B, T, Hkv, hd]. Never materialises the full
    [S, T] score matrix. ``q_offset`` gives the absolute position of q[0]
    (prefill continuation / decode). ``kv_len`` masks trailing cache slots.

    GQA is handled by expanding KV to the query heads up front: under TP the
    KV heads are replicated (or head-sharded) so the expansion is device-
    local, and every internal tensor then carries a single "heads" dim that
    shards cleanly on the model axis — the split [Hkv, G] layout forced GSPMD
    into involuntary full-rematerialization copies between the attention
    body and the seq-sharded residual (§Perf iteration B2).
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        # expand KV to query heads BEFORE the chunk scan: one reshard to the
        # clean heads layout up front — expanding per chunk makes GSPMD
        # re-slice a seq-sharded KV every iteration (involuntary full-remat
        # copies; §Perf B6, refuted and reverted)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    # KV is NEVER seq-sharded inside the chunk scan (chunk slices would cross
    # shards); heads shard when divisible, else KV replicates and the q rows
    # carry the parallelism ("seq_attn" -> model for 24/12-head archs, B7)
    k = logical_constraint(k, "batch", None, "heads", None)
    v = logical_constraint(v, "batch", None, "heads", None)
    c = min(chunk, t)
    n_chunks = (t + c - 1) // c
    t_pad = n_chunks * c
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    kv_len = t if kv_len is None else kv_len

    qh = (q * (hd ** -0.5)).astype(q.dtype)
    qh = logical_constraint(qh, "batch", "seq_attn", "heads", None)
    q_pos = q_offset + jnp.arange(s)

    def body(carry, idx):
        m, l, acc = carry                      # [b,h,s], [b,h,s], [b,h,s,d]
        kc = jax.lax.dynamic_slice_in_dim(k, idx * c, c, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, idx * c, c, axis=1)
        k_pos = idx * c + jnp.arange(c)
        scores = jnp.einsum("bshd,bchd->bhsc", qh, kc,
                            preferred_element_type=jnp.float32)
        mask = (k_pos[None, :] < kv_len)
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhsc,bchd->bhsd", p, vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = logical_constraint(jnp.full((b, hq, s), NEG_INF, jnp.float32),
                            "batch", "heads", "seq_attn")
    l0 = logical_constraint(jnp.zeros((b, hq, s), jnp.float32),
                            "batch", "heads", "seq_attn")
    acc0 = logical_constraint(jnp.zeros((b, hq, s, hd), jnp.float32),
                              "batch", "heads", "seq_attn", None)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3)            # [b, s, hq, hd]
    return out.astype(q.dtype)


def ring_decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                          new_kv=None):
    """Single-token attention against a (possibly ring-buffer) KV cache.

    q: [B, 1, Hq, hd]; caches: [B, Hkv, W, hd] (heads-major — the dot
    contracts the trailing [W, hd] tile with no layout copy); ``pos`` is the
    absolute position of the new token. Ring semantics: cache slot i holds
    absolute position ``pos - ((pos - i) mod W)``.

    With ``new_kv=(k_new, v_new)`` ([B, Hkv, 1, hd]) the caches are the
    PRE-update buffers: the new token's slot is masked out of the cache
    scores (it holds the stale pos-W entry) and its attention term is added
    explicitly — callers then update the cache purely for the NEXT step.
    """
    b, _, hq, hd = q.shape
    hkv, w = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = (q * (hd ** -0.5)).reshape(b, hkv, g, hd)
    slots = jnp.arange(w)
    abs_pos = pos - jnp.mod(pos - slots, w)          # [W]
    valid = abs_pos >= 0
    if window:
        valid = valid & (pos - abs_pos < window)
    if new_kv is not None:
        valid = valid & (slots != jnp.mod(pos, w))   # stale slot -> self term
    scores = jnp.einsum("bngd,bnwd->bngw", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    if new_kv is not None:
        k_new, v_new = new_kv
        s_self = jnp.einsum("bngd,bnwd->bngw", qg, k_new,
                            preferred_element_type=jnp.float32)  # [b,n,g,1]
        m = jnp.maximum(scores.max(-1, keepdims=True), s_self)
        p = jnp.exp(scores - m)
        p_self = jnp.exp(s_self - m)
        denom = p.sum(-1, keepdims=True) + p_self
        out = jnp.einsum("bngw,bnwd->bngd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
        out = (out + p_self.astype(jnp.float32)
               * v_new[:, :, 0, :][:, :, None].astype(jnp.float32))
        out = out / denom
        return out.reshape(b, 1, hq, hd).astype(q.dtype)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bngw,bnwd->bngd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, cfg.num_heads * hd), dtype),
        "wk": dense_init(kk, (d, cfg.num_kv_heads * hd), dtype),
        "wv": dense_init(kv, (d, cfg.num_kv_heads * hd), dtype),
        "wo": dense_init(ko, (cfg.num_heads * hd, d), dtype, fan_in=cfg.num_heads * hd),
    }


ATTN_AXES = {
    "wq": ("embed", "qkv"),
    "wk": ("embed", "qkv"),
    "wv": ("embed", "qkv"),
    "wo": ("qkv", "embed"),
}


def attention_block(params, x, cfg, positions, *, cache=None, pos=None,
                    cross_kv=None, causal=True, compute_dtype=jnp.bfloat16):
    """GQA attention. Three modes:
      - prefill/train: cache=None -> chunked attention over x itself
        (returns (out, (k, v)) so callers can build a cache);
      - decode: cache=(k_cache, v_cache), pos given -> ring decode;
      - cross-attention: cross_kv=(k, v) precomputed (whisper decoder).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ cast_param(params["wq"], compute_dtype, *ATTN_AXES["wq"])
         ).reshape(b, s, cfg.num_heads, hd)
    if cross_kv is None:
        k = (x @ cast_param(params["wk"], compute_dtype, *ATTN_AXES["wk"])
             ).reshape(b, s, cfg.num_kv_heads, hd)
        v = (x @ cast_param(params["wv"], compute_dtype, *ATTN_AXES["wv"])
             ).reshape(b, s, cfg.num_kv_heads, hd)
        if positions is not None:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        k, v = cross_kv
    q = logical_constraint(q, "batch", "seq_attn", "heads", None)
    k = logical_constraint(k, "batch", "kv_seq", "kv_heads", None)
    v = logical_constraint(v, "batch", "kv_seq", "kv_heads", None)

    use_pallas = cfg.attn_impl == "pallas"
    new_cache = None
    if cache is not None and cross_kv is None:
        # heads-major ring cache [B, Hkv, W, hd]; the single new row is
        # written in place (donated buffer, shard-local when heads carry the
        # model axis). Attention runs against the PRE-update cache plus an
        # explicit self term, so the updated cache feeds nothing downstream
        # and its update stays a pure in-place bf16 DUS (§Perf iteration A2).
        k_cache, v_cache = cache
        w = k_cache.shape[2]
        slot = jnp.mod(pos, w)
        k_new = k.astype(k_cache.dtype).transpose(0, 2, 1, 3)   # [B,Hkv,1,hd]
        v_new = v.astype(v_cache.dtype).transpose(0, 2, 1, 3)
        if use_pallas:
            kc = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot,
                                                     axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot,
                                                     axis=2)
            new_cache = (kc, vc)
            from repro.kernels import decode_attention_op
            out = decode_attention_op(
                q[:, 0], kc, vc, pos,
                window=cfg.sliding_window)[:, None]
        else:
            out = ring_decode_attention(q, k_cache, v_cache, pos,
                                        window=cfg.sliding_window,
                                        new_kv=(k_new, v_new))
            new_cache = (
                jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot,
                                                    axis=2),
                jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot,
                                                    axis=2))
    elif cache is not None:  # cross-attention with cached encoder KV
        out = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    else:
        if use_pallas:
            from repro.kernels import flash_attention_op
            out = flash_attention_op(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=causal,
                window=cfg.sliding_window).transpose(0, 2, 1, 3)
        else:
            out = chunked_attention(q, k, v, causal=causal,
                                    window=cfg.sliding_window,
                                    chunk=cfg.attn_chunk)
        new_cache = (k, v)
    out = out.reshape(b, s, cfg.num_heads * hd)
    out = out @ cast_param(params["wo"], compute_dtype, *ATTN_AXES["wo"])
    out = logical_constraint(out, "batch", "seq_q", "embed_act")
    return out, new_cache


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #

def init_mlp(key, d, d_ff, mlp_type, dtype):
    if mlp_type == "swiglu":
        k1, k3 = jax.random.split(key, 2)
        return {
            # gate/up fused along a local pair dim (§Perf iteration B3):
            # one matmul + ONE input-grad all-reduce in the TP backward
            "w_in": dense_init(k1, (d, 2, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d), dtype, fan_in=d_ff),
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, (d, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d), dtype, fan_in=d_ff),
    }


MLP_AXES = {
    "w_in": ("embed", None, "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
}


def mlp_axes(mlp_type: str):
    if mlp_type == "swiglu":
        return {k: MLP_AXES[k] for k in ("w_in", "w_down")}
    return {k: MLP_AXES[k] for k in ("w_up", "w_down")}


def mlp_block(params, x, mlp_type, compute_dtype=jnp.bfloat16):
    if mlp_type == "swiglu":
        wi = cast_param(params["w_in"], compute_dtype, *MLP_AXES["w_in"])
        gu = jnp.einsum("bsd,dxf->bsxf", x, wi)      # [B,S,2,ff] fused
        h = jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]
    else:
        h = jax.nn.gelu(x @ cast_param(params["w_up"], compute_dtype,
                                       *MLP_AXES["w_up"]))
    h = logical_constraint(h, "batch", "seq_attn", "mlp")
    out = h @ cast_param(params["w_down"], compute_dtype, *MLP_AXES["w_down"])
    return logical_constraint(out, "batch", "seq_q", "embed_act")


# --------------------------------------------------------------------------- #
# embeddings / head
# --------------------------------------------------------------------------- #

def init_embedding(key, vocab, d, dtype):
    return {"table": dense_init(key, (vocab, d), dtype, fan_in=d)}


EMBED_AXES = {"table": ("vocab", "embed")}


def embed(params, tokens, compute_dtype=jnp.bfloat16):
    out = cast_param(params["table"], compute_dtype, *EMBED_AXES["table"])[tokens]
    return logical_constraint(out, "batch", "seq_q", "embed_act")


def unembed(params, x, logical_vocab=0, compute_dtype=jnp.bfloat16):
    logits = x @ cast_param(params["table"], compute_dtype,
                            *EMBED_AXES["table"]).T
    if logical_vocab and logical_vocab < params["table"].shape[0]:
        pad = params["table"].shape[0] - logical_vocab
        mask = jnp.concatenate([jnp.zeros((logical_vocab,), logits.dtype),
                                jnp.full((pad,), NEG_INF, logits.dtype)])
        logits = logits + mask
    return logical_constraint(logits, "batch", "seq_q", "vocab")
