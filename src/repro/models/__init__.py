from repro.models.config import ModelConfig, BlockSlot
from repro.models import transformer, layers, moe, ssm, kvcache, sampling

__all__ = ["ModelConfig", "BlockSlot", "transformer", "layers", "moe", "ssm",
           "kvcache", "sampling"]
