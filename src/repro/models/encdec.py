"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, 1500, d]. Encoder = bidirectional attention
stack; decoder = causal self-attention + cross-attention to the encoded audio.
Sinusoidal positions (no RoPE), LayerNorm + GELU, MHA (kv == heads).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding.logical import logical_constraint


def sinusoidal_positions(length: int, d: int, offset=0):
    pos = offset + jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-np.log(10000.0) * dim / max(1, d // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #

def _enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.init_norm(cfg.d_model, "layernorm", dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "norm2": L.init_norm(cfg.d_model, "layernorm", dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": L.init_norm(cfg.d_model, "layernorm", dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "norm_x": L.init_norm(cfg.d_model, "layernorm", dtype),
        "xattn": L.init_attention(k2, cfg, dtype),
        "norm2": L.init_norm(cfg.d_model, "layernorm", dtype),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kd, kt, kf1, kf2 = jax.random.split(key, 5)

    def stack(maker, key, n):
        per = [maker(k, cfg, dtype) for k in jax.random.split(key, n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    return {
        "embed": L.init_embedding(kt, cfg.vocab_size, cfg.d_model, dtype),
        "encoder": stack(_enc_layer, ke, cfg.encoder_layers),
        "enc_final": L.init_norm(cfg.d_model, "layernorm", dtype),
        "decoder": stack(_dec_layer, kd, cfg.num_layers),
        "dec_final": L.init_norm(cfg.d_model, "layernorm", dtype),
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def param_axes(cfg: ModelConfig):
    def layered(d):
        return jax.tree.map(lambda t: ("layers",) + t, d,
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                isinstance(e, (str, type(None))) for e in x))

    enc = layered({"norm1": dict(L.NORM_AXES), "attn": dict(L.ATTN_AXES),
                   "norm2": dict(L.NORM_AXES), "mlp": L.mlp_axes("gelu")})
    dec = layered({"norm1": dict(L.NORM_AXES), "attn": dict(L.ATTN_AXES),
                   "norm_x": dict(L.NORM_AXES), "xattn": dict(L.ATTN_AXES),
                   "norm2": dict(L.NORM_AXES), "mlp": L.mlp_axes("gelu")})
    return {
        "embed": dict(L.EMBED_AXES),
        "encoder": enc,
        "enc_final": dict(L.NORM_AXES),
        "decoder": dec,
        "dec_final": dict(L.NORM_AXES),
    }



def _scan_or_loop(body, carry, xs, scan: bool):
    """lax.scan, or an unrolled python loop (dry-run cost extrapolation)."""
    if scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a, i=i: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys

# --------------------------------------------------------------------------- #
# encoder
# --------------------------------------------------------------------------- #

def encode(params, audio_embeds, cfg: ModelConfig):
    """audio_embeds: [B, F, d] precomputed frame embeddings (stub frontend)."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    b, f, d = audio_embeds.shape
    x = audio_embeds.astype(cdtype) + sinusoidal_positions(f, d).astype(cdtype)

    def body(x, lp):
        h = L.apply_norm(x, lp["norm1"], "layernorm", cfg.norm_eps)
        out, _ = L.attention_block(lp["attn"], h, cfg, None, causal=False,
                                   compute_dtype=cdtype)
        x = x + out
        h = L.apply_norm(x, lp["norm2"], "layernorm", cfg.norm_eps)
        x = x + L.mlp_block(lp["mlp"], h, "gelu", cdtype)
        return x, None

    x, _ = _scan_or_loop(body, x, params["encoder"], cfg.scan_layers)
    return L.apply_norm(x, params["enc_final"], "layernorm", cfg.norm_eps)


def cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute per-decoder-layer cross-attention K/V: [L, B, F, H, hd]."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    b, f, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def body(_, lp):
        k = (enc_out @ lp["xattn"]["wk"].astype(cdtype)).reshape(
            b, f, cfg.num_kv_heads, hd)
        v = (enc_out @ lp["xattn"]["wv"].astype(cdtype)).reshape(
            b, f, cfg.num_kv_heads, hd)
        return None, {"k": k, "v": v}

    _, kv = _scan_or_loop(body, None, params["decoder"], cfg.scan_layers)
    return kv


# --------------------------------------------------------------------------- #
# decoder
# --------------------------------------------------------------------------- #

def _dec_block(lp, x, cfg, cdtype, self_cache=None, pos=None, xkv=None):
    h = L.apply_norm(x, lp["norm1"], "layernorm", cfg.norm_eps)
    out, new_kv = L.attention_block(lp["attn"], h, cfg, None,
                                    cache=self_cache, pos=pos,
                                    compute_dtype=cdtype)
    x = x + out
    h = L.apply_norm(x, lp["norm_x"], "layernorm", cfg.norm_eps)
    out, _ = L.attention_block(lp["xattn"], h, cfg, None,
                               cross_kv=(xkv["k"], xkv["v"]),
                               causal=False, compute_dtype=cdtype)
    x = x + out
    h = L.apply_norm(x, lp["norm2"], "layernorm", cfg.norm_eps)
    x = x + L.mlp_block(lp["mlp"], h, "gelu", cdtype)
    return x, new_kv


def decode_train(params, tokens, audio_embeds, cfg: ModelConfig):
    """Teacher-forced decoder over full token sequence. Returns logits."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    enc_out = encode(params, audio_embeds, cfg)
    xkv = cross_kv(params, enc_out, cfg)
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cdtype)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(cdtype)

    def body(x, xs):
        lp, kv = xs
        x, _ = _dec_block(lp, x, cfg, cdtype, xkv=kv)
        return x, None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = _scan_or_loop(body_fn, x, (params["decoder"], xkv), cfg.scan_layers)
    x = L.apply_norm(x, params["dec_final"], "layernorm", cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.logical_vocab_size, cdtype)
    return logits


def prefill(params, tokens, audio_embeds, cfg: ModelConfig, cache_width: int):
    """Returns (last-token logits, {"self": ring KV, "cross": KV, "enc_done"})."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    enc_out = encode(params, audio_embeds, cfg)
    xkv = cross_kv(params, enc_out, cfg)
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cdtype)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(cdtype)

    def to_ring(k):
        """[B,S,Hkv,hd] -> heads-major [B,Hkv,W,hd] ring buffer."""
        k = k.transpose(0, 2, 1, 3)
        if s >= cache_width:
            tail = k[:, :, s - cache_width:]
            return jnp.roll(tail, s % cache_width, axis=2)
        return jnp.pad(k, ((0, 0), (0, 0), (0, cache_width - s), (0, 0)))

    def body(x, xs):
        lp, kv = xs
        x, new_kv = _dec_block(lp, x, cfg, cdtype, xkv=kv)
        kvdt = jnp.dtype(cfg.kv_dtype)
        ring = {"k": to_ring(new_kv[0]).astype(kvdt),
                "v": to_ring(new_kv[1]).astype(kvdt)}
        return x, ring

    x, self_cache = _scan_or_loop(body, x, (params["decoder"], xkv), cfg.scan_layers)
    x = L.apply_norm(x[:, -1:], params["dec_final"], "layernorm", cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.logical_vocab_size, cdtype)[:, 0]
    return logits, {"self": self_cache, "cross": xkv}


def decode_step(params, token, pos, cache, cfg: ModelConfig):
    """One decoder token against self-cache + cross-cache."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    b = token.shape[0]
    x = L.embed(params["embed"], token, cdtype)
    x = x + sinusoidal_positions(1, cfg.d_model, offset=pos).astype(cdtype)[None]

    def body(x, xs):
        lp, self_kv, kv = xs
        x, new_kv = _dec_block(lp, x, cfg, cdtype,
                               self_cache=(self_kv["k"], self_kv["v"]),
                               pos=pos, xkv=kv)
        return x, {"k": new_kv[0], "v": new_kv[1]}

    x, new_self = _scan_or_loop(
        body, x, (params["decoder"], cache["self"], cache["cross"]),
        cfg.scan_layers)
    x = L.apply_norm(x, params["dec_final"], "layernorm", cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.logical_vocab_size, cdtype)[:, 0]
    return logits, {"self": new_self, "cross": cache["cross"]}


def init_self_cache(cfg: ModelConfig, batch: int, width: int):
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, width, hd)
    kvdt = jnp.dtype(cfg.kv_dtype)
    return {"k": jnp.zeros(shape, kvdt),
            "v": jnp.zeros(shape, kvdt)}
