"""KV-cache / SSM-state construction and logical-axis metadata."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import ssm as ssm_lib


def slot_cache_shape(cfg, slot, batch: int, width: int):
    """Abstract cache entry for one period-slot (leading dim = n_periods)."""
    p = cfg.num_periods()
    hd = cfg.resolved_head_dim
    kvdt = jnp.dtype(cfg.kv_dtype)
    if slot.mixer == "attn":
        # heads-major layout [B, Hkv, W, hd]: the ring update is shard-local
        # when kv_heads divides the model axis (no cross-shard selects), and
        # the decode dot needs no transposed cache copy (§Perf iteration A1)
        shape = (p, batch, cfg.num_kv_heads, width, hd)
        return {
            "k": jnp.zeros(shape, kvdt),
            "v": jnp.zeros(shape, kvdt),
        }
    return {
        "conv": jnp.zeros((p, batch, cfg.ssm_conv_width - 1, cfg.d_inner), kvdt),
        "ssm": jnp.zeros((p, batch, cfg.d_inner, cfg.ssm_state_dim), jnp.float32),
    }


def slot_cache_axes(slot):
    if slot.mixer == "attn":
        # kv_heads dim precedes kv_seq: divisibility fallback gives the model
        # axis to heads when possible (moonshot 16, minitron 8 on pod meshes),
        # else to the sequence (starcoder2/qwen2 kv=2)
        kv = ("layers", "batch", "kv_heads", "kv_seq", None)
        return {"k": kv, "v": kv}
    return {
        "conv": ("layers", "batch", None, "ssm_inner"),
        "ssm": ("layers", "batch", "ssm_inner", "ssm_state"),
    }


def init_cache(cfg, batch: int, width: int):
    """Cache pytree: {"slot{i}": per-slot stacked cache}."""
    pattern = cfg.block_pattern()
    return {f"slot{i}": slot_cache_shape(cfg, s, batch, width)
            for i, s in enumerate(pattern)}


def cache_axes(cfg):
    pattern = cfg.block_pattern()
    return {f"slot{i}": slot_cache_axes(s) for i, s in enumerate(pattern)}


def cache_width(cfg, seq_len: int) -> int:
    """Ring-buffer width for a target context length (SWA bounds it)."""
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len
