"""Mamba-1 selective-state-space block (falcon-mamba, jamba mamba layers).

Prefill/train uses a chunked scan: ``lax.scan`` over sequence chunks with an
associative prefix-scan inside each chunk — O(S) memory in chunk-sized tiles
(mirrors the Pallas ``mamba_scan`` kernel's HBM->VMEM tiling). Decode is the
O(1) recurrence on a carried (conv_state, ssm_state).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init
from repro.sharding.logical import logical_constraint


def init_mamba(key, cfg, dtype):
    d, di = cfg.d_model, cfg.d_inner
    st, rk, w = cfg.ssm_state_dim, cfg.dt_rank, cfg.ssm_conv_width
    keys = jax.random.split(key, 6)
    # S4D-real initialisation for A; dt bias so softplus(dt) spans [1e-3, 1e-1]
    a = np.tile(np.arange(1, st + 1, dtype=np.float32), (di, 1))
    dt = np.exp(np.random.RandomState(0).uniform(math.log(1e-3), math.log(1e-1), di)
                ).astype(np.float32)
    dt_bias = dt + np.log(-np.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(keys[0], (d, 2 * di), dtype),
        "conv_w": dense_init(keys[1], (w, di), dtype, fan_in=w),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(keys[2], (di, rk + 2 * st), dtype, fan_in=di),
        "dt_proj": dense_init(keys[3], (rk, di), dtype, fan_in=rk),
        "dt_bias": jnp.asarray(dt_bias, dtype),
        "A_log": jnp.asarray(np.log(a), dtype=jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[4], (di, d), dtype, fan_in=di),
    }


MAMBA_AXES = {
    "in_proj": ("embed", "ssm_inner"),
    "conv_w": ("conv", "ssm_inner"),
    "conv_b": ("ssm_inner",),
    "x_proj": ("ssm_inner", None),
    "dt_proj": (None, "ssm_inner"),
    "dt_bias": ("ssm_inner",),
    "A_log": ("ssm_inner", "ssm_state"),
    "D": ("ssm_inner",),
    "out_proj": ("ssm_inner", "embed"),
}


def _causal_conv(x, conv_w, conv_b, history=None):
    """Depthwise causal conv. x: [B,S,di], conv_w: [W,di].
    ``history``: [B,W-1,di] previous inputs (decode) or None (zero-pad)."""
    w = conv_w.shape[0]
    if history is None:
        xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * conv_w[i] for i in range(w))
    return out + conv_b


def _ssm_inputs(params, x_c, cfg, compute_dtype):
    """Project to (dt [.., di], B [.., st], C [.., st]) — pre state-expansion."""
    rk, st = cfg.dt_rank, cfg.ssm_state_dim
    proj = x_c @ params["x_proj"].astype(compute_dtype)
    dt_r, b_c, c_c = jnp.split(proj, [rk, rk + st], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ params["dt_proj"].astype(compute_dtype)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))
    return dt, b_c.astype(jnp.float32), c_c.astype(jnp.float32)


def mamba_forward(params, x, cfg, compute_dtype=jnp.bfloat16, state=None):
    """Full-sequence forward. x: [B,S,d] -> (y [B,S,d], final_state)."""
    b, s, d = x.shape
    di = cfg.d_inner
    from repro.models.layers import cast_param
    xz = x @ cast_param(params["in_proj"], compute_dtype, *MAMBA_AXES["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = logical_constraint(x_in, "batch", "seq_attn", "ssm_inner")
    conv_hist = None if state is None else state["conv"]
    x_c = jax.nn.silu(_causal_conv(x_in, params["conv_w"].astype(compute_dtype),
                                   params["conv_b"].astype(compute_dtype),
                                   conv_hist))

    dt, b_c, c_c = _ssm_inputs(params, x_c, cfg, compute_dtype)
    a = -jnp.exp(params["A_log"])                      # [di, st]

    if cfg.attn_impl == "pallas" and s > 1 and state is None:
        from repro.kernels import mamba_scan_op
        y, h_final = mamba_scan_op(x_c, dt, b_c, c_c, a,
                                   params["D"], block_s=cfg.ssm_chunk)
        y = y.astype(jnp.float32)
    else:
        # chunked scan: the [chunk, di, st] state expansion happens INSIDE
        # the body, so the [S, di, st] tensor never materialises in HBM
        # (mirrors the Pallas kernel's per-chunk VMEM expansion)
        chunk = min(cfg.ssm_chunk, s)
        n_chunks = (s + chunk - 1) // chunk
        pad = n_chunks * chunk - s
        xq, dtq, bq, cq = x_c.astype(jnp.float32), dt, b_c, c_c
        if pad:
            # zero dt => exp(0*A)=1, dbx=0: padded steps are identities
            xq = jnp.pad(xq, ((0, 0), (0, pad), (0, 0)))
            dtq = jnp.pad(dtq, ((0, 0), (0, pad), (0, 0)))
            bq = jnp.pad(bq, ((0, 0), (0, pad), (0, 0)))
            cq = jnp.pad(cq, ((0, 0), (0, pad), (0, 0)))
        st = cfg.ssm_state_dim

        def to_chunks(t):
            return t.reshape(b, n_chunks, chunk, t.shape[-1]).swapaxes(0, 1)

        h0 = jnp.zeros((b, di, st), jnp.float32) if state is None \
            else state["ssm"].astype(jnp.float32)

        def chunk_body(h, inp):
            x_ch, dt_ch, b_ch, c_ch = inp            # [b, chunk, ...]
            da_c = jnp.exp(dt_ch[..., None] * a)     # [b, chunk, di, st]
            dbx_c = (dt_ch * x_ch)[..., None] * b_ch[..., None, :]
            a_cum, h_free = jax.lax.associative_scan(
                _ssm_combine, (da_c, dbx_c), axis=1)
            h_all = h_free + a_cum * h[:, None]      # [b, chunk, di, st]
            y_ch = jnp.einsum("bsdn,bsn->bsd", h_all, c_ch)
            return h_all[:, -1], y_ch

        h_final, y_chunks = jax.lax.scan(
            chunk_body, h0, (to_chunks(xq), to_chunks(dtq),
                             to_chunks(bq), to_chunks(cq)))
        y = y_chunks.swapaxes(0, 1).reshape(b, n_chunks * chunk, di)[:, :s]
        y = y + params["D"] * x_c.astype(jnp.float32)
    y = (y.astype(compute_dtype)) * jax.nn.silu(z)
    out = y @ cast_param(params["out_proj"], compute_dtype,
                         *MAMBA_AXES["out_proj"])
    out = logical_constraint(out, "batch", "seq_q", "embed_act")

    new_state = {
        "conv": _conv_tail(x_in, cfg.ssm_conv_width, conv_hist),
        "ssm": h_final,
    }
    return out, new_state


def _ssm_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def _conv_tail(x_in, width, history):
    """Last W-1 inputs, for decode continuation."""
    b, s, di = x_in.shape
    need = width - 1
    if history is not None:
        x_in = jnp.concatenate([history.astype(x_in.dtype), x_in], axis=1)
        s = x_in.shape[1]
    if s >= need:
        return x_in[:, s - need:s]
    pad = need - s
    return jnp.pad(x_in, ((0, 0), (pad, 0), (0, 0)))


def mamba_decode_step(params, x, state, cfg, compute_dtype=jnp.bfloat16):
    """Single-token recurrence. x: [B,1,d]; state {conv [B,W-1,di], ssm [B,di,st]}."""
    out, new_state = mamba_forward(params, x, cfg, compute_dtype, state=state)
    return out, new_state


def init_mamba_state(batch, cfg, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state_dim), jnp.float32),
    }


MAMBA_STATE_AXES = {
    "conv": ("batch", None, "ssm_inner"),
    "ssm": ("batch", "ssm_inner", "ssm_state"),
}
