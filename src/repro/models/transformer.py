"""Decoder-only LM stack covering dense / MoE / hybrid / SSM families.

Layers are rolled into ``lax.scan`` over *periods* (the lcm of the structural
interleave periods): a dense arch scans L one-block periods, jamba scans 4
eight-block periods (7 mamba + 1 attn, MoE on odd slots). Each period-slot's
parameters are stacked along a leading axis and consumed as scan xs, keeping
HLO size flat across 24..64-layer architectures.

Entry points: ``forward`` (train / full-sequence), ``prefill`` (build a ring
KV cache + last-token logits), ``decode_step`` (one token against the cache).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import kvcache as kvcache_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.sharding.logical import logical_constraint


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #

def _init_slot(key, cfg: ModelConfig, slot, dtype):
    keys = jax.random.split(key, 4)
    p = {"norm1": L.init_norm(cfg.d_model, cfg.norm_type, dtype)}
    if slot.mixer == "attn":
        p["attn"] = L.init_attention(keys[0], cfg, dtype)
    else:
        p["mamba"] = ssm_lib.init_mamba(keys[1], cfg, dtype)
    if slot.ffn is not None:
        p["norm2"] = L.init_norm(cfg.d_model, cfg.norm_type, dtype)
        if slot.ffn == "moe":
            p["moe"] = moe_lib.init_moe(keys[2], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(keys[3], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def _slot_axes(cfg: ModelConfig, slot):
    a = {"norm1": dict(L.NORM_AXES) if cfg.norm_type == "layernorm"
         else {"scale": (None,)}}
    if slot.mixer == "attn":
        a["attn"] = dict(L.ATTN_AXES)
    else:
        a["mamba"] = dict(ssm_lib.MAMBA_AXES)
    if slot.ffn is not None:
        a["norm2"] = dict(a["norm1"])
        if slot.ffn == "moe":
            a["moe"] = dict(moe_lib.MOE_AXES)
        else:
            a["mlp"] = L.mlp_axes(cfg.mlp_type)
    return a


def init_params(key, cfg: ModelConfig):
    """Parameter pytree; per-slot params stacked along a leading periods axis."""
    dtype = jnp.dtype(cfg.param_dtype)
    pattern = cfg.block_pattern()
    n = cfg.num_periods()
    k_embed, k_head, k_final, k_blocks = jax.random.split(key, 4)

    def stacked_slot(slot_key, slot):
        keys = jax.random.split(slot_key, n)
        per = [_init_slot(k, cfg, slot, dtype) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    slot_keys = jax.random.split(k_blocks, len(pattern))
    params = {
        "embed": L.init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "slots": {f"slot{i}": stacked_slot(sk, s)
                  for i, (sk, s) in enumerate(zip(slot_keys, pattern))},
        "final_norm": L.init_norm(cfg.d_model, cfg.norm_type, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(k_head, cfg.vocab_size, cfg.d_model, dtype)
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree (no allocation) — used by the dry-run."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def param_axes(cfg: ModelConfig):
    pattern = cfg.block_pattern()

    def add_layer_dim(axes_dict):
        return jax.tree.map(
            lambda t: ("layers",) + t, axes_dict,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    axes = {
        "embed": dict(L.EMBED_AXES),
        "slots": {f"slot{i}": add_layer_dim(_slot_axes(cfg, s))
                  for i, s in enumerate(pattern)},
        "final_norm": {"scale": (None,)} if cfg.norm_type == "rmsnorm"
        else dict(L.NORM_AXES),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = dict(L.EMBED_AXES)
    return axes


# --------------------------------------------------------------------------- #
# block application
# --------------------------------------------------------------------------- #

def _apply_slot(slot_params, x, cfg: ModelConfig, slot, positions, cdtype,
                cache=None, pos=None):
    """One layer: pre-norm mixer + residual, then pre-norm FFN + residual.
    Returns (x, new_cache, aux)."""
    h = L.apply_norm(x, slot_params["norm1"], cfg.norm_type, cfg.norm_eps)
    new_cache = None
    if slot.mixer == "attn":
        kv = None if cache is None else (cache["k"], cache["v"])
        out, new_kv = L.attention_block(
            slot_params["attn"], h, cfg, positions, cache=kv, pos=pos,
            compute_dtype=cdtype)
        if cache is not None:
            new_cache = {"k": new_kv[0], "v": new_kv[1]}
        else:
            new_cache = new_kv  # (k, v) of this segment (prefill harvests it)
    else:
        state = cache if (cache is not None and "ssm" in cache) else None
        out, new_state = ssm_lib.mamba_forward(
            slot_params["mamba"], h, cfg, cdtype, state=state)
        new_cache = new_state
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if slot.ffn is not None:
        h2 = L.apply_norm(x, slot_params["norm2"], cfg.norm_type, cfg.norm_eps)
        if slot.ffn == "moe":
            out2, aux, _ = moe_lib.moe_block(slot_params["moe"], h2, cfg, cdtype)
        else:
            out2 = L.mlp_block(slot_params["mlp"], h2, cfg.mlp_type, cdtype)
        x = x + out2
    return x, new_cache, aux


def _default_positions(cfg: ModelConfig, batch, seq, offset=0):
    pos = offset + jnp.arange(seq)[None, :]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


# --------------------------------------------------------------------------- #
# forward (train / scoring)
# --------------------------------------------------------------------------- #

def forward(params, tokens, cfg: ModelConfig, positions=None,
            input_embeds=None, mode: str = "train"):
    """Full-sequence forward. Returns (logits [B,S,V], aux_loss)."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    if input_embeds is not None:
        x = input_embeds.astype(cdtype)
        b, s = x.shape[:2]
    else:
        b, s = tokens.shape
        x = L.embed(params["embed"], tokens, cdtype)
    if positions is None:
        positions = _default_positions(cfg, b, s)
    pattern = cfg.block_pattern()

    def period_body(carry, slot_params):
        x, aux = carry
        for i, slot in enumerate(pattern):
            x, _, a = _apply_slot(slot_params[f"slot{i}"], x, cfg, slot,
                                  positions, cdtype)
            aux = aux + a
        return (x, aux), None

    body = period_body
    if cfg.remat and mode == "train":
        body = jax.checkpoint(period_body, prevent_cse=False)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["slots"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for p in range(cfg.num_periods()):
            sliced = jax.tree.map(lambda a: a[p], params["slots"])
            (x, aux), _ = body((x, aux), sliced)

    x = L.apply_norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x, cfg.logical_vocab_size, cdtype)
    return logits, aux


# --------------------------------------------------------------------------- #
# prefill
# --------------------------------------------------------------------------- #

def prefill(params, tokens, cfg: ModelConfig, cache_width: int,
            positions=None, input_embeds=None):
    """Run the prompt, build a ring KV cache of ``cache_width`` slots.
    Returns (last-token logits [B,V], cache)."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    if input_embeds is not None:
        x = input_embeds.astype(cdtype)
        b, s = x.shape[:2]
    else:
        b, s = tokens.shape
        x = L.embed(params["embed"], tokens, cdtype)
    if positions is None:
        positions = _default_positions(cfg, b, s)
    pattern = cfg.block_pattern()

    def to_ring(kv_seg):
        """Place a [B,S,Hkv,hd] KV segment into a heads-major [B,Hkv,W,hd]
        ring buffer."""
        k = kv_seg.transpose(0, 2, 1, 3)             # [B,Hkv,S,hd]
        if s >= cache_width:
            tail = k[:, :, s - cache_width:]
            return jnp.roll(tail, s % cache_width, axis=2)
        return jnp.pad(k, ((0, 0), (0, 0), (0, cache_width - s), (0, 0)))

    def period_body(x, slot_params):
        caches = {}
        for i, slot in enumerate(pattern):
            x, new_cache, _ = _apply_slot(slot_params[f"slot{i}"], x, cfg,
                                          slot, positions, cdtype)
            kvdt = jnp.dtype(cfg.kv_dtype)
            if slot.mixer == "attn":
                k, v = new_cache
                caches[f"slot{i}"] = {"k": to_ring(k).astype(kvdt),
                                      "v": to_ring(v).astype(kvdt)}
            else:
                caches[f"slot{i}"] = {
                    "conv": new_cache["conv"].astype(kvdt),
                    "ssm": new_cache["ssm"],
                }
        return x, caches

    if cfg.scan_layers:
        x, cache = jax.lax.scan(period_body, x, params["slots"])
    else:
        cache_list = []
        for p in range(cfg.num_periods()):
            sliced = jax.tree.map(lambda a: a[p], params["slots"])
            x, c = period_body(x, sliced)
            cache_list.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)

    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg.norm_type, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x, cfg.logical_vocab_size, cdtype)[:, 0]
    return logits, cache


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #

def decode_step(params, token, pos, cache, cfg: ModelConfig, positions=None):
    """One decode step. token: [B,1] int32; pos: scalar int32 (absolute).
    Returns (logits [B,V], new cache)."""
    cdtype = jnp.dtype(cfg.compute_dtype)
    b = token.shape[0]
    x = L.embed(params["embed"], token, cdtype)
    if positions is None:
        positions = _default_positions(cfg, b, 1, offset=pos)
    pattern = cfg.block_pattern()

    def period_body(x, xs):
        slot_params, slot_caches = xs
        new_caches = {}
        for i, slot in enumerate(pattern):
            x, nc, _ = _apply_slot(slot_params[f"slot{i}"], x, cfg, slot,
                                   positions, cdtype,
                                   cache=slot_caches[f"slot{i}"], pos=pos)
            new_caches[f"slot{i}"] = nc
        return x, new_caches

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(period_body, x, (params["slots"], cache))
    else:
        ncs = []
        for p in range(cfg.num_periods()):
            sliced = jax.tree.map(lambda a: a[p], (params["slots"], cache))
            x, nc = period_body(x, sliced)
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)

    x = L.apply_norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x, cfg.logical_vocab_size, cdtype)[:, 0]
    return logits, new_cache
