"""repro.obs: the observability layer (flight recorder + logging).

``Tracer`` records typed events from the whole serving stack into a
bounded ring buffer (``repro.obs.tracer``); ``repro.obs.export`` writes
them as Perfetto-loadable Chrome trace JSON; ``repro.obs.timeline``
decomposes per-request end-to-end latency from them; ``repro.obs.log`` is
the CLIs' leveled logger. See docs/observability.md.

Only the tracer core is imported eagerly — it is on the hot serving path
and must stay dependency-free; export/timeline load on demand.
"""
from repro.obs.tracer import (DEFAULT_CAPACITY, EVENT_KINDS, NULL_TRACER,
                              TRACE_LEVELS, Event, Tracer)

__all__ = ["DEFAULT_CAPACITY", "EVENT_KINDS", "Event", "NULL_TRACER",
           "TRACE_LEVELS", "Tracer"]
