"""Per-request timelines: decompose end-to-end latency from trace events.

Source of truth: the only join of the flight recorder's event streams into
a per-request view — where each request's end-to-end latency went, stage by
stage, split into

  queue_wait        time on an executor queue not covered below (includes
                    waiting behind other experts' batches and on overlapped
                    prefetch loads, which stall no one by construction)
  switch_load_wait  time idle-waiting on a demand load from host DRAM/disk
  peer_copy_wait    time idle-waiting on a demand pool -> pool replica copy
  exec              the stage's own batch execution

and, for runs with token-level decode on (PR 9), three more per-chain
components after the terminal stage's prefill:

  decode_wait       time between prefill completion / consecutive decode
                    steps spent waiting for a step boundary (continuous
                    batching admits joiners at step starts only)
  kv_reload_wait    the KV-reload portion of the chain's decode steps
                    (offloaded blocks riding the PCIe link back)
  decode_exec       the steps' compute time itself

Needs a *full*-level trace: stages are reconstructed by joining ``assign``
events (arrival on a queue, chain linkage via ``parent``) with ``exec``
events (batch membership) and demand ``load`` events (stall intervals,
split by ``via``). The components sum exactly to ``end - arrival`` per
stage — queue_wait is defined as the remainder — and chained stages are
contiguous (a follow-up's arrival is its parent stage's completion), so a
chain's stage totals sum to its end-to-end latency. Reconciliation against
``Metrics`` (pinned by tests): terminal-stage totals average to
``Metrics.avg_latency`` for offline runs, whose latency anchor is
per-stage (see ``CoServeSystem.route_followup``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

from repro.obs.tracer import Event


@dataclasses.dataclass
class Stage:
    """One executed stage of one request."""
    request: int
    root: int                     # root request id of the chain
    expert: str
    executor: str
    arrival: float                # assign time on the executor queue
    start: float                  # batch execution start
    end: float                    # batch execution end
    queue_wait: float
    switch_load_wait: float
    peer_copy_wait: float
    exec: float
    terminal: bool = False        # no follow-up stage observed

    @property
    def total(self) -> float:
        return self.end - self.arrival

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _clip(lo: float, hi: float, a: float, b: float) -> float:
    """Length of [a, b] ∩ [lo, hi]."""
    return max(0.0, min(hi, b) - max(lo, a))


def stage_records(events: Iterable[Event]) -> List[Stage]:
    """Join assign / exec / demand-load events into per-stage records."""
    assigns: Dict[int, List[dict]] = {}
    parents: Dict[int, Optional[int]] = {}
    loads: Dict[tuple, List[tuple]] = {}     # (executor, expert) -> intervals
    execs: List[Event] = []
    for e in events:
        if e.kind == "assign":
            rid = e.attrs["request"]
            assigns.setdefault(rid, []).append(
                {"t": e.t, "expert": e.name, "executor": e.attrs["executor"]})
            parents[rid] = e.attrs.get("parent")
        elif e.kind == "exec":
            execs.append(e)
        elif e.kind == "load" and e.attrs.get("demand"):
            loads.setdefault((e.actor, e.name), []).append(
                (e.t, e.t + e.dur, e.attrs.get("via", "disk")))

    def root_of(rid: int) -> int:
        seen = set()
        while parents.get(rid) is not None and rid not in seen:
            seen.add(rid)
            rid = parents[rid]
        return rid

    has_child = {p for p in parents.values() if p is not None}
    stages: List[Stage] = []
    for ev in execs:
        t_s, t_e = ev.t, ev.t + ev.dur
        for rid in ev.attrs.get("requests", ()):
            cands = [a for a in assigns.get(rid, ()) if a["t"] <= t_s + 1e-12]
            if not cands:
                continue               # assign fell off the ring buffer
            a = max(cands, key=lambda x: x["t"])
            switch = peer = 0.0
            for lo, hi, via in loads.get((ev.actor, ev.name), ()):
                part = _clip(a["t"], t_s, lo, hi)
                if via == "peer":
                    peer += part
                else:
                    switch += part
            stages.append(Stage(
                request=rid, root=root_of(rid), expert=ev.name,
                executor=ev.actor, arrival=a["t"], start=t_s, end=t_e,
                queue_wait=(t_s - a["t"]) - switch - peer,
                switch_load_wait=switch, peer_copy_wait=peer,
                exec=ev.dur, terminal=rid not in has_child))
    return stages


def decode_spans(events: Iterable[Event]) -> Dict[int, dict]:
    """Per-request decode summary from ``decode`` step events: every step a
    request is a member of counts fully toward its span (the whole batch
    advances together). Empty for stage-level runs."""
    spans: Dict[int, dict] = {}
    for e in events:
        if e.kind != "decode":
            continue
        for rid in e.attrs.get("requests", ()):
            sp = spans.setdefault(
                rid, {"start": e.t, "end": e.t, "dur": 0.0, "kv": 0.0,
                      "steps": 0})
            sp["start"] = min(sp["start"], e.t)
            sp["end"] = max(sp["end"], e.t + e.dur)
            sp["dur"] += e.dur
            sp["kv"] += e.attrs.get("kv_wait", 0.0)
            sp["steps"] += 1
    return spans


def request_timelines(events: Iterable[Event]) -> Dict[int, dict]:
    """Chain view: root request id -> ordered stages + latency breakdown.

    ``e2e`` spans the whole chain (root arrival to terminal completion —
    the online anchor); ``last_stage`` is the terminal stage's own total
    (the offline anchor). Both are sums of the stage components, so the
    decomposition is exact by construction.
    """
    events = list(events)
    spans = decode_spans(events)
    by_root: Dict[int, List[Stage]] = {}
    for s in stage_records(events):
        by_root.setdefault(s.root, []).append(s)
    out: Dict[int, dict] = {}
    for root, stages in by_root.items():
        stages.sort(key=lambda s: s.arrival)
        last = stages[-1]
        rec = {
            "stages": [s.to_dict() for s in stages],
            "queue_wait": sum(s.queue_wait for s in stages),
            "switch_load_wait": sum(s.switch_load_wait for s in stages),
            "peer_copy_wait": sum(s.peer_copy_wait for s in stages),
            "exec": sum(s.exec for s in stages),
            "decode_wait": 0.0,
            "kv_reload_wait": 0.0,
            "decode_exec": 0.0,
            "e2e": last.end - stages[0].arrival,
            "last_stage": last.total,
            "complete": last.terminal,
        }
        sp = spans.get(last.request)
        if sp is not None:
            # the terminal stage's prefill is followed by its decode span:
            # the chain now ends at its last token. decode_wait is defined
            # as the remainder (step-boundary gaps), so the decomposition
            # stays exact by construction.
            rec["kv_reload_wait"] = sp["kv"]
            rec["decode_exec"] = sp["dur"] - sp["kv"]
            rec["decode_wait"] = (sp["end"] - last.end) - sp["dur"]
            rec["e2e"] = sp["end"] - stages[0].arrival
            rec["last_stage"] = last.total + (sp["end"] - last.end)
        out[root] = rec
    return out


def reconcile(events: Iterable[Event], metrics) -> dict:
    """Compare the event-derived view against the run's ``Metrics``:
    terminal-stage count/mean latency (offline anchor) and summed demand
    stall vs ``Metrics.stall_time``. Returns the deltas; callers decide
    tolerance (tests pin 1e-6 on latency, trace_report pins 1% on stall)."""
    events = list(events)
    stages = stage_records(events)
    spans = decode_spans(events)
    terminals = [s for s in stages if s.terminal]

    def _total(s: Stage) -> float:
        # with decode on, a request finishes at its last token, not at
        # prefill completion — extend the terminal stage by its decode span
        sp = spans.get(s.request)
        return s.total + (sp["end"] - s.end if sp is not None else 0.0)

    mean = sum(_total(s) for s in terminals) / len(terminals) \
        if terminals else 0.0
    # stall from the load events themselves (one per demand load, exactly
    # what ExecStats accumulates) — the per-stage clipped waits count a
    # shared load once per batch member, deliberately, and would overcount
    stall = sum(e.dur for e in events
                if e.kind == "load" and e.attrs.get("demand"))
    return {
        "completed_events": len(terminals),
        "completed_metrics": metrics.completed,
        "avg_latency_events": mean,
        "avg_latency_metrics": metrics.avg_latency,
        "avg_latency_delta": mean - metrics.avg_latency,
        "stall_events_s": stall,
        "stall_metrics_s": metrics.stall_time,
    }
