"""Chrome trace-event export: flight-recorder events as a Perfetto trace.

Source of truth: the only writer (and validator) of the on-disk trace
artifact — ``Session.save_events``, the ``--trace-events`` CLI flag and the
CI trace smoke all produce/consume exactly this format.

The output is the Chrome trace-event JSON object format
(``{"traceEvents": [...]}``, loadable in Perfetto / ``chrome://tracing``):

  pid 1 "executors"  one thread per executor — ``exec`` batches as complete
                     ("X") slices, demand-load stalls as ``stall:<expert>``
                     slices (an executor is idle while a demand load is in
                     flight, so the two never overlap on a track), ``evict``
                     as instants;
  pid 2 "channels"   one thread per transfer channel (SSD fan-in, per-device
                     PCIe, peer ingress) — ``xfer`` legs as "X" slices named
                     by the expert they move (FIFO channels guarantee
                     non-overlapping slices per track);
  pid 3 "control"    scheduler / gateway / autoscaler decision instants.

Timestamps are sim-seconds scaled to microseconds (the format's unit).
``otherData`` embeds the run's ``Metrics`` aggregates and the tracer's
drop count so ``tools/trace_report.py`` can reconcile the events against
the metrics without a second input file.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

from repro.obs.tracer import Event, Tracer

PID_EXECUTORS = 1
PID_CHANNELS = 2
PID_CONTROL = 3
_PROCESS_NAMES = {PID_EXECUTORS: "executors", PID_CHANNELS: "channels",
                  PID_CONTROL: "control"}
_CONTROL_ACTORS = ("scheduler", "gateway", "autoscaler")

SCHEMA_PHASES = ("X", "i", "M")       # complete, instant, metadata


def _us(t: float) -> float:
    """Sim seconds -> trace microseconds (stable rounding)."""
    return round(t * 1e6, 3)


def _track_map(events: Iterable[Event]) -> Dict[int, List[str]]:
    """pid -> ordered actor (thread) names, deterministic."""
    execs, chans = set(), set()
    for e in events:
        if e.kind in ("exec", "load", "evict"):
            execs.add(e.actor)
        elif e.kind == "xfer":
            chans.add(e.actor)
    return {PID_EXECUTORS: sorted(execs), PID_CHANNELS: sorted(chans),
            PID_CONTROL: list(_CONTROL_ACTORS)}


def chrome_trace(events: Iterable[Event],
                 metadata: Optional[dict] = None) -> dict:
    """Render events as a Chrome trace-event JSON object."""
    events = list(events)
    tracks = _track_map(events)
    tids: Dict[int, Dict[str, int]] = {
        pid: {name: i + 1 for i, name in enumerate(names)}
        for pid, names in tracks.items()}

    out: List[dict] = []
    for pid, name in _PROCESS_NAMES.items():
        out.append({"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                    "args": {"name": name}})
        for actor, tid in tids[pid].items():
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_name", "args": {"name": actor}})

    for e in events:
        args = dict(e.attrs)
        if e.kind == "exec":
            out.append({"ph": "X", "pid": PID_EXECUTORS,
                        "tid": tids[PID_EXECUTORS][e.actor], "cat": "exec",
                        "name": e.name, "ts": _us(e.t), "dur": _us(e.dur),
                        "args": args})
        elif e.kind == "load":
            if not args.get("demand"):
                continue               # overlapped prefetch: it never idles
            #                            anyone; its link legs are the xfers
            args["expert"] = e.name
            args["executor"] = e.actor
            out.append({"ph": "X", "pid": PID_EXECUTORS,
                        "tid": tids[PID_EXECUTORS][e.actor], "cat": "load",
                        "name": f"stall:{e.name}", "ts": _us(e.t),
                        "dur": _us(e.dur), "args": args})
        elif e.kind == "xfer":
            args["channel"] = e.actor
            out.append({"ph": "X", "pid": PID_CHANNELS,
                        "tid": tids[PID_CHANNELS][e.actor], "cat": "xfer",
                        "name": e.name, "ts": _us(e.t), "dur": _us(e.dur),
                        "args": args})
        elif e.kind == "evict":
            out.append({"ph": "i", "s": "t", "pid": PID_EXECUTORS,
                        "tid": tids[PID_EXECUTORS][e.actor], "cat": "evict",
                        "name": f"evict:{e.name}", "ts": _us(e.t),
                        "args": args})
        else:                          # control-plane instants
            actor = e.actor if e.actor in tids[PID_CONTROL] else "scheduler"
            out.append({"ph": "i", "s": "t", "pid": PID_CONTROL,
                        "tid": tids[PID_CONTROL][actor], "cat": e.kind,
                        "name": f"{e.kind}:{e.name}", "ts": _us(e.t),
                        "args": args})

    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": metadata or {}}


def validate_chrome_trace(doc: dict) -> None:
    """Structural validation against the Chrome trace-event object format.
    Raises ``ValueError`` listing every problem found."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome trace: top-level object must have "
                         "a 'traceEvents' array")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be an array")
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in SCHEMA_PHASES:
            problems.append(f"{where}: ph={ph!r} not in {SCHEMA_PHASES}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in e:
                problems.append(f"{where}: missing {key!r}")
        if ph in ("X", "i"):
            ts = e.get("ts")
            if not isinstance(ts, (int, float)):
                problems.append(f"{where}: ts must be a number, got {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X events need dur >= 0, "
                                f"got {dur!r}")
        if ph == "i" and e.get("s") not in (None, "t", "p", "g"):
            problems.append(f"{where}: instant scope s={e.get('s')!r}")
        if len(problems) >= 20:
            problems.append("... (further problems suppressed)")
            break
    if problems:
        raise ValueError("invalid Chrome trace: " + "; ".join(problems))


# --------------------------------------------------------------------------- #
# file round trip
# --------------------------------------------------------------------------- #

def trace_metadata(tracer: Tracer, metrics=None) -> dict:
    """The ``otherData`` block: tracer accounting + the Metrics aggregates
    trace_report reconciles against."""
    meta = {"tracer": tracer.snapshot()}
    if metrics is not None:
        meta["metrics"] = {
            "completed": metrics.completed,
            "switches": metrics.switches,
            "evictions": metrics.evictions,
            "makespan_s": metrics.makespan,
            "stall_time_s": metrics.stall_time,
            "avg_latency_s": metrics.avg_latency,
        }
    return meta


def save_events(tracer: Tracer, path: str, metrics=None) -> dict:
    """Export the tracer's ring buffer as a Chrome trace JSON file."""
    doc = chrome_trace(tracer.events, metadata=trace_metadata(tracer, metrics))
    validate_chrome_trace(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def load_chrome_trace(path: str) -> dict:
    """Read + validate a saved trace file."""
    with open(path) as f:
        doc = json.load(f)
    validate_chrome_trace(doc)
    return doc
