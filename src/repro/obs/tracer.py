"""Flight recorder: typed structured events in a bounded ring buffer.

Source of truth: the only event sink in the serving stack — the simulator
loop, ``RequestScheduler``, ``MemoryHierarchy``/``TransferEngine``,
executors, the admission gate and the autoscaler all emit here, so "what
happened during this run, in order" has exactly one definition.

Design constraints (pinned by tests):

  * zero-cost when disabled — every call site guards with
    ``if tracer.enabled:`` / ``if tracer.full:`` (plain attribute reads; no
    call, no allocation), and the system-wide default is ``NULL_TRACER``,
    so a ``trace: off`` run's metrics are byte-identical to an untraced
    build;
  * bounded — events land in a ``deque(maxlen=capacity)`` ring: a runaway
    stream overwrites the oldest events and counts the drops instead of
    growing without bound (a recorder must never OOM the thing it records);
  * deterministic — events carry *sim time* only, never wall clock, so two
    runs of the same seeded spec produce identical event streams.

Event vocabulary (``kind`` / who emits it / level):

  ``load``    executor begins an expert transfer (demand or overlap
              prefetch) — ``Executor.start_load``; summary
  ``evict``   executor evicts a pool resident to make room; summary
  ``xfer``    one channel leg of a transfer occupies a link (SSD / PCIe /
              peer ingress) — ``TransferEngine``; summary
  ``exec``    executor runs a batch — ``Executor.start_next_batch``; full.
              ``attrs["on"]`` is ``"host"`` when the batch executed in
              place on a CPU executor (heterogeneous co-execution),
              ``"device"`` otherwise
  ``assign``  scheduler placed a request on an executor queue
              (``CoServeSystem.assign``); full
  ``sched``   the scheduler's decision record (policy mode + choice)
              (``RequestScheduler.assign``); full
  ``admit`` / ``shed``  the admission gate's verdict on a fresh arrival
              (online gateway); full / summary
  ``scale``   autoscaler fleet action; summary
  ``decode``  one token-level decode step of an executor's continuous batch
              (``DecodeRuntime``) — ``attrs["requests"]`` is the step's
              membership, ``attrs["kv_wait"]`` the KV-reload portion of
              ``dur``; full
  ``kv``      a KV-block lifecycle transition (alloc / grow / offload /
              reload / spill / release) on a device pool — the bytes side
              of a decode event; the matching channel occupancy rides an
              ``xfer`` event with ``op`` ``kv_offload``/``kv_reload``;
              summary

``actor`` is the track the event belongs to (executor id, channel name,
"scheduler", "gateway", "autoscaler"); ``name`` is the subject (expert id,
tenant, action); ``dur`` > 0 makes it an interval, 0 an instant; free-form
``attrs`` carry the payload (bytes, link leg, request ids, ...).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List

TRACE_LEVELS = ("off", "summary", "full")
DEFAULT_CAPACITY = 262_144        # events; ~60 MB worst case, plenty for the
#                                   bench smokes the CI traces end to end

EVENT_KINDS = ("load", "evict", "xfer", "exec", "assign", "sched",
               "admit", "shed", "scale", "decode", "kv")


@dataclasses.dataclass
class Event:
    """One recorded occurrence, in sim time (seconds)."""
    t: float                      # sim time the event begins
    kind: str                     # one of EVENT_KINDS
    actor: str                    # track: executor / channel / control loop
    name: str                     # subject: expert id, tenant, action, ...
    dur: float = 0.0              # interval length (0 = instant)
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"t": self.t, "kind": self.kind, "actor": self.actor,
                "name": self.name, "dur": self.dur, "attrs": self.attrs}

    @classmethod
    def from_dict(cls, d: dict) -> "Event":
        return cls(t=d["t"], kind=d["kind"], actor=d["actor"],
                   name=d["name"], dur=d.get("dur", 0.0),
                   attrs=dict(d.get("attrs", {})))


class Tracer:
    """The ring-buffer recorder. ``enabled``/``full`` are plain booleans so
    disabled call sites cost one attribute read and nothing else."""

    def __init__(self, level: str = "summary",
                 capacity: int = DEFAULT_CAPACITY):
        if level not in TRACE_LEVELS:
            raise ValueError(f"trace level must be one of {TRACE_LEVELS}, "
                             f"got {level!r}")
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.level = level
        self.enabled = level != "off"
        self.full = level == "full"
        self.capacity = capacity
        self.events: "collections.deque[Event]" = \
            collections.deque(maxlen=capacity)
        self.dropped = 0

    # ------------------------------------------------------------------ #
    def emit(self, t: float, kind: str, actor: str, name: str,
             dur: float = 0.0, **attrs):
        if len(self.events) == self.capacity:
            self.dropped += 1          # the deque evicts the oldest event
        self.events.append(Event(t, kind, actor, name, dur, attrs))

    # ------------------------------------------------------------------ #
    def to_dicts(self) -> List[dict]:
        return [e.to_dict() for e in self.events]

    def by_kind(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for e in self.events:
            counts[e.kind] = counts.get(e.kind, 0) + 1
        return counts

    def snapshot(self) -> dict:
        return {"level": self.level, "capacity": self.capacity,
                "events": len(self.events), "dropped": self.dropped,
                "by_kind": self.by_kind()}


# the system-wide default: every traced object points here unless a real
# Tracer is wired in, so call sites never need a None check
NULL_TRACER = Tracer(level="off", capacity=0)
