"""Tiny leveled logger for the CLIs and benchmark harness.

Source of truth: the only place ``--quiet``/``--verbose`` semantics live —
``launch.serve`` and ``benchmarks.run`` report through here instead of
ad-hoc ``print`` calls.

Deliberately not ``logging``: at the default level, ``info`` output is the
message verbatim on stdout (flushed), so existing consumers of the CLI /
benchmark output see byte-identical text; ``debug`` adds a dim prefix and
only appears under ``--verbose``; ``warning``/``error`` go to stderr and
survive ``--quiet``.
"""
from __future__ import annotations

import sys

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}

_level = LEVELS["info"]


def set_level(name: str):
    """Set the global threshold ("debug" | "info" | "warning" | "error")."""
    global _level
    if name not in LEVELS:
        raise ValueError(f"unknown log level {name!r}, "
                         f"expected one of {sorted(LEVELS)}")
    _level = LEVELS[name]


def level_from_flags(quiet: bool = False, verbose: bool = False) -> str:
    """The CLI mapping: --quiet -> warning, --verbose -> debug."""
    if quiet and verbose:
        raise ValueError("--quiet and --verbose are mutually exclusive")
    return "warning" if quiet else "debug" if verbose else "info"


class Logger:
    def __init__(self, name: str = "repro"):
        self.name = name

    def debug(self, msg: str):
        if _level <= LEVELS["debug"]:
            print(f"[{self.name}] {msg}", flush=True)

    def info(self, msg: str):
        if _level <= LEVELS["info"]:
            print(msg, flush=True)

    def warning(self, msg: str):
        if _level <= LEVELS["warning"]:
            print(f"[{self.name}] warning: {msg}", file=sys.stderr,
                  flush=True)

    def error(self, msg: str):
        if _level <= LEVELS["error"]:
            print(f"[{self.name}] error: {msg}", file=sys.stderr, flush=True)


def get_logger(name: str = "repro") -> Logger:
    return Logger(name)
