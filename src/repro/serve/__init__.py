"""Online serving subsystem: streaming arrivals, multi-tenant SLO telemetry,
admission control and load-driven autoscaling over the CoServe core.

One source-of-truth per concern (stated in each module's docstring):
arrivals stamp tenant/deadline metadata, slo owns the targets, telemetry
owns the streaming counts, admission owns rejection, the autoscaler owns
runtime fleet changes, and the gateway is the single composition point.
"""
from repro.serve.admission import AdmissionConfig, AdmissionController
from repro.serve.arrivals import (BOARDS, TenantSpec, board_payload_stream,
                                  build_multi_board_coe, bursty_gaps,
                                  diurnal_gaps, make_gaps, merge_board_coe,
                                  merge_streams,
                                  multi_tenant_stream, poisson_gaps,
                                  step_gaps, tenant_stream)
from repro.serve.autoscaler import Autoscaler, AutoscalerConfig, ScaleEvent
from repro.serve.gateway import OnlineGateway, OnlineReport
from repro.serve.slo import SLOPolicy, SLOTarget, deadline_priority
from repro.serve.telemetry import (LatencyTracker, P2Quantile, TelemetryHub,
                                   WindowRate)

__all__ = [
    "AdmissionConfig", "AdmissionController", "BOARDS", "TenantSpec",
    "board_payload_stream", "build_multi_board_coe", "bursty_gaps",
    "diurnal_gaps", "make_gaps", "merge_board_coe", "merge_streams",
    "multi_tenant_stream",
    "poisson_gaps", "step_gaps", "tenant_stream", "Autoscaler",
    "AutoscalerConfig", "ScaleEvent", "OnlineGateway", "OnlineReport",
    "SLOPolicy", "SLOTarget", "deadline_priority", "LatencyTracker",
    "P2Quantile", "TelemetryHub", "WindowRate",
]
