"""Streaming SLO telemetry: windowed throughput + online quantiles.

Source of truth: the only accumulator of streaming per-tenant / per-expert
statistics and SLO-violation counts — the autoscaler and reports consume
this hub's numbers; nothing else counts violations.

Offline ``Metrics`` sorts every latency after the run; a 24/7 stream cannot.
``P2Quantile`` is the P-square algorithm (Jain & Chlamtac 1985): O(1) memory
per tracked quantile, five markers adjusted per observation with parabolic
interpolation. ``P2QuantileBank`` runs every tracked quantile's markers in
lockstep through one flattened, unrolled update per observation — the hot
path behind ``LatencyTracker`` (p50/p95/p99 + mean/max), numerically
identical to one ``P2Quantile`` per q (pinned by tests, measured by the
simperf suite). ``TelemetryHub`` keeps one tracker per tenant and per
expert arch plus a sliding completion window for instantaneous throughput —
the signals the autoscaler and admission controller consume.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.coe import Request


class P2Quantile:
    """Single-quantile P-square estimator (O(1) memory)."""

    def __init__(self, q: float):
        self.q = q
        self._init: List[float] = []     # exact until 5 observations
        self.n = 0
        self._pos: List[float] = []      # marker positions n_i
        self._des: List[float] = []      # desired positions n'_i
        self._h: List[float] = []        # marker heights q_i

    def add(self, x: float):
        self.n += 1
        if self._h == []:
            self._init.append(x)
            if len(self._init) == 5:
                self._init.sort()
                self._h = list(self._init)
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                q = self.q
                self._des = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q,
                             3.0 + 2.0 * q, 5.0]
            return
        h, pos = self._h, self._pos
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1.0
        q = self.q
        incr = (0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0)
        for i in range(5):
            self._des[i] += incr[i]
        # adjust interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._des[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
                    (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                d = 1.0 if d >= 1.0 else -1.0
                hp = self._parabolic(i, d)
                if not (h[i - 1] < hp < h[i + 1]):
                    hp = self._linear(i, d)
                h[i] = hp
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, n = self._h, self._pos
        return h[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        h, n = self._h, self._pos
        j = i + int(d)
        return h[i] + d * (h[j] - h[i]) / (n[j] - n[i])

    def value(self) -> float:
        if self._h:
            return self._h[2]
        if not self._init:
            return 0.0
        from repro.core.serving import nearest_rank
        return nearest_rank(sorted(self._init), self.q)


class P2QuantileBank:
    """Every tracked quantile's P-square markers updated in lockstep.

    Numerically identical to one ``P2Quantile`` per q fed the same stream
    (pinned by tests/test_telemetry_quantiles.py) but one flattened row per
    quantile instead of a Python object: marker state lives in a 16-slot
    list unpacked to locals, the 5-wide marker loops are unrolled, and the
    constants the scalar code recomputes per observation are folded
    (``pos[0]``/``des[0]`` never move; markers 0 and 4 are never
    parabolically adjusted; the desired-position increments are fixed per
    q). ~2.5x the observations/sec of the per-q estimators — this is
    ``LatencyTracker``'s hot path, hit once per completion and once per
    executed stage.
    """

    # row layout: h0..h4, p1..p4, des1..des4, incr1..incr3
    def __init__(self, qs):
        self.qs = tuple(qs)
        self.n = 0
        self._init: List[float] = []     # exact until 5 observations
        self._rows: List[List[float]] = []

    def add(self, x: float):
        self.n += 1
        rows = self._rows
        if not rows:
            ini = self._init
            ini.append(x)
            if len(ini) == 5:
                ini.sort()
                h0, h1, h2, h3, h4 = ini
                for q in self.qs:
                    rows.append([h0, h1, h2, h3, h4,
                                 2.0, 3.0, 4.0, 5.0,
                                 1.0 + 2.0 * q, 1.0 + 4.0 * q,
                                 3.0 + 2.0 * q, 5.0,
                                 q / 2.0, q, (1.0 + q) / 2.0])
            return
        for row in rows:
            (h0, h1, h2, h3, h4, p1, p2, p3, p4,
             d1, d2, d3, d4, i1, i2, i3) = row
            # cell search + position bumps (pos[i] += 1 for i > k), fused
            if x < h0:
                h0 = x
                p1 += 1.0; p2 += 1.0; p3 += 1.0
            elif x >= h4:
                h4 = x
            elif x < h1:
                p1 += 1.0; p2 += 1.0; p3 += 1.0
            elif x < h2:
                p2 += 1.0; p3 += 1.0
            elif x < h3:
                p3 += 1.0
            p4 += 1.0
            d1 += i1; d2 += i2; d3 += i3; d4 += 1.0
            # interior markers toward desired positions (pos0 == 1.0)
            d = d1 - p1
            if (d >= 1.0 and p2 - p1 > 1.0) or \
                    (d <= -1.0 and 1.0 - p1 < -1.0):
                d = 1.0 if d >= 1.0 else -1.0
                hp = h1 + d / (p2 - 1.0) * (
                    (p1 - 1.0 + d) * (h2 - h1) / (p2 - p1)
                    + (p2 - p1 - d) * (h1 - h0) / (p1 - 1.0))
                if not (h0 < hp < h2):
                    if d == 1.0:
                        hp = h1 + (h2 - h1) / (p2 - p1)
                    else:
                        hp = h1 - (h0 - h1) / (1.0 - p1)
                h1 = hp
                p1 += d
            d = d2 - p2
            if (d >= 1.0 and p3 - p2 > 1.0) or \
                    (d <= -1.0 and p1 - p2 < -1.0):
                d = 1.0 if d >= 1.0 else -1.0
                hp = h2 + d / (p3 - p1) * (
                    (p2 - p1 + d) * (h3 - h2) / (p3 - p2)
                    + (p3 - p2 - d) * (h2 - h1) / (p2 - p1))
                if not (h1 < hp < h3):
                    if d == 1.0:
                        hp = h2 + (h3 - h2) / (p3 - p2)
                    else:
                        hp = h2 - (h1 - h2) / (p1 - p2)
                h2 = hp
                p2 += d
            d = d3 - p3
            if (d >= 1.0 and p4 - p3 > 1.0) or \
                    (d <= -1.0 and p2 - p3 < -1.0):
                d = 1.0 if d >= 1.0 else -1.0
                hp = h3 + d / (p4 - p2) * (
                    (p3 - p2 + d) * (h4 - h3) / (p4 - p3)
                    + (p4 - p3 - d) * (h3 - h2) / (p3 - p2))
                if not (h2 < hp < h4):
                    if d == 1.0:
                        hp = h3 + (h4 - h3) / (p4 - p3)
                    else:
                        hp = h3 - (h2 - h3) / (p2 - p3)
                h3 = hp
                p3 += d
            row[0] = h0; row[1] = h1; row[2] = h2; row[3] = h3
            row[4] = h4; row[5] = p1; row[6] = p2; row[7] = p3
            row[8] = p4; row[9] = d1; row[10] = d2; row[11] = d3
            row[12] = d4

    def values(self) -> List[float]:
        """Current estimates, one per q (exact below 5 observations)."""
        if self._rows:
            return [r[2] for r in self._rows]
        if not self._init:
            return [0.0] * len(self.qs)
        from repro.core.serving import nearest_rank
        s = sorted(self._init)
        return [nearest_rank(s, q) for q in self.qs]


class LatencyTracker:
    """Mean/max + streaming p50/p95/p99 for one key (tenant, arch, ...)."""

    QS = (0.50, 0.95, 0.99)

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self._est = P2QuantileBank(self.QS)

    def add(self, latency: float):
        self.count += 1
        self.total += latency
        self.max = max(self.max, latency)
        self._est.add(latency)

    # a tail quantile estimated from fewer than this many tail samples
    # (count * (1-q)) is marked low-confidence in snapshots
    MIN_TAIL_SAMPLES = 10

    def snapshot(self) -> Dict[str, float]:
        # enforce quantile monotonicity (independent P2 estimators can cross
        # by estimation error on small samples): running max over p50<=p95<=p99
        vals = []
        hi = 0.0
        for v in self._est.values():
            hi = max(hi, v)
            vals.append(hi)
        return {"count": self.count,
                "mean": self.total / self.count if self.count else 0.0,
                "max": self.max,
                "p50": vals[0], "p95": vals[1], "p99": vals[2],
                "low_confidence": [
                    f"p{int(q * 100)}" for q in self.QS
                    if self.count * (1.0 - q) < self.MIN_TAIL_SAMPLES]}


class WindowRate:
    """Events-per-second over a sliding window of sim time."""

    def __init__(self, window_s: float = 10.0):
        self.window_s = window_s
        self._events: Deque[float] = collections.deque()

    def add(self, t: float):
        self._events.append(t)
        self._prune(t)

    def rate(self, now: float) -> float:
        self._prune(now)
        if not self._events:
            return 0.0
        # normalize by elapsed stream time until the window fills — dividing
        # by the distance to the oldest event explodes when one completion
        # lands at the sample instant
        span = min(self.window_s, max(now, 1e-9))
        return len(self._events) / span

    def _prune(self, now: float):
        while self._events and self._events[0] < now - self.window_s:
            self._events.popleft()


@dataclasses.dataclass
class TimelinePoint:
    """One periodic telemetry sample (the ticker writes these)."""
    t: float
    queue_depth: int
    executors: int
    throughput: float
    violation_rate: float
    shed: int


class TelemetryHub:
    """Aggregates streaming serving telemetry.

    Schema of ``snapshot()`` (also the CLI/benchmark JSON):
      arrived / completed / shed      — request counts
      throughput_rps                  — completions/s over the sliding window
      latency                         — overall LatencyTracker snapshot
      per_tenant[t]                   — end-to-end tracker + slo {target,
                                        violations, violation_rate}
      per_expert[arch]                — per-STAGE latency tracker (each chain
                                        hop samples the arch that served it)
      queue                           — max/final depth from the ticker
    (the full per-tick ``timeline`` is surfaced via OnlineReport)
    """

    def __init__(self, slo_targets: Optional[Dict[str, float]] = None,
                 window_s: float = 10.0):
        self.slo_targets = dict(slo_targets or {})
        self.arrived = 0
        self.completed = 0
        self.shed = 0
        self.shed_by_tenant: Dict[str, int] = {}
        self.overall = LatencyTracker()
        self.per_tenant: Dict[str, LatencyTracker] = {}
        self.per_expert: Dict[str, LatencyTracker] = {}
        self.violations: Dict[str, int] = {}
        self.tenant_completed: Dict[str, int] = {}
        self.window = WindowRate(window_s)
        self.timeline: List[TimelinePoint] = []
        self.max_queue_depth = 0
        # token-level decode (PR 9): streaming TTFT and inter-token latency,
        # fed by DecodeRuntime.attach_telemetry; empty when decode is off
        # (and then omitted from snapshot() so the schema is unchanged)
        self.ttft = LatencyTracker()
        self.token = LatencyTracker()

    # --- event hooks ---------------------------------------------------- #
    def on_arrival(self, req: Request, now: float):
        self.arrived += 1

    def on_shed(self, req: Request, now: float):
        self.shed += 1
        self.shed_by_tenant[req.tenant] = \
            self.shed_by_tenant.get(req.tenant, 0) + 1

    def on_complete(self, req: Request, now: float):
        """Chain-terminal completion: end-to-end latency, per tenant."""
        lat = now - req.e2e_arrival()
        self.completed += 1
        self.window.add(now)
        self.overall.add(lat)
        self.per_tenant.setdefault(req.tenant, LatencyTracker()).add(lat)
        self.tenant_completed[req.tenant] = \
            self.tenant_completed.get(req.tenant, 0) + 1
        target = self.slo_targets.get(req.tenant)
        if target is not None and lat > target:
            self.violations[req.tenant] = self.violations.get(req.tenant, 0) + 1

    def on_stage(self, req: Request, arch: str, now: float):
        """Every executed stage (incl. intermediate chain hops): the stage's
        own queue+exec latency, keyed by the arch that served it — chain
        latency must not be attributed to the terminal expert alone."""
        self.per_expert.setdefault(arch, LatencyTracker()).add(
            now - req.arrival_time)

    def on_first_token(self, latency: float):
        """Time-to-first-token of one request (arrival -> first decode
        step completion)."""
        self.ttft.add(latency)

    def on_token(self, latency: float):
        """One inter-token gap (consecutive decode-step completions)."""
        self.token.add(latency)

    def sample(self, now: float, queue_depth: int, executors: int):
        self.max_queue_depth = max(self.max_queue_depth, queue_depth)
        self.timeline.append(TimelinePoint(
            t=now, queue_depth=queue_depth, executors=executors,
            throughput=self.window.rate(now),
            violation_rate=self.violation_rate(), shed=self.shed))

    # --- derived signals ------------------------------------------------ #
    def violation_rate(self, tenant: Optional[str] = None) -> float:
        if tenant is not None:
            done = self.tenant_completed.get(tenant, 0)
            return self.violations.get(tenant, 0) / done if done else 0.0
        done = sum(self.tenant_completed.values())
        return sum(self.violations.values()) / done if done else 0.0

    def snapshot(self, now: float) -> dict:
        per_tenant = {}
        for t, tracker in sorted(self.per_tenant.items()):
            snap = tracker.snapshot()
            target = self.slo_targets.get(t)
            snap["slo"] = {
                "target_s": target,
                "violations": self.violations.get(t, 0),
                "violation_rate": round(self.violation_rate(t), 4),
                "shed": self.shed_by_tenant.get(t, 0),
            }
            per_tenant[t] = snap
        out = {
            "arrived": self.arrived,
            "completed": self.completed,
            "shed": self.shed,
            "throughput_rps": round(self.window.rate(now), 3),
            "latency": self.overall.snapshot(),
            "per_tenant": per_tenant,
            "per_expert": {a: tr.snapshot()
                           for a, tr in sorted(self.per_expert.items())},
            "queue": {"max_depth": self.max_queue_depth,
                      "final_depth": self.timeline[-1].queue_depth
                      if self.timeline else 0},
        }
        if self.ttft.count or self.token.count:
            out["decode"] = {"ttft": self.ttft.snapshot(),
                             "token": self.token.snapshot()}
        return out
