"""Per-tenant SLO targets and deadline-aware scheduling priority.

Source of truth: the only mapping from tenant to latency target —
violation classification (TelemetryHub) and EDF queue priority both read
the targets from here, so "violates its SLO" has one definition.

An SLO is an end-to-end latency target per tenant (``TenantSpec.slo_seconds``
stamps each request's absolute ``deadline`` at generation time). Two
consumers:

  * ``deadline_priority`` plugs into ``RequestScheduler.priority_fn`` —
    new queue groups are inserted earliest-deadline-first, so a tight-SLO
    tenant's work overtakes slack work *without* breaking the paper's
    arranging (same-expert requests still merge into one group; the group
    carries its earliest member deadline).
  * ``SLOPolicy.target_map`` hands the tenant -> target map to
    ``TelemetryHub``, which owns violation classification (one definition).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

from repro.core.coe import Request

_FAR_FUTURE = 1e30   # deadline for requests with no SLO: never overtakes


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    tenant: str
    latency_s: float            # end-to-end target


def deadline_priority(req: Request) -> float:
    """Scheduler hook: absolute deadline (earlier = more urgent)."""
    return req.deadline if req.deadline is not None else _FAR_FUTURE


@dataclasses.dataclass
class SLOPolicy:
    """The tenant -> target map used by telemetry, admission and scaling."""
    targets: Dict[str, SLOTarget]

    @classmethod
    def from_tenants(cls, tenants: Sequence) -> "SLOPolicy":
        return cls(targets={t.name: SLOTarget(t.name, t.slo_seconds)
                            for t in tenants})

    def target_map(self) -> Dict[str, float]:
        return {name: t.latency_s for name, t in self.targets.items()}
