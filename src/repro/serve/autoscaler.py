"""Load-driven executor autoscaling over the elasticity hooks.

Source of truth: the only runtime caller of ``add_executor`` /
``fail_executor`` / ``rebalance_placement`` on the online path — fleet
shape changes, their batch-budget re-division and the resulting placement
rebalances all originate from this control loop's ``step``.

The seed already supports runtime topology changes (``add_executor`` /
``fail_executor`` + INJECT, built for the fault-tolerance tests); this module
closes the loop: a periodic controller reads queue depth and SLO-violation
telemetry and scales the executor fleet between ``min_executors`` and
``max_executors``.

Relative to the offline ``launch.elastic.ElasticController`` (which pre-
materializes INJECT ticks over a fixed horizon), this controller rides the
simulator's self-rescheduling TICK events, so it works on unbounded streams,
and it adds the SLO-violation signal from streaming telemetry.

Scale-up when either signal is hot (queued requests per executor above
``up_queue_per_executor``, or windowed violation rate above
``up_violation_rate``); scale-down only when the queue is cold AND the SLO is
comfortably met. Asymmetric thresholds + a cooldown give hysteresis so the
controller doesn't flap on bursty traffic. Scale-down drains by failing the
emptiest *scaled* executor — its orphaned requests re-enter the arrival path
(at-most-once), exactly like the fault-tolerance path, so no work is lost.
Baseline executors (the operator-configured floor) are never removed.
Because scaled executors share the same physical device pool, the fleet's
total activation (batch) memory is held fixed and re-divided on every
scaling action — more executors mean more parallel queues and load channels,
not conjured memory.

On a multi-device fleet the controller is topology-aware: scale-up targets
the device pool with the highest queued-requests-per-executor (not a fixed
``pool_group``), and every scaling action rebalances the system's
``PlacementPlan`` — replication is re-planned with pools weighted by their
new executor counts and the hottest missing replicas are pulled in through
the contended load path — so placement follows capacity instead of staying
frozen at construction.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.serving import ExecutorSpec

from repro.serve.telemetry import TelemetryHub


@dataclasses.dataclass
class AutoscalerConfig:
    spec: ExecutorSpec                   # template for scaled-up executors
    min_executors: int = 1
    max_executors: int = 8
    up_queue_per_executor: float = 12.0  # scale up above this queue pressure
    down_queue_per_executor: float = 2.0 # scale down below this
    up_violation_rate: float = 0.10      # scale up above this SLO violation rate
    down_violation_rate: float = 0.01    # scale down only below this
    cooldown_s: float = 5.0              # min gap between scaling actions
    fleet_aware: bool = True             # scale-up picks the hottest device
    #                                      pool; actions rebalance placement
    rebalance_loads: int = 4             # max replica loads per scale event


@dataclasses.dataclass
class ScaleEvent:
    t: float
    action: str          # "up" | "down"
    executor_id: str
    reason: str
    n_executors: int     # fleet size after the action


class Autoscaler:
    """Periodic control step; wire via ``sim.add_ticker(interval, as_.step)``."""

    def __init__(self, config: AutoscalerConfig,
                 telemetry: Optional[TelemetryHub] = None):
        self.config = config
        self.telemetry = telemetry
        self.events: List[ScaleEvent] = []
        self._scaled_ids: List[str] = []     # executors this loop added
        self._last_action_t = -1e30
        self._last_violations = 0
        self._last_completed = 0
        self._batch_budgets: dict = {}             # fixed activation regions
        self.placement_loads = 0                   # replica loads issued

    # ------------------------------------------------------------------ #
    def _pool_group(self) -> str:
        return self.config.spec.pool_group or self.config.spec.device

    def _target_group(self, sim) -> str:
        """Which device pool a scale-up lands on: the spec's own group, or —
        fleet-aware — the compatible pool with the highest queued requests
        per executor (ties to the spec's group), so capacity goes where the
        backlog is."""
        base = self._pool_group()
        if not self.config.fleet_aware:
            return base
        kind = self.config.spec.device
        membership = getattr(sim.system, "pool_devices", {})
        cands = [g for g, dev in membership.items() if dev == kind] or [base]
        per_group: dict = {g: [0, 0] for g in cands}     # [queued, execs]
        for e in sim.system.live_executors():
            if e.pool.group in per_group:
                per_group[e.pool.group][0] += e.queued_requests()
                per_group[e.pool.group][1] += 1
        return max(cands, key=lambda g: (
            per_group[g][0] / max(1, per_group[g][1]), g == base))

    def _rebalance_batch(self, sim, group: str):
        """The modeled device's activation region is fixed: adding executors
        must split it, not mint new memory. The budget is the memory
        hierarchy's construction-time activation accounting for this pool
        group (expert-pool bytes stay with the shared DevicePool); re-divide
        it across all live executors on the scaled pool."""
        peers = [e for e in sim.system.live_executors()
                 if e.pool.group == group]
        if not peers:
            return
        if group not in self._batch_budgets:
            hierarchy = getattr(sim.system, "hierarchy", None)
            budget = hierarchy.batch_budget(group) if hierarchy else 0
            self._batch_budgets[group] = \
                budget or sum(e.batch_bytes for e in peers)
        share = self._batch_budgets[group] // len(peers)
        for e in peers:
            e.batch_bytes = share

    def _rebalance_placement(self, sim, now: float):
        """Scale events rebalance the PlacementPlan, not just batch budgets:
        replication follows the fleet's new shape and the issued replica
        loads get their LOAD_DONE events like any other transfer."""
        if not self.config.fleet_aware:
            return
        rebalance = getattr(sim.system, "rebalance_placement", None)
        if rebalance is None:
            return
        from repro.core.simulator import LOAD_DONE
        for ex, eid, done in rebalance(now,
                                       max_loads=self.config.rebalance_loads):
            self.placement_loads += 1
            sim.push(done, LOAD_DONE, (ex, eid))

    def _record(self, sim, ev: ScaleEvent):
        self.events.append(ev)
        tracer = sim.system.tracer
        if tracer.enabled:
            tracer.emit(ev.t, "scale", "autoscaler", ev.action,
                        executor=ev.executor_id, reason=ev.reason,
                        n_executors=ev.n_executors)

    # ------------------------------------------------------------------ #
    def _window_violation_rate(self) -> float:
        """Violation rate since the previous *actionable* control step (not
        lifetime — a long good history must not mask a fresh overload).
        Only called once past the cooldown gate, so violations accrued
        during cooldown still count toward the next decision."""
        if self.telemetry is None:
            return 0.0
        viol = sum(self.telemetry.violations.values())
        done = sum(self.telemetry.tenant_completed.values())
        d_viol = viol - self._last_violations
        d_done = done - self._last_completed
        self._last_violations, self._last_completed = viol, done
        return d_viol / d_done if d_done > 0 else 0.0

    def step(self, sim, now: float):
        cfg = self.config
        if now - self._last_action_t < cfg.cooldown_s:
            return
        live = sim.system.live_executors()
        n = len(live)
        pressure = sim.system.queue_depth() / n if n else float("inf")
        vrate = self._window_violation_rate()

        if n < cfg.max_executors and (
                pressure > cfg.up_queue_per_executor
                or vrate > cfg.up_violation_rate):
            group = self._target_group(sim)
            spec = cfg.spec if group == self._pool_group() \
                else dataclasses.replace(cfg.spec, pool_group=group)
            self._rebalance_batch(sim, group)   # snapshot budget pre-growth
            ex = sim.system.add_executor(spec)
            self._rebalance_batch(sim, group)
            self._scaled_ids.append(ex.id)
            self._last_action_t = now
            reason = (f"queue_pressure={pressure:.1f}"
                      if pressure > cfg.up_queue_per_executor
                      else f"violation_rate={vrate:.3f}")
            self._record(sim, ScaleEvent(now, "up", ex.id, reason, n + 1))
            self._rebalance_placement(sim, now)
            return

        if n > cfg.min_executors and self._scaled_ids \
                and pressure < cfg.down_queue_per_executor \
                and vrate <= cfg.down_violation_rate:
            victim = self._pick_victim(sim)
            if victim is None:
                return
            victim_group = victim.pool.group
            from repro.core.simulator import ARRIVAL
            orphans = sim.system.fail_executor(victim, now)
            for r in orphans:
                sim.push(now, ARRIVAL, r)    # re-queue, like the failure path
            for peer in sim.system.live_executors():
                sim.kick(peer, now)
            self._rebalance_batch(sim, victim_group)
            self._scaled_ids.remove(victim.id)
            self._last_action_t = now
            self._record(sim, ScaleEvent(
                now, "down", victim.id,
                f"queue_pressure={pressure:.1f}", n - 1))
            self._rebalance_placement(sim, now)

    def _pick_victim(self, sim):
        """Emptiest scaled-up executor (cheapest drain); never the baseline
        fleet, never one mid-load."""
        cands = [e for e in sim.system.live_executors()
                 if e.id in self._scaled_ids and e.load_in_flight is None]
        if not cands:
            return None
        return min(cands, key=lambda e: (e.queued_requests(),
                                         e.current is not None))

    # ------------------------------------------------------------------ #
    def summary(self) -> dict:
        return {
            "actions": len(self.events),
            "scale_ups": sum(1 for e in self.events if e.action == "up"),
            "scale_downs": sum(1 for e in self.events if e.action == "down"),
            "placement_loads": self.placement_loads,
            "events": [dataclasses.asdict(e) for e in self.events],
        }
