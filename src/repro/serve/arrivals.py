"""Composable arrival processes and multi-tenant request streams.

Source of truth: the only generator of online Requests — tenant identity,
deadline stamping (``arrival + slo_seconds``) and the chain-root arrival
anchor are set here once, and every downstream consumer (telemetry, SLO
classification, EDF priority) reads them instead of re-deriving.

Offline evaluation materializes the whole task up front
(``workload.make_task_requests``); the online layer instead *generates*
arrivals lazily so a stream can run indefinitely in O(1) memory:

  interarrival process (Poisson | MMPP bursty | diurnal | load step)
      x  per-tenant payload stream (board-scan order or uniform random)
      ->  heap-merged multi-tenant Request generator

Tenants map onto circuit boards (BOARD_A / BOARD_B): a tenant is a product
line streaming inspection images at its own rate, traffic shape and SLO.
``build_multi_board_coe`` merges several boards into one expert catalog so
heterogeneous tenants share the executors — the contention the SLO/admission
layers manage.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.coe import CoEModel, ExpertSpec, Request, RoutingModule
from repro.core.workload import (BOARD_A, BOARD_B, BoardSpec, active_types,
                                 board_layout, build_board_coe,
                                 component_distribution)

BOARDS = {"A": BOARD_A, "B": BOARD_B}


# --------------------------------------------------------------------------- #
# interarrival processes (generators of gaps, seconds)
# --------------------------------------------------------------------------- #

def poisson_gaps(rate: float, rng: np.random.RandomState) -> Iterator[float]:
    """Memoryless arrivals at ``rate`` req/s."""
    while True:
        yield float(rng.exponential(1.0 / rate))


def bursty_gaps(rate: float, rng: np.random.RandomState,
                burst_factor: float = 8.0, on_fraction: float = 0.2,
                mean_phase_s: float = 2.0) -> Iterator[float]:
    """Two-state MMPP: exponential ON/OFF phases; the ON rate is
    ``burst_factor`` times the OFF rate, scaled so the long-run mean is
    ``rate``. Models camera-line bursts between idle conveyor gaps."""
    lo = rate / (on_fraction * burst_factor + (1.0 - on_fraction))
    hi = burst_factor * lo
    on = False
    phase_left = 0.0
    t_gap = 0.0
    while True:
        lam = hi if on else lo
        gap = float(rng.exponential(1.0 / lam))
        while gap > phase_left:   # phase flips mid-gap: re-draw the remainder
            t_gap += phase_left
            gap = (gap - phase_left) * lam   # residual, rate-normalized
            on = not on
            lam = hi if on else lo
            gap = gap / lam
            mean = mean_phase_s * (on_fraction if on else 1.0 - on_fraction)
            phase_left = float(rng.exponential(mean))
        phase_left -= gap
        yield t_gap + gap
        t_gap = 0.0


def diurnal_gaps(rate: float, rng: np.random.RandomState,
                 period_s: float = 120.0, amplitude: float = 0.8
                 ) -> Iterator[float]:
    """Sinusoidally modulated Poisson (thinning): rate(t) = rate *
    (1 + amplitude * sin(2 pi t / period)). A compressed day/night ramp."""
    lam_max = rate * (1.0 + amplitude)
    t = 0.0
    while True:
        total = 0.0
        while True:
            gap = float(rng.exponential(1.0 / lam_max))
            total += gap
            t += gap
            lam = rate * (1.0 + amplitude * math.sin(2 * math.pi * t / period_s))
            if rng.rand() * lam_max <= lam:
                break
        yield total


def step_gaps(rate_before: float, rate_after: float, t_step: float,
              rng: np.random.RandomState) -> Iterator[float]:
    """Poisson with a rate step at ``t_step`` — the autoscaler's unit test
    signal (load suddenly doubles when a second shift starts)."""
    t = 0.0
    while True:
        lam = rate_before if t < t_step else rate_after
        gap = float(rng.exponential(1.0 / lam))
        t += gap
        yield gap


PROCESSES = ("poisson", "bursty", "diurnal", "step")
REQUEST_CLASSES = ("scan", "random")


def make_gaps(process: str, rate: float, rng: np.random.RandomState,
              **kw) -> Iterator[float]:
    if process == "poisson":
        return poisson_gaps(rate, rng)
    if process == "bursty":
        return bursty_gaps(rate, rng, **kw)
    if process == "diurnal":
        return diurnal_gaps(rate, rng, **kw)
    if process == "step":
        return step_gaps(rate, kw.get("rate_after", 2.0 * rate),
                         kw.get("t_step", 10.0), rng)
    raise ValueError(f"unknown arrival process {process!r} "
                     f"(choose from {PROCESSES})")


# --------------------------------------------------------------------------- #
# tenant specification + payload streams
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One traffic source: a product line with its own board, rate, traffic
    shape, request class and latency SLO."""
    name: str
    board: BoardSpec
    rate: float = 50.0              # mean offered load, req/s
    process: str = "poisson"        # poisson | bursty | diurnal | step
    request_class: str = "scan"     # scan (board-scan locality) | random
    slo_seconds: float = 2.0        # per-request end-to-end latency target
    seed: int = 0
    process_kwargs: Tuple[Tuple[str, object], ...] = ()

    def __post_init__(self):
        if self.process not in PROCESSES:
            raise ValueError(f"unknown arrival process {self.process!r} "
                             f"(choose from {PROCESSES})")
        if self.request_class not in REQUEST_CLASSES:
            raise ValueError(f"unknown request class {self.request_class!r} "
                             f"(choose from {REQUEST_CLASSES})")
        if self.rate <= 0.0:
            raise ValueError(f"tenant {self.name!r}: rate must be positive, "
                             f"got {self.rate}")
        if self.slo_seconds <= 0.0:
            raise ValueError(f"tenant {self.name!r}: slo_seconds must be "
                             f"positive, got {self.slo_seconds}")

    def kwargs(self) -> Dict[str, object]:
        return dict(self.process_kwargs)


def board_payload_stream(board: BoardSpec, seed: int,
                         request_class: str = "scan") -> Iterator[dict]:
    """Endless stream of request payloads for one board.

    ``scan`` visits active component types in shuffled placement order with
    all images of a type adjacent (the locality CoServe's arranging exploits);
    ``random`` draws types independently from the quantity distribution —
    a worst-case tenant with no locality.

    This deliberately parallels ``workload.make_task_requests`` rather than
    sharing its loop: that function's RNG consumption order defines the
    offline paper workload realization, and an infinite generator cannot
    reproduce its draw order without changing those numbers. Keep the
    payload schema (component/outcome/needs_detection/det_expert [+board])
    in sync with it and with ``build_multi_board_coe``'s routing.
    """
    if request_class not in REQUEST_CLASSES:
        raise ValueError(f"unknown request class {request_class!r} "
                         f"(choose from {REQUEST_CLASSES})")
    rng = np.random.RandomState(seed)
    dist = component_distribution(board, 0)
    act = active_types(board, 0)
    probs = dist[act]
    needs_det, det_assign = board_layout(board, 0)
    per_board_total = board.n_active * board.avg_quantity

    def payload(c: int) -> dict:
        ok = bool(rng.rand() < board.ok_prob)
        return {"board": board.name, "component": int(c),
                "outcome": "ok" if ok else "defect",
                "needs_detection": bool(needs_det[c]),
                "det_expert": int(det_assign[c])}

    if request_class == "random":
        p = probs / probs.sum()
        while True:
            yield payload(int(rng.choice(act, p=p)))
    while True:
        order = rng.permutation(act)
        for c in order:
            q = max(1, int(rng.poisson(
                probs[np.searchsorted(act, c)] * per_board_total)))
            for _ in range(q):
                yield payload(int(c))


def tenant_stream(tenant: TenantSpec, ids: Iterator[int],
                  t0: float = 0.0) -> Iterator[Request]:
    """Timestamped Request generator for one tenant (monotone arrivals)."""
    from repro.core.workload import _name_seed
    rng = np.random.RandomState(tenant.seed + _name_seed(tenant.name))
    gaps = make_gaps(tenant.process, tenant.rate, rng, **tenant.kwargs())
    payloads = board_payload_stream(tenant.board, tenant.seed,
                                    tenant.request_class)
    t = t0
    for gap, data in zip(gaps, payloads):
        t += gap
        yield Request(
            id=next(ids),
            expert_id=f"{tenant.board.name}_cls{data['component']:03d}",
            arrival_time=t, task_id=tenant.name, data=data,
            tenant=tenant.name, deadline=t + tenant.slo_seconds,
            root_arrival_time=t)


def merge_streams(streams: Sequence[Iterator[Request]]) -> Iterator[Request]:
    """Heap-merge per-tenant streams into one globally time-ordered stream,
    pulling lazily (one pending request per tenant)."""
    return heapq.merge(*streams, key=lambda r: r.arrival_time)


def multi_tenant_stream(tenants: Sequence[TenantSpec],
                        max_requests: Optional[int] = None
                        ) -> Iterator[Request]:
    ids = itertools.count()
    merged = merge_streams([tenant_stream(t, ids) for t in tenants])
    return itertools.islice(merged, max_requests) \
        if max_requests is not None else merged


# --------------------------------------------------------------------------- #
# multi-board CoE (tenants over different boards share one system)
# --------------------------------------------------------------------------- #

def merge_board_coe(boards: Sequence[BoardSpec],
                    weights: Optional[Sequence[float]] = None
                    ) -> CoEModel:
    """Merge several boards' expert catalogs into one CoE. Expert ids are
    already board-prefixed (``A_cls000``), so distinct boards union
    disjointly; a board named by several tenants appears once with its
    tenants' traffic shares summed. Usage probabilities are scaled by each
    board's total share so initial placement favours the hot experts.

    Prefer the declarative path: a ``DeploymentSpec`` with
    ``model.kind="tenants"`` builds this catalog via
    ``repro.api.build_catalog`` — spec-driven callers get the tenant-rate
    weighting (or ``model.tenant_weights``) for free."""
    if weights is None:
        weights = [1.0] * len(boards)
    total = sum(weights) or 1.0
    share_by_board: Dict[str, float] = {}
    unique_boards: Dict[str, BoardSpec] = {}
    for board, w in zip(boards, weights):
        unique_boards[board.name] = board
        share_by_board[board.name] = \
            share_by_board.get(board.name, 0.0) + w / total

    experts: List[ExpertSpec] = []
    chain_prob: Dict[str, Dict[str, float]] = {}
    for name, board in unique_boards.items():
        sub = build_board_coe(board)
        for spec in sub.experts.values():
            experts.append(dataclasses.replace(
                spec, usage_prob=spec.usage_prob * share_by_board[name]))
        chain_prob.update(sub.routing.chain_prob)

    def first_expert(data) -> str:
        return f"{data['board']}_cls{data['component']:03d}"

    def next_expert(req: Request, eid: str, output) -> Optional[str]:
        d = req.data or {}
        bname = d.get("board", "")
        if eid.startswith(f"{bname}_cls") and d.get("needs_detection") \
                and output == "ok":
            return f"{bname}_det{d['det_expert']:02d}"
        return None

    return CoEModel(experts,
                    RoutingModule(first_expert, next_expert, chain_prob))


def build_multi_board_coe(boards: Sequence[BoardSpec],
                          weights: Optional[Sequence[float]] = None
                          ) -> CoEModel:
    """Deprecated alias of ``merge_board_coe`` (kept so downstream callers
    migrate without breaking): new code should declare the tenant mix in a
    ``DeploymentSpec`` (``model.kind="tenants"``) and let
    ``repro.api.build_catalog`` build the merged catalog."""
    import warnings
    warnings.warn(
        "build_multi_board_coe(...) direct kwargs are deprecated — declare "
        'the tenant mix in a DeploymentSpec (model.kind="tenants") and use '
        "repro.api.build_catalog, or call merge_board_coe for the raw merge",
        DeprecationWarning, stacklevel=2)
    return merge_board_coe(boards, weights)
