"""Online serving gateway: streaming front-end over the event-driven core.

Source of truth: the only composition point of the online subsystem — the
simulation hooks (admission, completion, per-stage telemetry, ticks) are
wired exactly once here, so there is one place where "what runs on an
online tick" is defined.

``OnlineGateway`` wires the pieces of the online subsystem around an existing
``CoServeSystem`` (either engine — ``SimEngine`` advances virtual time from
profiles, ``RealEngine`` advances it by measured wall time of real JAX
expert loads/forwards):

  arrivals   — a lazy Request generator feeds ``Simulation.set_source``
               (one pending arrival in memory, streams can be unbounded)
  telemetry  — completion hooks update streaming per-tenant/per-expert
               p50/p95/p99 + windowed throughput; a periodic tick samples
               queue depth and fleet size into a timeline
  slo        — tenant deadlines drive the scheduler's EDF priority hook
  admission  — optional load shedding on fresh arrivals
  autoscaler — optional control loop scaling executors on the tick
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.coe import Request
from repro.core.serving import CoServeSystem, Metrics
from repro.core.simulator import Simulation

from repro.serve.admission import AdmissionController
from repro.serve.arrivals import TenantSpec, multi_tenant_stream
from repro.serve.autoscaler import Autoscaler
from repro.serve.slo import SLOPolicy, deadline_priority
from repro.serve.telemetry import TelemetryHub


@dataclasses.dataclass
class OnlineReport:
    """Everything an online run produces (offline Metrics + streaming view)."""
    metrics: Metrics
    telemetry: dict
    admission: Optional[dict]
    autoscaler: Optional[dict]
    timeline: list

    def to_json(self) -> dict:
        m = self.metrics
        return {
            "completed": m.completed,
            "shed": self.telemetry.get("shed", 0),
            "throughput": round(m.throughput, 3),
            "makespan_s": round(m.makespan, 3),
            "switches": m.switches,
            "latency_s": {"avg": round(m.avg_latency, 4),
                          "p50": round(m.p50_latency, 4),
                          "p95": round(m.p95_latency, 4),
                          "p99": round(m.p99_latency, 4)},
            "per_tenant": self.telemetry.get("per_tenant", {}),
            "per_expert": self.telemetry.get("per_expert", {}),
            "queue": self.telemetry.get("queue", {}),
            "slo_violation_rate": self.telemetry.get("violation_rate", 0.0),
            "admission": self.admission,
            "autoscaler": self.autoscaler,
            "timeline": self.timeline,
        }


class OnlineGateway:
    def __init__(self, system: CoServeSystem,
                 tenants: Sequence[TenantSpec],
                 admission: Optional[AdmissionController] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 slo_priority: bool = True,
                 tick_interval: float = 0.5,
                 telemetry_window_s: float = 10.0):
        self.system = system
        self.tenants = list(tenants)
        self.slo = SLOPolicy.from_tenants(self.tenants)
        self.telemetry = TelemetryHub(slo_targets=self.slo.target_map(),
                                      window_s=telemetry_window_s)
        self.admission = admission
        self.autoscaler = autoscaler
        if autoscaler is not None and autoscaler.telemetry is None:
            autoscaler.telemetry = self.telemetry
        self.tick_interval = tick_interval
        if slo_priority:
            system.scheduler.priority_fn = deadline_priority
        self.sim = Simulation(system)

    # ------------------------------------------------------------------ #
    def _tick(self, sim: Simulation, now: float):
        self.telemetry.sample(now, self.system.queue_depth(),
                              len(self.system.live_executors()))
        if self.autoscaler is not None:
            self.autoscaler.step(sim, now)

    # ------------------------------------------------------------------ #
    def run(self, max_requests: Optional[int] = None,
            source: Optional[Iterable[Request]] = None) -> OnlineReport:
        """Serve ``max_requests`` from the tenant mix (or an explicit
        ``source`` generator, which may be finite) to completion, collecting
        telemetry."""
        if max_requests is None and source is None:
            raise ValueError(
                "run() needs max_requests or a finite source: the default "
                "tenant mix is an unbounded stream and would never return")
        if getattr(self, "_ran", False):
            raise RuntimeError(
                "OnlineGateway.run() is single-shot: the Simulation and "
                "telemetry accumulate state — build a fresh gateway (and "
                "system) per run")
        self._ran = True
        sim = self.sim
        hub = self.telemetry
        tracer = self.system.tracer

        def on_admit(s: Simulation, req: Request) -> bool:
            hub.on_arrival(req, s.now)
            if self.admission is not None and not self.admission(s, req):
                hub.on_shed(req, s.now)
                if tracer.enabled:
                    tracer.emit(s.now, "shed", "gateway", req.tenant,
                                request=req.id,
                                policy=self.admission.config.policy)
                return False
            if tracer.full:
                tracer.emit(s.now, "admit", "gateway", req.tenant,
                            request=req.id, expert=req.expert_id)
            return True

        def on_complete(s: Simulation, req: Request, now: float):
            hub.on_complete(req, now)

        def on_stage(s: Simulation, req: Request, expert_id: str, now: float):
            hub.on_stage(req, self.system.coe.spec(expert_id).arch, now)

        sim.admission = on_admit
        sim.on_complete = on_complete
        sim.on_stage = on_stage
        sim.add_ticker(self.tick_interval, self._tick, start=0.0)
        stream = source if source is not None \
            else multi_tenant_stream(self.tenants, max_requests)
        sim.set_source(stream)
        metrics = sim.run()
        snap = hub.snapshot(sim.now)
        snap["violation_rate"] = round(hub.violation_rate(), 4)
        return OnlineReport(
            metrics=metrics,
            telemetry=snap,
            admission=self.admission.stats() if self.admission else None,
            autoscaler=self.autoscaler.summary() if self.autoscaler else None,
            timeline=[dataclasses.asdict(p) for p in hub.timeline],
        )
