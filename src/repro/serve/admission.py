"""Load-shedding admission control for the online gateway.

Source of truth: the only place an arrival may be rejected — shedding
happens on fresh SOURCE arrivals in one hook, never mid-chain and never
inside the scheduler, so "admitted" has exactly one meaning in telemetry.

Under sustained overload an open queue grows without bound and *every*
tenant's tail latency diverges. The controller gates fresh arrivals (never
in-flight follow-ups — shedding mid-chain would strand pinned experts and
waste the classification work already done) using one of three policies:

  queue_depth    — reject when total queued requests exceed ``max_queue``
                   (bounds memory and worst-case wait; the acceptance
                   criterion's bounded-vs-unbounded demonstration)
  deadline       — reject when the *predicted* wait on the best executor
                   already exceeds the request's SLO slack: work that is
                   guaranteed late is not worth admitting
  token_bucket   — per-tenant rate cap (burst-tolerant fairness: one tenant's
                   burst cannot crowd out the others' admission budget)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.coe import Request


@dataclasses.dataclass
class AdmissionConfig:
    policy: str = "queue_depth"      # queue_depth | deadline | token_bucket
    max_queue: int = 200             # queue_depth: global queued-request cap
    slack_factor: float = 1.0        # deadline: admit while wait < slack*SLO
    bucket_rate: float = 100.0       # token_bucket: tokens/s per tenant
    bucket_burst: float = 50.0       # token_bucket: capacity


class AdmissionController:
    """Callable gate: ``controller(sim, req) -> bool`` (False = shed).

    Wire it to ``Simulation.admission``; it only ever sees SOURCE arrivals,
    so chained follow-ups are structurally exempt.
    """

    def __init__(self, config: Optional[AdmissionConfig] = None):
        self.config = config or AdmissionConfig()
        self.admitted = 0
        self.rejected = 0
        self._tokens: Dict[str, float] = {}
        self._token_t: Dict[str, float] = {}

    # ------------------------------------------------------------------ #
    def __call__(self, sim, req: Request) -> bool:
        ok = self._decide(sim, req)
        if ok:
            self.admitted += 1
        else:
            self.rejected += 1
        return ok

    def _decide(self, sim, req: Request) -> bool:
        cfg = self.config
        if cfg.policy == "queue_depth":
            return sim.system.queue_depth() < cfg.max_queue
        if cfg.policy == "deadline":
            if req.deadline is None:
                return True
            waits = [e.pending_time(sim.now)
                     for e in sim.system.live_executors()]
            best_wait = min(waits) if waits else 0.0
            slack = req.deadline - sim.now
            return best_wait <= cfg.slack_factor * slack
        if cfg.policy == "token_bucket":
            t_last = self._token_t.get(req.tenant, sim.now)
            level = self._tokens.get(req.tenant, cfg.bucket_burst)
            level = min(cfg.bucket_burst,
                        level + (sim.now - t_last) * cfg.bucket_rate)
            self._token_t[req.tenant] = sim.now
            if level >= 1.0:
                self._tokens[req.tenant] = level - 1.0
                return True
            self._tokens[req.tenant] = level
            return False
        raise ValueError(f"unknown admission policy {cfg.policy!r}")

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        total = self.admitted + self.rejected
        return {"policy": self.config.policy, "admitted": self.admitted,
                "rejected": self.rejected,
                "rejection_rate": self.rejected / total if total else 0.0}
