"""Optimized-HLO analysis: per-collective byte accounting for the roofline.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction. Shapes are read
from the instruction's result type (for reduce-scatter we scale back up by
the shard count where it matters; operand-side accounting keeps this simple
and consistent across op kinds).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  %all-gather.3 = bf16[16,1024,512]{2,1,0} all-gather(...)
#       ROOT %r = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(...)
_INSTR_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)[\s(]")

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z]+[0-9]+|pred)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dtype")
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Bytes moved per collective kind (result-shape accounting, per device)."""
    out: Dict[str, float] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        op = m.group("op")
        b = _shape_bytes(m.group("type"))
        out[op] = out.get(op, 0.0) + b
    return out


def count_collectives(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        op = m.group("op")
        out[op] = out.get(op, 0) + 1
    return out
