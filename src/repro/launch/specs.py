"""Lowerable (function, abstract inputs, shardings) per (arch x shape x mesh).

Every assigned cell becomes a ``LoweredSpec``: the step function
(train / prefill / decode), ShapeDtypeStruct stand-ins for all inputs (no
allocation), and NamedShardings resolved through the logical rule tables.
``build_cell`` is what both the dry-run and the roofline pass call.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable_shapes, get_config
from repro.configs.base import shape_overrides
from repro.models import encdec, kvcache, transformer
from repro.models.config import ModelConfig
from repro.sharding.logical import rules_for, use_rules
from repro.sharding.partition import param_shardings
from repro.training.optimizer import adamw_init
from repro.training.train_loop import make_train_step, make_whisper_train_step

OPT_AXES_STEP = ((),)  # scalar step


@dataclasses.dataclass
class LoweredSpec:
    arch: str
    shape: str
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    cfg: ModelConfig
    rules: Any


def _named(mesh, spec_tree, axes_tree, rules):
    return param_shardings(spec_tree, axes_tree, mesh, rules)


def _tokens_spec(batch, seq):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def _serve_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, param_dtype="bfloat16", remat=False)


def _positions_spec(cfg, batch, seq):
    if cfg.mrope_sections:
        return jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
    return None


def _ep_split(cfg: ModelConfig, mesh: Mesh) -> int:
    """Virtual-expert EP split (SSPerf B4): when the expert count does not
    divide the model axis but a half-width split does, split each expert into
    half-ff virtual experts so expert parallelism applies exactly (mixtral 8e
    on a 16-way axis -> split 2). SwiGLU is elementwise in ff -> exact."""
    import os
    # Measured net-negative under GSPMD (dispatch/combine gathers lower to
    # mask+all-reduce that outweighs the removed partial-sum ARs — §Perf B4,
    # refuted): exact + tested, but opt-in until a custom all-to-all dispatch
    # lands.
    if not cfg.moe_num_experts or not os.environ.get("REPRO_EP_SPLIT"):
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = sizes.get("model", 1)
    e, ff = cfg.moe_num_experts, (cfg.moe_d_ff or cfg.d_ff)
    if model_n <= 1 or e % model_n == 0:
        return 1
    if model_n % e == 0:
        split = model_n // e
        if ff % split == 0 and (ff // split) % 128 == 0:  # lane-aligned
            return split
    return 1


def build_cell(arch: str, shape: str, mesh: Mesh,
               n_periods: Optional[int] = None) -> LoweredSpec:
    """``n_periods`` overrides the depth (in scan periods) — the roofline
    pass lowers 1- and 2-period variants and extrapolates per-period costs,
    because XLA's cost_analysis counts a while-loop body once regardless of
    trip count."""
    cfg = get_config(arch)
    if shape not in applicable_shapes(cfg):
        raise ValueError(f"{arch} x {shape}: skipped "
                         "(see DESIGN.md SSArch-applicability)")
    cfg = shape_overrides(cfg, shape)
    cfg = dataclasses.replace(cfg, moe_ep_split=_ep_split(cfg, mesh))
    if n_periods is not None:
        # unrolled shallow variant: XLA cost_analysis counts a while body
        # once, so per-period costs must come from unrolled 1- vs 2-period
        # compiles (the full-depth scan compile validates memory/sharding)
        cfg = dataclasses.replace(
            cfg, num_layers=cfg.period() * n_periods, scan_layers=False,
            encoder_layers=n_periods if cfg.is_encoder_decoder else 0)
    spec = SHAPES[shape]
    b, s = spec.global_batch, spec.seq_len
    mode = spec.kind                       # "train" | "prefill" | "decode"
    if mode != "train":
        cfg = _serve_cfg(cfg)
    rules = rules_for(cfg, mesh, mode)

    if cfg.is_encoder_decoder:
        return _build_encdec_cell(arch, shape, cfg, mesh, rules, spec)

    p_axes = transformer.param_axes(cfg)
    abstract = transformer.abstract_params(cfg)
    p_shard = _named(mesh, abstract, p_axes, rules)

    batch_axes = {"tokens": ("batch", None), "labels": ("batch", None)}
    if cfg.mrope_sections:
        batch_axes["positions"] = (None, "batch", None)

    if spec.kind == "train":
        step = make_train_step(cfg)
        opt = jax.eval_shape(lambda p: adamw_init(p), abstract)
        opt_axes = type(opt)(step=(), mu=p_axes, nu=p_axes)
        opt_shard = _named(mesh, opt, opt_axes, rules)
        batch = {"tokens": _tokens_spec(b, s), "labels": _tokens_spec(b, s)}
        if cfg.mrope_sections:
            batch["positions"] = _positions_spec(cfg, b, s)
        b_shard = _named(mesh, batch, batch_axes, rules)
        return LoweredSpec(arch, shape, step, (abstract, opt, batch),
                           (p_shard, opt_shard, b_shard), (0, 1), cfg, rules)

    if spec.kind == "prefill":
        width = kvcache.cache_width(cfg, s)

        def prefill_fn(params, tokens, positions=None):
            return transformer.prefill(params, tokens, cfg, width,
                                       positions=positions)

        args = [abstract, _tokens_spec(b, s)]
        shards = [p_shard,
                  NamedSharding(mesh, _resolve(mesh, (b, s),
                                               ("batch", None), rules))]
        if cfg.mrope_sections:
            args.append(_positions_spec(cfg, b, s))
            shards.append(NamedSharding(
                mesh, _resolve(mesh, (3, b, s), (None, "batch", None), rules)))
        return LoweredSpec(arch, shape, prefill_fn, tuple(args),
                           tuple(shards), (), cfg, rules)

    # decode
    width = kvcache.cache_width(cfg, s)
    cache = jax.eval_shape(lambda: kvcache.init_cache(cfg, b, width))
    c_axes = kvcache.cache_axes(cfg)
    c_shard = _named(mesh, cache, c_axes, rules)

    def decode_fn(params, token, pos, cache, positions=None):
        return transformer.decode_step(params, token, pos, cache, cfg,
                                       positions=positions)

    args = [abstract, _tokens_spec(b, 1),
            jax.ShapeDtypeStruct((), jnp.int32), cache]
    shards = [p_shard,
              NamedSharding(mesh, _resolve(mesh, (b, 1), ("batch", None), rules)),
              NamedSharding(mesh, P()), c_shard]
    if cfg.mrope_sections:
        args.append(_positions_spec(cfg, b, 1))
        shards.append(NamedSharding(
            mesh, _resolve(mesh, (3, b, 1), (None, "batch", None), rules)))
    return LoweredSpec(arch, shape, decode_fn, tuple(args), tuple(shards),
                       (3,), cfg, rules)


def _resolve(mesh, shape, axes, rules):
    from repro.sharding.logical import resolve_spec
    return resolve_spec(shape, axes, mesh, rules)


# --------------------------------------------------------------------------- #
# whisper (enc-dec)
# --------------------------------------------------------------------------- #

def _build_encdec_cell(arch, shape, cfg, mesh, rules, spec) -> LoweredSpec:
    b, s = spec.global_batch, spec.seq_len
    p_axes = encdec.param_axes(cfg)
    abstract = encdec.abstract_params(cfg)
    p_shard = _named(mesh, abstract, p_axes, rules)
    f, d = cfg.encoder_seq, cfg.d_model
    audio = jax.ShapeDtypeStruct((b, f, d), jnp.bfloat16)
    audio_shard = NamedSharding(
        mesh, _resolve(mesh, (b, f, d), ("batch", None, None), rules))
    tok_shard = NamedSharding(
        mesh, _resolve(mesh, (b, s), ("batch", None), rules))

    if spec.kind == "train":
        step = make_whisper_train_step(cfg)
        opt = jax.eval_shape(lambda p: adamw_init(p), abstract)
        opt_axes = type(opt)(step=(), mu=p_axes, nu=p_axes)
        opt_shard = _named(mesh, opt, opt_axes, rules)
        batch = {"tokens": _tokens_spec(b, s), "labels": _tokens_spec(b, s),
                 "audio_embeds": audio}
        b_shard = {"tokens": tok_shard, "labels": tok_shard,
                   "audio_embeds": audio_shard}
        return LoweredSpec(arch, shape, step, (abstract, opt, batch),
                           (p_shard, opt_shard, b_shard), (0, 1), cfg, rules)

    if spec.kind == "prefill":
        def prefill_fn(params, tokens, audio_embeds):
            return encdec.prefill(params, tokens, audio_embeds, cfg,
                                  cache_width=s)
        return LoweredSpec(arch, shape, prefill_fn,
                           (abstract, _tokens_spec(b, s), audio),
                           (p_shard, tok_shard, audio_shard), (), cfg, rules)

    # decode: self cache (ring of width s) + cross cache (encoder K/V)
    hd = cfg.resolved_head_dim
    # self cache is heads-major [L,B,Hkv,W,hd] (see kvcache.slot_cache_axes);
    # the cross cache keeps the [B,F,H,hd] segment layout chunked_attention
    # consumes directly
    self_axes = ("layers", "batch", "kv_heads", "kv_seq", None)
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads", None)
    self_cache = {
        "k": jax.ShapeDtypeStruct((cfg.num_layers, b, cfg.num_kv_heads, s, hd),
                                  jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((cfg.num_layers, b, cfg.num_kv_heads, s, hd),
                                  jnp.bfloat16),
    }
    cross_cache = {
        "k": jax.ShapeDtypeStruct((cfg.num_layers, b, f, cfg.num_kv_heads, hd),
                                  jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((cfg.num_layers, b, f, cfg.num_kv_heads, hd),
                                  jnp.bfloat16),
    }
    cache = {"self": self_cache, "cross": cross_cache}
    c_axes = {"self": {"k": self_axes, "v": self_axes},
              "cross": {"k": kv_axes, "v": kv_axes}}
    c_shard = _named(mesh, cache, c_axes, rules)

    def decode_fn(params, token, pos, cache):
        return encdec.decode_step(params, token, pos, cache, cfg)

    return LoweredSpec(
        arch, shape, decode_fn,
        (abstract, _tokens_spec(b, 1), jax.ShapeDtypeStruct((), jnp.int32),
         cache),
        (p_shard,
         NamedSharding(mesh, _resolve(mesh, (b, 1), ("batch", None), rules)),
         NamedSharding(mesh, P()), c_shard),
        (3,), cfg, rules)


# --------------------------------------------------------------------------- #

def lower_cell(cell: LoweredSpec, mesh: Mesh):
    """jit + lower under the mesh and the cell's logical rules."""
    with use_rules(cell.rules, mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums)
        return jitted.lower(*cell.abstract_args)
