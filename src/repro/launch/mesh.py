"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Single pod: 16x16 = 256 chips over ("data", "model"); multi-pod:
2x16x16 = 512 over ("pod", "data", "model"). The dry-run provides 512 host
placeholder devices via XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_local_mesh():
    """1x1 mesh for CPU smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
