"""End-to-end training driver (deliverable b): train a ~100M-parameter LM for
a few hundred steps on the local device(s), with fault-tolerant checkpointing
(atomic commit + async snapshots + restart-from-latest) and optional int8
error-feedback gradient compression on the DP path.

  PYTHONPATH=src python -m repro.launch.train --steps 300 --preset 100m
  PYTHONPATH=src python -m repro.launch.train --resume --steps 400  # restart

On a real pod this runs under the production mesh (launch/mesh.py) with the
same step function the dry-run lowers; on this CPU container it runs the
reduced preset on one device.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.pipeline import SyntheticLMDataset
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.training.checkpoint import AsyncCheckpointer, restore_latest
from repro.training.compression import compress_grads, ef_init
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.train_loop import cross_entropy_loss


PRESETS = {
    # ~100M params: 12L x 640d x 2560ff, 16k vocab
    "100m": dict(num_layers=12, d_model=640, num_heads=10, num_kv_heads=10,
                 head_dim=64, d_ff=2560, vocab_size=16384),
    # ~20M: CI-speed variant
    "20m": dict(num_layers=6, d_model=384, num_heads=6, num_kv_heads=6,
                head_dim=64, d_ff=1536, vocab_size=8192),
}


def build_config(arch: str, preset: str) -> ModelConfig:
    cfg = get_config(arch)
    if preset == "smoke":
        return smoke_config(cfg)
    return dataclasses.replace(
        cfg, **PRESETS[preset],
        moe_num_experts=0, moe_top_k=0, moe_d_ff=0,   # dense preset
        sliding_window=0, logical_vocab_size=0, remat=False,
        compute_dtype="float32")


def make_compressed_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """Train step carrying an error-feedback residual (int8 grad path)."""

    def loss_fn(params, batch):
        logits, aux = transformer.forward(params, batch["tokens"], cfg,
                                          mode="train")
        return cross_entropy_loss(logits, batch["labels"]) + 0.01 * aux

    def step(params, opt_state, residual, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, residual = compress_grads(grads, residual)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                opt_cfg)
        return params, opt_state, residual, {"loss": loss, "grad_norm": gnorm}

    return step


def make_plain_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def loss_fn(params, batch):
        logits, aux = transformer.forward(params, batch["tokens"], cfg,
                                          mode="train")
        return cross_entropy_loss(logits, batch["labels"]) + 0.01 * aux

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--preset", default="100m", choices=list(PRESETS) + ["smoke"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 + error-feedback gradient compression")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = build_config(args.arch, args.preset)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(100, args.steps // 3))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    opt_state = adamw_init(params)
    residual = ef_init(params) if args.compress else None
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {args.arch} preset={args.preset}: {n_params/1e6:.1f}M "
          f"params, {args.steps} steps, batch {args.batch} x seq {args.seq}"
          + (" [int8-EF grads]" if args.compress else ""))

    start_step = 0
    if args.resume:
        out = restore_latest(args.ckpt_dir, params, opt_state)
        if out is not None:
            start_step, params, opt_state, extra = out
            print(f"[train] resumed from step {start_step}")

    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch, seed=0, branching=2)
    step_fn = jax.jit(make_compressed_step(cfg, opt_cfg) if args.compress
                      else make_plain_step(cfg, opt_cfg),
                      donate_argnums=(0, 1))
    ckpt = AsyncCheckpointer(args.ckpt_dir)
    history = []
    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
        if args.compress:
            params, opt_state, residual, metrics = step_fn(
                params, opt_state, residual, batch)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            tok_s = (step + 1 - start_step) * args.batch * args.seq / dt
            print(f"  step {step + 1:5d}  loss {loss:7.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):7.3f}  "
                  f"{tok_s:,.0f} tok/s")
            history.append({"step": step + 1, "loss": loss})
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, params, opt_state)
    ckpt.wait()
    if history:
        print(f"[train] loss {history[0]['loss']:.4f} -> "
              f"{history[-1]['loss']:.4f} over {args.steps - start_step} steps")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)
    return history


if __name__ == "__main__":
    main()
