"""Serving driver (deliverable b): the CoServe system end to end.

Three modes behind the SAME scheduler/manager code:

  --mode sim     paper-scale circuit-board workload (352 experts, 2500+ reqs)
                 on the event-driven engine — reproduces the paper's numbers.
  --mode real    actually loads JAX expert params across host/disk tiers and
                 runs jitted forwards on the local device, with measured wall
                 time (scaled-down pool so experts really switch).
  --mode online  streaming multi-tenant front-end (repro.serve): generator
                 arrivals, per-tenant SLO telemetry (p50/p95/p99), optional
                 admission control and queue/SLO-driven autoscaling.
                 ``--engine real`` drives the same gateway over real JAX
                 experts instead of the profile-driven simulator.

Fleet knobs (``--devices/--links/--replication/--peer-bw/--placement``)
apply to both sim and online (sim-engine) modes: multi-device pools behind
the shared SSD, per-device PCIe links, planned expert replication, an
optional NVLink/ICI-class peer fabric for pool->pool replica copies, and
greedy-vs-searched initial placement.

  PYTHONPATH=src python -m repro.launch.serve --mode sim  --board A --requests 2500
  PYTHONPATH=src python -m repro.launch.serve --mode real --requests 200
  PYTHONPATH=src python -m repro.launch.serve --mode online --tenants A,B \
      --arrival poisson --requests 2000 --rates 25,12 --slos 2.0,4.0 \
      --admission queue_depth --autoscale 2,8
  PYTHONPATH=src python -m repro.launch.serve --mode online --devices 4 \
      --links per-device --replication 1 --peer-bw 50 --placement search \
      --tenants A,B --rates 25,12 --requests 2000
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (COSERVE, COSERVE_NONE, SAMBA, SAMBA_FIFO,
                        SAMBA_PARALLEL, CoEModel, CoServeSystem, DeviceProfile,
                        ExecutorSpec, ExpertSpec, HostStore, RealEngine,
                        Request, RoutingModule, Simulation, SystemPolicy,
                        TierSpec, microbenchmark_arch, run_real)
from repro.core.memory import NUMA, UMA
from repro.core.workload import (BOARD_A, BOARD_B, build_board_coe,
                                 make_executor_specs, make_task_requests)
from repro.fleet import (FleetSpec, PlacementPlan, SearchConfig, build_fleet,
                         search_placement, trace_from_requests,
                         trace_from_usage, validate_pool_groups)

POLICIES: Dict[str, SystemPolicy] = {
    "coserve": COSERVE,
    "coserve_none": COSERVE_NONE,
    "samba": SAMBA,
    "samba_fifo": SAMBA_FIFO,
    "samba_parallel": SAMBA_PARALLEL,
}


def _policy_from_args(args) -> SystemPolicy:
    """Base policy + the ``--prefetch`` / ``--prefetch-trigger`` overrides.

    ``off``  — no load/execute overlap, no cross-tier promotion;
    ``device`` — device-pool overlap only (the seed's behaviour);
    ``all``  — device overlap + dependency-aware disk->host prefetch;
    default  — whatever the named policy declares.
    ``--prefetch-trigger queue`` fires the disk->host promotion when the
    upstream request joins a queue instead of when it starts executing.
    """
    policy = POLICIES[args.policy]
    mode = getattr(args, "prefetch", None)
    if mode == "off":
        policy = dataclasses.replace(policy, prefetch=False,
                                     host_prefetch=False)
    elif mode == "device":
        policy = dataclasses.replace(policy, host_prefetch=False)
    elif mode == "all":
        policy = dataclasses.replace(policy, prefetch=True,
                                     host_prefetch=True)
    trigger = getattr(args, "prefetch_trigger", None)
    if trigger is not None:
        policy = dataclasses.replace(policy, prefetch_trigger=trigger)
    return policy


# --------------------------------------------------------------------------- #
# sim mode — the paper's full-scale workload
# --------------------------------------------------------------------------- #

def _fleet_tier(args, base):
    """The run's TierSpec: the named preset, plus the optional peer
    (NVLink/ICI-class) device<->device fabric from ``--peer-bw`` GB/s."""
    if getattr(args, "peer_bw", 0.0):
        return dataclasses.replace(base, peer_bw=args.peer_bw * 1e9)
    return base


def _fleet_pools(args, tier, n_gpu: int, n_cpu: int, devices: int):
    """(pools, specs) for the run's fleet shape — the single-device path
    stays ``make_executor_specs`` (seed layout) exactly."""
    if devices > 1:
        # multi-device fleet: n_gpu executors on EACH of --devices
        # accelerators (shared SSD fan-in; --links picks the PCIe layout)
        fleet = FleetSpec(n_devices=devices, gpu_per_device=n_gpu,
                          n_cpu=n_cpu, links=args.links)
        return build_fleet(tier, fleet)
    return make_executor_specs(tier, n_gpu, n_cpu)


def _searched_placement(args, coe, pools, specs, tier, trace):
    """``--placement search``: seed with the greedy sweep and search over
    ``trace`` under the SAME ``--replication`` budget — search never plans
    copies the user disabled (with ``--replication 0`` it still migrates /
    swaps / replaces primaries). Falls back to the greedy seed when nothing
    improves."""
    greedy = PlacementPlan.build(coe, pools, replication=args.replication)
    res = search_placement(
        coe, pools, trace, tier, links=args.links,
        pool_devices=validate_pool_groups(specs), seed_plan=greedy,
        config=SearchConfig(seed=args.seed, replication=args.replication))
    return res.plan, res.snapshot()


def run_sim(args) -> dict:
    board = BOARD_A if args.board == "A" else BOARD_B
    tier = _fleet_tier(args, NUMA if args.tier == "numa" else UMA)
    coe = build_board_coe(board)
    policy = _policy_from_args(args)
    n_gpu, n_cpu = args.executors
    devices = args.devices
    if policy.assign == "single":
        # a single-assign baseline only ever uses executors[0]: building a
        # fleet for it would spread the hot placement across pools that can
        # never serve, distorting the comparison
        n_gpu, n_cpu, devices = 1, 0, 1
    pools, specs = _fleet_pools(args, tier, n_gpu, n_cpu, devices)
    requests = make_task_requests(board, args.requests)
    placement, search_report = None, None
    if args.placement == "search":
        trace = trace_from_requests(coe, requests[:512])
        placement, search_report = _searched_placement(
            args, coe, pools, specs, tier, trace)
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier,
                           links=args.links, replication=args.replication,
                           placement=placement)
    sim = Simulation(system)
    sim.submit(requests)
    m = sim.run()
    out = {"mode": "sim", "board": board.name, "tier": tier.name,
           "policy": args.policy, "devices": devices,
           "links": args.links, "completed": m.completed,
           "throughput": round(m.throughput, 2), "switches": m.switches,
           "makespan_s": round(m.makespan, 2),
           "avg_latency_s": round(m.avg_latency, 4),
           "stall_s": round(m.stall_time, 3),
           "placement": m.memory.get("placement", {}),
           "pcie_links": {name: ch.get("wait_time_s")
                          for name, ch in m.memory.get(
                              "channels", {}).get("pcie_channels", {}).items()},
           "peer_links": {name: ch.get("wait_time_s")
                          for name, ch in m.memory.get(
                              "channels", {}).get("peer_channels", {}).items()},
           "host_prefetch": m.memory.get("prefetch", {})}
    if search_report is not None:
        out["placement_search"] = search_report
    return out


# --------------------------------------------------------------------------- #
# real mode — tiny JAX experts, actual loads + jitted execution
# --------------------------------------------------------------------------- #

def _tiny_apply_fns():
    import jax
    import jax.numpy as jnp

    def mlp(params, x):
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    return {"tiny_cls": jax.jit(mlp), "tiny_det": jax.jit(mlp)}


def _tiny_params(key, d_in: int, d_h: int, d_out: int):
    import jax
    ks = jax.random.split(key, 2)
    return {"w1": jax.random.normal(ks[0], (d_in, d_h)) * 0.1,
            "b1": np.zeros((d_h,), np.float32),
            "w2": jax.random.normal(ks[1], (d_h, d_out)) * 0.1,
            "b2": np.zeros((d_out,), np.float32)}


def _real_board_layout(n_components: int, n_detection: int):
    """Deterministic component->detection wiring of the tiny real-JAX CoE.
    One seeded stream, drawn in this exact order — request generators must
    use this helper (not fresh RandomState(0) draws) to match the catalog's
    declared dependencies."""
    rng = np.random.RandomState(0)
    det_assign = rng.randint(0, n_detection, n_components)
    needs_det = rng.rand(n_components) < 0.5
    return needs_det, det_assign


def build_real_system(n_components: int = 24, n_detection: int = 4,
                      pool_experts: int = 6, n_executors: int = 2,
                      store_root: Optional[str] = None,
                      policy: SystemPolicy = COSERVE,
                      d_hidden: int = 256,
                      ) -> Tuple[CoServeSystem, CoEModel]:
    """A small CoE of real JAX MLP experts over host+disk tiers."""
    import jax

    apply_fns = _tiny_apply_fns()
    store = HostStore(root=store_root or tempfile.mkdtemp(prefix="coserve_"))
    needs_det, det_assign = _real_board_layout(n_components, n_detection)

    payload = {
        "make_batch": lambda reqs: np.stack([r.data["x"] for r in reqs]),
        "interpret": lambda out: ["ok" if o == 0 else "defect"
                                  for o in np.argmax(out, -1)],
    }
    experts: List[ExpertSpec] = []
    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, n_components + n_detection)
    mem = (64 * d_hidden + d_hidden * 2 + d_hidden + 2) * 4
    for c in range(n_components):
        eid = f"cls{c:03d}"
        params = _tiny_params(keys[c], 64, d_hidden, 2)
        # half the catalog starts on the disk tier, half in host DRAM
        (store.put_disk if c % 2 else store.put_host)(eid, params)
        experts.append(ExpertSpec(
            id=eid, arch="tiny_cls", mem_bytes=mem, payload=payload,
            usage_prob=1.0 / n_components))
    for dnum in range(n_detection):
        eid = f"det{dnum:02d}"
        params = _tiny_params(keys[n_components + dnum], 64, d_hidden, 2)
        store.put_disk(eid, params)
        ups = tuple(f"cls{c:03d}" for c in range(n_components)
                    if needs_det[c] and det_assign[c] == dnum)
        experts.append(ExpertSpec(
            id=eid, arch="tiny_det", mem_bytes=mem, payload=payload,
            depends_on=ups, usage_prob=0.2))

    def first_expert(data) -> str:
        return f"cls{data['component']:03d}"

    def next_expert(req: Request, eid: str, output) -> Optional[str]:
        if eid.startswith("cls") and req.data.get("needs_detection") \
                and output == "ok":
            return f"det{req.data['det_expert']:02d}"
        return None

    coe = CoEModel(experts, RoutingModule(first_expert, next_expert))
    engine = RealEngine(coe, store, apply_fns)

    # offline profiling with the real runner (paper §4.5)
    import time as _t

    def run_batch_factory(arch_params):
        def run_batch(n: int) -> float:
            x = np.zeros((n, 64), np.float32)
            fn = apply_fns["tiny_cls"]
            fn(arch_params, x)  # warm
            t0 = _t.perf_counter()
            jax.block_until_ready(fn(arch_params, x))
            return _t.perf_counter() - t0
        return run_batch

    tier = TierSpec(name="local", unified=True, host_cache_bytes=0,
                    device_bytes=pool_experts * mem + 4 * mem)
    sample = _tiny_params(jax.random.PRNGKey(9), 64, d_hidden, 2)
    prof = microbenchmark_arch("tiny_cls", run_batch_factory(sample), mem,
                               act_bytes_per_item=64 * 4, tier=tier,
                               batch_sizes=(1, 2, 4, 8), repeats=2)
    det_prof = dataclasses.replace(prof, arch="tiny_det")
    dev_prof = DeviceProfile(device="gpu", tier=tier,
                             arch_profiles={"tiny_cls": prof,
                                            "tiny_det": det_prof})
    pools = {"gpu": pool_experts * mem}
    specs = [ExecutorSpec("gpu", dev_prof, 4 * mem, "gpu")
             for _ in range(n_executors)]
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier,
                           engine=engine)
    return system, coe


def run_real_mode(args) -> dict:
    system, coe = build_real_system(policy=_policy_from_args(args))
    rng = np.random.RandomState(1)
    n_components = sum(1 for e in coe.experts if e.startswith("cls"))
    needs_det, det_assign = _real_board_layout(
        n_components, sum(1 for e in coe.experts if e.startswith("det")))
    reqs = []
    for i in range(args.requests):
        c = int(rng.randint(n_components))
        reqs.append(Request(
            id=i, expert_id=f"cls{c:03d}",
            data={"component": c, "x": rng.randn(64).astype(np.float32),
                  "needs_detection": bool(needs_det[c]),
                  "det_expert": int(det_assign[c])}))
    m = run_real(system, reqs)
    return {"mode": "real", "policy": args.policy, "completed": m.completed,
            "throughput": round(m.throughput, 2), "switches": m.switches,
            "makespan_s": round(m.makespan, 3)}


# --------------------------------------------------------------------------- #
# online mode — streaming multi-tenant serving (repro.serve)
# --------------------------------------------------------------------------- #

def _parse_tenants(args):
    """``--tenants A,B`` (or ``gold:A,batch:B``) + per-tenant rate/SLO/arrival
    lists (singletons broadcast)."""
    from repro.serve import BOARDS, TenantSpec

    tokens = [t.strip() for t in args.tenants.split(",") if t.strip()]

    def broadcast(raw, cast):
        vals = [cast(v) for v in str(raw).split(",")]
        if len(vals) == 1:
            vals *= len(tokens)
        if len(vals) != len(tokens):
            raise SystemExit(f"expected 1 or {len(tokens)} values, got {raw!r}")
        return vals

    names = [t.partition(":")[0] for t in tokens]
    if len(set(names)) != len(names):
        raise SystemExit(f"duplicate tenant names in {args.tenants!r} — "
                         "per-tenant SLOs and telemetry are keyed by name")
    rates = broadcast(args.rates, float)
    slos = broadcast(args.slos, float)
    procs = broadcast(args.arrival, str)
    classes = broadcast(args.request_class, str)
    tenants = []
    for i, tok in enumerate(tokens):
        name, _, board_key = tok.partition(":")
        board_key = board_key or name
        if board_key not in BOARDS:
            raise SystemExit(f"unknown board {board_key!r} in tenant {tok!r}")
        try:
            tenants.append(TenantSpec(
                name=name, board=BOARDS[board_key], rate=rates[i],
                process=procs[i], request_class=classes[i],
                slo_seconds=slos[i], seed=args.seed + i))
        except ValueError as e:
            raise SystemExit(str(e))
    return tenants


def _admission_from_args(args, mean_rate: float):
    """Shared ``--admission`` wiring. The token bucket defaults its refill
    to the tenant mix's mean per-tenant rate, so the policy actually bites
    under a burst instead of idling at its library default."""
    from repro.serve import AdmissionConfig, AdmissionController

    if args.admission == "none":
        return None
    bucket_rate = args.bucket_rate if args.bucket_rate is not None \
        else mean_rate
    return AdmissionController(AdmissionConfig(
        policy=args.admission, max_queue=args.max_queue,
        bucket_rate=bucket_rate, bucket_burst=args.bucket_burst))


def _autoscaler_from_args(args, scale_spec: ExecutorSpec, fleet: int):
    """Shared ``--autoscale`` parsing for both online engines."""
    from repro.serve import Autoscaler, AutoscalerConfig

    if args.autoscale == "none":
        return None
    if args.autoscale == "auto":
        lo, hi = fleet, 2 * fleet
    else:
        try:
            lo, hi = map(int, args.autoscale.split(","))
        except ValueError:
            raise SystemExit(
                f"--autoscale expects 'min,max', 'auto' or 'none', "
                f"got {args.autoscale!r}")
    return Autoscaler(AutoscalerConfig(
        spec=scale_spec, min_executors=lo, max_executors=hi))


def run_online(args) -> dict:
    from repro.serve import OnlineGateway, build_multi_board_coe

    tenants = _parse_tenants(args)
    tier = _fleet_tier(args, NUMA if args.tier == "numa" else UMA)
    coe = build_multi_board_coe([t.board for t in tenants],
                                weights=[t.rate for t in tenants])
    policy = _policy_from_args(args)
    n_gpu, n_cpu = args.executors
    devices = args.devices
    single = policy.assign == "single"
    if single:   # same fleet normalization as run_sim
        n_gpu, n_cpu, devices = 1, 0, 1
    # multi-tenant mixes over a multi-device fleet: the same FleetSpec path
    # sim mode uses, so --devices/--links/--replication/--peer-bw drive the
    # streaming gateway too (ROADMAP "online fleet mode" open item)
    pools, specs = _fleet_pools(args, tier, n_gpu, n_cpu, devices)
    placement, search_report = None, None
    if args.placement == "search":
        # no requests exist yet on the online path: search over the expected
        # load (pre-assessed P(use), already weighted by tenant rates); the
        # autoscaler re-plans replicas from *observed* load at scale events
        trace = trace_from_usage(coe, length=512)
        placement, search_report = _searched_placement(
            args, coe, pools, specs, tier, trace)
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier,
                           links=args.links, replication=args.replication,
                           placement=placement)

    admission = _admission_from_args(
        args, mean_rate=sum(t.rate for t in tenants) / len(tenants))
    # single-assign policies route everything to executor 0: scaling the
    # fleet could never receive work, so the autoscaler is disabled
    autoscaler = None if single \
        else _autoscaler_from_args(args, specs[0], len(specs))

    gw = OnlineGateway(system, tenants, admission=admission,
                       autoscaler=autoscaler,
                       slo_priority=not args.no_slo_priority,
                       tick_interval=args.tick)
    report = gw.run(max_requests=args.requests)
    out = {"mode": "online", "engine": "sim", "tier": tier.name,
           "policy": args.policy, "devices": devices, "links": args.links,
           "replication": args.replication,
           "tenants": {t.name: {"board": t.board.name, "rate_rps": t.rate,
                                "process": t.process,
                                "slo_s": t.slo_seconds} for t in tenants}}
    if search_report is not None:
        out["placement_search"] = search_report
    out.update(report.to_json())
    return out


def run_online_real(args) -> dict:
    """The same gateway over the RealEngine: actual JAX expert loads and
    jitted forwards advance the clock by measured wall time."""
    import numpy as np

    from repro.core.coe import Request
    from repro.serve import OnlineGateway, TenantSpec, make_gaps
    from repro.core.workload import BOARD_A

    if any("," in str(v) for v in (args.rates, args.slos, args.arrival)):
        raise SystemExit(
            "--engine real serves a single tenant over the tiny local CoE: "
            "pass scalar --rates/--slos/--arrival (multi-tenant mixes need "
            "--engine sim); --tenants is ignored here")
    if args.request_class not in ("scan", "random"):
        raise SystemExit(f"unknown request class {args.request_class!r}")
    # the real engine's source always draws uniformly at random — "random"
    # is served as asked; the default "scan" has no board-scan analogue on
    # the tiny local CoE and also gets the uniform stream
    system, coe = build_real_system(policy=_policy_from_args(args))
    n_components = sum(1 for e in coe.experts if e.startswith("cls"))
    n_detection = sum(1 for e in coe.experts if e.startswith("det"))
    needs_det, det_assign = _real_board_layout(n_components, n_detection)
    try:
        tenant = TenantSpec(name="local", board=BOARD_A,
                            rate=float(args.rates),
                            process=args.arrival,
                            request_class="random",   # what the source does
                            slo_seconds=float(args.slos),
                            seed=args.seed)
    except ValueError as e:
        raise SystemExit(str(e))

    def source():
        rng = np.random.RandomState(args.seed)
        gaps = make_gaps(tenant.process, tenant.rate, rng)
        t = 0.0
        for i in range(args.requests):
            t += next(gaps)
            c = int(rng.randint(n_components))
            yield Request(
                id=i, expert_id=f"cls{c:03d}", arrival_time=t,
                task_id="local", tenant="local",
                deadline=t + tenant.slo_seconds, root_arrival_time=t,
                data={"component": c, "x": rng.randn(64).astype(np.float32),
                      "needs_detection": bool(needs_det[c]),
                      "det_expert": int(det_assign[c])})

    admission = _admission_from_args(args, mean_rate=tenant.rate)
    ex0 = system.executors[0]
    scale_spec = ExecutorSpec("gpu", ex0.device_profile, ex0.batch_bytes,
                              "gpu")
    autoscaler = _autoscaler_from_args(args, scale_spec,
                                       len(system.executors))
    gw = OnlineGateway(system, [tenant], admission=admission,
                       autoscaler=autoscaler,
                       slo_priority=not args.no_slo_priority,
                       tick_interval=args.tick)
    report = gw.run(source=source())
    out = {"mode": "online", "engine": "real", "policy": args.policy,
           "tenants": {"local": {"rate_rps": tenant.rate,
                                 "process": tenant.process,
                                 "request_class": tenant.request_class,
                                 "slo_s": tenant.slo_seconds}}}
    out.update(report.to_json())
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sim", choices=["sim", "real", "online"])
    ap.add_argument("--board", default="A", choices=["A", "B"])
    ap.add_argument("--tier", default="numa", choices=["numa", "uma"])
    ap.add_argument("--policy", default="coserve", choices=list(POLICIES))
    ap.add_argument("--prefetch", default=None,
                    choices=["off", "device", "all"],
                    help="override the policy's prefetch behaviour: off | "
                         "device (pool overlap only) | all (+ disk->host "
                         "promotion); default: the policy's own setting")
    ap.add_argument("--prefetch-trigger", default=None,
                    choices=["exec", "queue"],
                    help="when the cross-tier promotion fires: exec "
                         "(upstream starts executing, default) | queue "
                         "(upstream joins a queue — wider overlap window, "
                         "more speculative SSD traffic)")
    ap.add_argument("--requests", type=int, default=2500)
    ap.add_argument("--executors", type=lambda s: tuple(map(int, s.split(","))),
                    default=(3, 1), help="n_gpu,n_cpu (per device when "
                                         "--devices > 1)")
    ap.add_argument("--devices", type=int, default=1,
                    help="sim/online modes: number of accelerator devices, "
                         "each with its own pool behind the shared SSD")
    ap.add_argument("--links", default="shared",
                    choices=["shared", "per-device"],
                    help="host->device channel layout: one PCIe link the "
                         "whole fleet queues on, or one per accelerator")
    ap.add_argument("--replication", type=int, default=0,
                    help="planned device-pool copies of the hottest experts "
                         "beyond the primary (0 = paper placement)")
    ap.add_argument("--peer-bw", type=float, default=0.0,
                    help="device<->device (NVLink/ICI-class) peer fabric "
                         "bandwidth in GB/s; replicas of experts resident "
                         "on a sibling pool materialize pool->pool instead "
                         "of reloading from host DRAM (0 = no fabric)")
    ap.add_argument("--placement", default="greedy",
                    choices=["greedy", "search"],
                    help="initial expert placement: the greedy hot-first "
                         "sweep (paper §4.1) or the cost-model local search "
                         "over a workload trace (falls back to greedy when "
                         "nothing improves)")
    ap.add_argument("--out", default=None)
    # --- online-mode flags (repro.serve) ------------------------------- #
    ap.add_argument("--engine", default="sim", choices=["sim", "real"],
                    help="online mode: event-driven sim or real JAX experts")
    ap.add_argument("--tenants", default="A,B",
                    help="comma list of name[:board] tokens, boards A|B")
    ap.add_argument("--arrival", default="poisson",
                    help="arrival process per tenant (broadcasts): "
                         "poisson|bursty|diurnal|step")
    ap.add_argument("--rates", default="25",
                    help="mean req/s per tenant (broadcasts)")
    ap.add_argument("--slos", default="2.0",
                    help="end-to-end latency SLO seconds per tenant")
    ap.add_argument("--request-class", default="scan",
                    help="scan (board-scan locality) | random")
    ap.add_argument("--admission", default="none",
                    choices=["none", "queue_depth", "deadline", "token_bucket"])
    ap.add_argument("--max-queue", type=int, default=200)
    ap.add_argument("--bucket-rate", type=float, default=None,
                    help="token_bucket: admitted req/s per tenant "
                         "(default: the tenant mix's mean per-tenant rate)")
    ap.add_argument("--bucket-burst", type=float, default=50.0,
                    help="token_bucket: burst capacity in tokens")
    ap.add_argument("--autoscale", default="auto",
                    help="min,max executors; 'auto' = current fleet to 2x; "
                         "'none' disables scaling")
    ap.add_argument("--no-slo-priority", action="store_true",
                    help="disable deadline-EDF queue insertion")
    ap.add_argument("--tick", type=float, default=0.5,
                    help="telemetry/autoscaler control interval, sim seconds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.tick <= 0:
        raise SystemExit(f"--tick must be positive, got {args.tick}")
    if args.devices < 1:
        raise SystemExit(f"--devices must be >= 1, got {args.devices}")
    if args.replication < 0:
        raise SystemExit(f"--replication must be >= 0, "
                         f"got {args.replication}")
    if args.peer_bw < 0:
        raise SystemExit(f"--peer-bw must be >= 0, got {args.peer_bw}")
    fleet_flags = (args.devices > 1 or args.links != "shared"
                   or args.replication or args.peer_bw
                   or args.placement != "greedy")
    if fleet_flags and (args.mode == "real"
                        or (args.mode == "online" and args.engine == "real")):
        raise SystemExit("--devices/--links/--replication/--peer-bw/"
                         "--placement drive the simulated fleet; --mode real "
                         "and --engine real run the single-device "
                         "shared-link topology")
    if args.mode == "online":
        result = run_online(args) if args.engine == "sim" \
            else run_online_real(args)
    else:
        result = run_sim(args) if args.mode == "sim" else run_real_mode(args)
    print(json.dumps(result, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    main()
