"""Serving driver (deliverable b): the CoServe system end to end.

Two backends behind the SAME scheduler/manager code:

  --mode sim   paper-scale circuit-board workload (352 experts, 2500+ reqs)
               on the event-driven engine — reproduces the paper's numbers.
  --mode real  actually loads JAX expert params across host/disk tiers and
               runs jitted forwards on the local device, with measured wall
               time (scaled-down pool so experts really switch).

  PYTHONPATH=src python -m repro.launch.serve --mode sim  --board A --requests 2500
  PYTHONPATH=src python -m repro.launch.serve --mode real --requests 200
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (COSERVE, COSERVE_NONE, SAMBA, SAMBA_FIFO,
                        SAMBA_PARALLEL, CoEModel, CoServeSystem, DeviceProfile,
                        ExecutorSpec, ExpertSpec, HostStore, RealEngine,
                        Request, RoutingModule, Simulation, SystemPolicy,
                        TierSpec, microbenchmark_arch, run_real)
from repro.core.memory import NUMA, UMA
from repro.core.workload import (BOARD_A, BOARD_B, build_board_coe,
                                 make_executor_specs, make_task_requests)

POLICIES: Dict[str, SystemPolicy] = {
    "coserve": COSERVE,
    "coserve_none": COSERVE_NONE,
    "samba": SAMBA,
    "samba_fifo": SAMBA_FIFO,
    "samba_parallel": SAMBA_PARALLEL,
}


# --------------------------------------------------------------------------- #
# sim mode — the paper's full-scale workload
# --------------------------------------------------------------------------- #

def run_sim(args) -> dict:
    board = BOARD_A if args.board == "A" else BOARD_B
    tier = NUMA if args.tier == "numa" else UMA
    coe = build_board_coe(board)
    n_gpu, n_cpu = args.executors
    if POLICIES[args.policy].assign == "single":
        n_gpu, n_cpu = 1, 0
    pools, specs = make_executor_specs(tier, n_gpu, n_cpu)
    system = CoServeSystem(coe, specs, pools, policy=POLICIES[args.policy],
                           tier=tier)
    sim = Simulation(system)
    sim.submit(make_task_requests(board, args.requests))
    m = sim.run()
    return {"mode": "sim", "board": board.name, "tier": tier.name,
            "policy": args.policy, "completed": m.completed,
            "throughput": round(m.throughput, 2), "switches": m.switches,
            "makespan_s": round(m.makespan, 2),
            "avg_latency_s": round(m.avg_latency, 4)}


# --------------------------------------------------------------------------- #
# real mode — tiny JAX experts, actual loads + jitted execution
# --------------------------------------------------------------------------- #

def _tiny_apply_fns():
    import jax
    import jax.numpy as jnp

    def mlp(params, x):
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    return {"tiny_cls": jax.jit(mlp), "tiny_det": jax.jit(mlp)}


def _tiny_params(key, d_in: int, d_h: int, d_out: int):
    import jax
    ks = jax.random.split(key, 2)
    return {"w1": jax.random.normal(ks[0], (d_in, d_h)) * 0.1,
            "b1": np.zeros((d_h,), np.float32),
            "w2": jax.random.normal(ks[1], (d_h, d_out)) * 0.1,
            "b2": np.zeros((d_out,), np.float32)}


def build_real_system(n_components: int = 24, n_detection: int = 4,
                      pool_experts: int = 6, n_executors: int = 2,
                      store_root: Optional[str] = None,
                      policy: SystemPolicy = COSERVE,
                      d_hidden: int = 256,
                      ) -> Tuple[CoServeSystem, CoEModel]:
    """A small CoE of real JAX MLP experts over host+disk tiers."""
    import jax

    apply_fns = _tiny_apply_fns()
    store = HostStore(root=store_root or tempfile.mkdtemp(prefix="coserve_"))
    rng = np.random.RandomState(0)
    det_assign = rng.randint(0, n_detection, n_components)
    needs_det = rng.rand(n_components) < 0.5

    payload = {
        "make_batch": lambda reqs: np.stack([r.data["x"] for r in reqs]),
        "interpret": lambda out: ["ok" if o == 0 else "defect"
                                  for o in np.argmax(out, -1)],
    }
    experts: List[ExpertSpec] = []
    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, n_components + n_detection)
    mem = (64 * d_hidden + d_hidden * 2 + d_hidden + 2) * 4
    for c in range(n_components):
        eid = f"cls{c:03d}"
        params = _tiny_params(keys[c], 64, d_hidden, 2)
        # half the catalog starts on the disk tier, half in host DRAM
        (store.put_disk if c % 2 else store.put_host)(eid, params)
        experts.append(ExpertSpec(
            id=eid, arch="tiny_cls", mem_bytes=mem, payload=payload,
            usage_prob=1.0 / n_components))
    for dnum in range(n_detection):
        eid = f"det{dnum:02d}"
        params = _tiny_params(keys[n_components + dnum], 64, d_hidden, 2)
        store.put_disk(eid, params)
        ups = tuple(f"cls{c:03d}" for c in range(n_components)
                    if needs_det[c] and det_assign[c] == dnum)
        experts.append(ExpertSpec(
            id=eid, arch="tiny_det", mem_bytes=mem, payload=payload,
            depends_on=ups, usage_prob=0.2))

    def first_expert(data) -> str:
        return f"cls{data['component']:03d}"

    def next_expert(req: Request, eid: str, output) -> Optional[str]:
        if eid.startswith("cls") and req.data.get("needs_detection") \
                and output == "ok":
            return f"det{req.data['det_expert']:02d}"
        return None

    coe = CoEModel(experts, RoutingModule(first_expert, next_expert))
    engine = RealEngine(coe, store, apply_fns)

    # offline profiling with the real runner (paper §4.5)
    import time as _t

    def run_batch_factory(arch_params):
        def run_batch(n: int) -> float:
            x = np.zeros((n, 64), np.float32)
            fn = apply_fns["tiny_cls"]
            fn(arch_params, x)  # warm
            t0 = _t.perf_counter()
            jax.block_until_ready(fn(arch_params, x))
            return _t.perf_counter() - t0
        return run_batch

    tier = TierSpec(name="local", unified=True, host_cache_bytes=0,
                    device_bytes=pool_experts * mem + 4 * mem)
    sample = _tiny_params(jax.random.PRNGKey(9), 64, d_hidden, 2)
    prof = microbenchmark_arch("tiny_cls", run_batch_factory(sample), mem,
                               act_bytes_per_item=64 * 4, tier=tier,
                               batch_sizes=(1, 2, 4, 8), repeats=2)
    det_prof = dataclasses.replace(prof, arch="tiny_det")
    dev_prof = DeviceProfile(device="gpu", tier=tier,
                             arch_profiles={"tiny_cls": prof,
                                            "tiny_det": det_prof})
    pools = {"gpu": pool_experts * mem}
    specs = [ExecutorSpec("gpu", dev_prof, 4 * mem, "gpu")
             for _ in range(n_executors)]
    system = CoServeSystem(coe, specs, pools, policy=policy, tier=tier,
                           engine=engine)
    return system, coe


def run_real_mode(args) -> dict:
    system, coe = build_real_system(policy=POLICIES[args.policy])
    rng = np.random.RandomState(1)
    n_components = sum(1 for e in coe.experts if e.startswith("cls"))
    det_assign = np.random.RandomState(0).randint(
        0, sum(1 for e in coe.experts if e.startswith("det")), n_components)
    needs_det = np.random.RandomState(0).rand(n_components) < 0.5
    reqs = []
    for i in range(args.requests):
        c = int(rng.randint(n_components))
        reqs.append(Request(
            id=i, expert_id=f"cls{c:03d}",
            data={"component": c, "x": rng.randn(64).astype(np.float32),
                  "needs_detection": bool(needs_det[c]),
                  "det_expert": int(det_assign[c])}))
    m = run_real(system, reqs)
    return {"mode": "real", "policy": args.policy, "completed": m.completed,
            "throughput": round(m.throughput, 2), "switches": m.switches,
            "makespan_s": round(m.makespan, 3)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sim", choices=["sim", "real"])
    ap.add_argument("--board", default="A", choices=["A", "B"])
    ap.add_argument("--tier", default="numa", choices=["numa", "uma"])
    ap.add_argument("--policy", default="coserve", choices=list(POLICIES))
    ap.add_argument("--requests", type=int, default=2500)
    ap.add_argument("--executors", type=lambda s: tuple(map(int, s.split(","))),
                    default=(3, 1), help="n_gpu,n_cpu")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    result = run_sim(args) if args.mode == "sim" else run_real_mode(args)
    print(json.dumps(result, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    main()
