"""Serving CLI: a thin flag -> DeploymentSpec adapter over ``repro.api``.

Every flag parses into the one declarative ``DeploymentSpec`` (byte-identical
behaviour to the pre-spec wiring, pinned by equivalence tests); the spec
builds the system (``repro.api.build_system``) and a ``Session`` runs it.
Three modes behind the SAME scheduler/manager code:

  --mode sim     paper-scale circuit-board workload (352 experts, 2500+ reqs)
                 on the event-driven engine — reproduces the paper's numbers.
  --mode real    actually loads JAX expert params across host/disk tiers and
                 runs jitted forwards on the local device.
  --mode online  streaming multi-tenant front-end (repro.serve): generator
                 arrivals, per-tenant SLO telemetry, admission control and
                 autoscaling (``--engine real`` for real JAX experts).

Config artifacts (docs/configuration.md has the full workflow):

  --config spec.json   run a saved spec; any config flag passed alongside
                       overrides just that field (flag > file > default)
  --dump-config PATH   write the resolved spec (then exit) — the run's full
                       configuration as a reproducible, diffable artifact
  --dump-trace PATH    after the run, save the observed traffic as a
                       replayable WorkloadTrace artifact
  --trace PATH         ``--placement search`` replays this saved trace
                       (yesterday's traffic) instead of static priors
  --plan PATH          apply a saved PlacementPlan verbatim (no re-search)
  --save-plan PATH     save the plan this run actually served

  PYTHONPATH=src python -m repro.launch.serve --mode sim  --board A --requests 2500
  PYTHONPATH=src python -m repro.launch.serve --config examples/specs/online_fleet.json
  PYTHONPATH=src python -m repro.launch.serve --mode online --devices 4 \
      --links per-device --replication 1 --peer-bw 50 --placement search \
      --tenants A,B --rates 25,12 --requests 2000 --save-plan plan.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import warnings

# legacy re-exports: the system builders lived here before repro.api
from repro.api import DeploymentSpec, Session, SpecError
from repro.api.build import POLICIES, build_real_system  # noqa: F401
from repro.api.build import real_board_layout as _real_board_layout  # noqa: F401
from repro.api.spec import (DecodeSection, FleetSection, HeteroSection,
                            MemorySection, ModelSpec, PolicySection,
                            ServingSection, TenantSection, WorkloadSection)
from repro.memory import POLICY_NAMES
from repro.obs import log as obslog

log = obslog.get_logger("serve")


# --------------------------------------------------------------------------- #
# flags -> spec
# --------------------------------------------------------------------------- #

def _tenant_sections(args) -> tuple:
    """``--tenants A,B`` (or ``gold:A,batch:B``) + per-tenant rate/SLO/arrival
    lists (singletons broadcast)."""
    tokens = [t.strip() for t in args.tenants.split(",") if t.strip()]

    def broadcast(raw, cast):
        vals = [cast(v) for v in str(raw).split(",")]
        if len(vals) == 1:
            vals *= len(tokens)
        if len(vals) != len(tokens):
            raise SystemExit(f"expected 1 or {len(tokens)} values, got {raw!r}")
        return vals

    rates = broadcast(args.rates, float)
    slos = broadcast(args.slos, float)
    procs = broadcast(args.arrival, str)
    classes = broadcast(getattr(args, "request_class", "scan"), str)
    sections = []
    for i, tok in enumerate(tokens):
        name, _, board_key = tok.partition(":")
        sections.append(TenantSection(
            name=name, board=board_key or name, rate=rates[i],
            arrival=procs[i], request_class=classes[i],
            slo_seconds=slos[i]))
    return tuple(sections)


def spec_from_args(args) -> DeploymentSpec:
    """The CLI's entire flag surface as one DeploymentSpec (validation —
    including the old ad-hoc flag checks — happens in the spec)."""
    mode = getattr(args, "mode", "sim")
    engine = getattr(args, "engine", "sim")
    n_gpu, n_cpu = getattr(args, "executors", (3, 1))

    plan_path = getattr(args, "plan", None) or ""
    placement = getattr(args, "placement", "greedy")
    if plan_path and placement == "search":
        raise SystemExit("--plan applies a saved placement verbatim; it "
                         "cannot be combined with --placement search "
                         "(use --trace to reuse a saved traffic trace)")
    fleet = FleetSection(
        devices=getattr(args, "devices", 1), gpu_per_device=n_gpu,
        cpu=n_cpu, links=getattr(args, "links", "shared"),
        replication=getattr(args, "replication", 0),
        peer_bw_gbps=getattr(args, "peer_bw", 0.0),
        placement="plan" if plan_path else placement,
        trace_path=getattr(args, "trace", None) or "",
        plan_path=plan_path)
    memory = MemorySection(
        tier=getattr(args, "tier", "numa"),
        prefetch=getattr(args, "prefetch", None),
        prefetch_trigger=getattr(args, "prefetch_trigger", None))
    policy = PolicySection(name=args.policy,
                           evict=getattr(args, "evict", None))
    serving = ServingSection(
        mode=mode, engine=engine,
        admission=getattr(args, "admission", "none"),
        max_queue=getattr(args, "max_queue", 200),
        bucket_rate=getattr(args, "bucket_rate", None),
        bucket_burst=getattr(args, "bucket_burst", 50.0),
        autoscale=getattr(args, "autoscale", "auto"),
        slo_priority=not getattr(args, "no_slo_priority", False),
        tick=getattr(args, "tick", 0.5))

    tenants: tuple = ()
    if mode == "online" and engine == "sim":
        model = ModelSpec(kind="tenants")
        tenants = _tenant_sections(args)
    elif mode == "online":
        if any("," in str(v) for v in (args.rates, args.slos, args.arrival)):
            raise SystemExit(
                "--engine real serves a single tenant over the tiny local "
                "CoE: pass scalar --rates/--slos/--arrival (multi-tenant "
                "mixes need --engine sim); --tenants is ignored here")
        model = ModelSpec(kind="tiny")
        # the tiny CoE's source draws uniformly at random — "random" is
        # served as asked; "scan" has no board-scan analogue here and also
        # gets the uniform stream (the Session reports it as served)
        tenants = (TenantSection(
            name="local", board="A", rate=float(args.rates),
            arrival=args.arrival, request_class=args.request_class,
            slo_seconds=float(args.slos)),)
    elif mode == "real":
        model = ModelSpec(kind="tiny")
    else:
        model = ModelSpec(kind="board", board=getattr(args, "board", "A"))

    hetero = HeteroSection(
        host_exec=getattr(args, "host_exec", False),
        cpu_multiplier=getattr(args, "cpu_multiplier", 0.0),
        host_place=getattr(args, "host_place", False))
    decode = DecodeSection(
        enabled=getattr(args, "decode", False),
        tokens=getattr(args, "decode_tokens", 24),
        kv_evict=getattr(args, "kv_evict", "kv_aware"),
        kv_budget_fraction=getattr(args, "kv_budget", 0.5))
    return DeploymentSpec(
        model=model, fleet=fleet, memory=memory, policy=policy,
        serving=serving,
        workload=WorkloadSection(requests=args.requests, tenants=tenants),
        hetero=hetero, decode=decode, seed=getattr(args, "seed", 0))


# --------------------------------------------------------------------------- #
# legacy runners (pre-spec downstream callers) — thin Session wrappers
# --------------------------------------------------------------------------- #

def run_sim(args) -> dict:
    return Session(spec_from_args(args)).run()


def run_real_mode(args) -> dict:
    return Session(spec_from_args(args)).run()


def run_online(args) -> dict:
    warnings.warn(
        "run_online(args) positional wiring is deprecated — build a "
        "DeploymentSpec (serving.mode='online') and run it through "
        "repro.api.Session",
        DeprecationWarning, stacklevel=2)
    return Session(spec_from_args(args)).run()


def run_online_real(args) -> dict:
    return Session(spec_from_args(args)).run()


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #

# dests that configure the run (with --config, any of them passed on the
# command line overrides just that field of the loaded spec; the artifact/io
# flags --out/--dump-config/--dump-trace/--save-plan always compose)
_CONFIG_DESTS = ("mode", "board", "tier", "policy", "evict", "prefetch",
                 "prefetch_trigger", "requests", "executors", "devices",
                 "links", "replication", "peer_bw", "placement", "trace",
                 "plan", "engine", "tenants", "arrival", "rates", "slos",
                 "request_class", "admission", "max_queue", "bucket_rate",
                 "bucket_burst", "autoscale", "no_slo_priority", "tick",
                 "host_exec", "cpu_multiplier", "host_place",
                 "decode", "decode_tokens", "kv_evict", "kv_budget", "seed")

# flag dest -> dotted spec path for the scalar overrides; the structural
# dests (executors, plan, no_slo_priority, the tenant-mix group) are mapped
# by hand in _resolve_spec
_DEST_PATHS = {
    "mode": "serving.mode", "engine": "serving.engine",
    "admission": "serving.admission", "max_queue": "serving.max_queue",
    "bucket_rate": "serving.bucket_rate",
    "bucket_burst": "serving.bucket_burst",
    "autoscale": "serving.autoscale", "tick": "serving.tick",
    "board": "model.board",
    "tier": "memory.tier", "prefetch": "memory.prefetch",
    "prefetch_trigger": "memory.prefetch_trigger",
    "policy": "policy.name", "evict": "policy.evict",
    "requests": "workload.requests",
    "devices": "fleet.devices", "links": "fleet.links",
    "replication": "fleet.replication", "peer_bw": "fleet.peer_bw_gbps",
    "placement": "fleet.placement", "trace": "fleet.trace_path",
    "host_exec": "hetero.host_exec",
    "cpu_multiplier": "hetero.cpu_multiplier",
    "host_place": "hetero.host_place",
    "decode": "decode.enabled", "decode_tokens": "decode.tokens",
    "kv_evict": "decode.kv_evict", "kv_budget": "decode.kv_budget_fraction",
    "seed": "seed",
}

# the tenant mix is one coherent group: overriding any of these rebuilds
# workload.tenants wholesale from the flag values (the flat comma-lists
# can't be partially merged into the file's structured tenant entries)
_TENANT_DESTS = ("tenants", "arrival", "rates", "slos", "request_class")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="SPEC_JSON",
                    help="run a saved DeploymentSpec; config flags passed "
                         "alongside override just those fields — flag > "
                         "file > default (docs/configuration.md)")
    ap.add_argument("--dump-config", default=None, metavar="PATH",
                    help="write the resolved DeploymentSpec JSON ('-' for "
                         "stdout) and exit without serving")
    ap.add_argument("--mode", default="sim", choices=["sim", "real", "online"])
    ap.add_argument("--board", default="A", choices=["A", "B"])
    ap.add_argument("--tier", default="numa", choices=["numa", "uma"])
    ap.add_argument("--policy", default="coserve", choices=list(POLICIES))
    ap.add_argument("--evict", default=None, choices=list(POLICY_NAMES),
                    help="override the policy's eviction order (e.g. "
                         "'observed' ranks victims by live per-expert load "
                         "with the dependency_prob order as cold-start "
                         "fallback); default: the policy's own setting")
    ap.add_argument("--prefetch", default=None,
                    choices=["off", "device", "all"],
                    help="override the policy's prefetch behaviour: off | "
                         "device (pool overlap only) | all (+ disk->host "
                         "promotion); default: the policy's own setting")
    ap.add_argument("--prefetch-trigger", default=None,
                    choices=["exec", "queue"],
                    help="when the cross-tier promotion fires: exec "
                         "(upstream starts executing, default) | queue "
                         "(upstream joins a queue — wider overlap window, "
                         "more speculative SSD traffic)")
    ap.add_argument("--requests", type=int, default=2500)
    ap.add_argument("--executors", type=lambda s: tuple(map(int, s.split(","))),
                    default=(3, 1), help="n_gpu,n_cpu (per device when "
                                         "--devices > 1)")
    ap.add_argument("--devices", type=int, default=1,
                    help="sim/online modes: number of accelerator devices, "
                         "each with its own pool behind the shared SSD")
    ap.add_argument("--links", default="shared",
                    choices=["shared", "per-device"],
                    help="host->device channel layout: one PCIe link the "
                         "whole fleet queues on, or one per accelerator")
    ap.add_argument("--replication", type=int, default=0,
                    help="planned device-pool copies of the hottest experts "
                         "beyond the primary (0 = paper placement)")
    ap.add_argument("--peer-bw", type=float, default=0.0,
                    help="device<->device (NVLink/ICI-class) peer fabric "
                         "bandwidth in GB/s; replicas of experts resident "
                         "on a sibling pool materialize pool->pool instead "
                         "of reloading from host DRAM (0 = no fabric)")
    ap.add_argument("--placement", default="greedy",
                    choices=["greedy", "search"],
                    help="initial expert placement: the greedy hot-first "
                         "sweep (paper §4.1) or the cost-model local search "
                         "over a workload trace (falls back to greedy when "
                         "nothing improves)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="--placement search: replay this saved "
                         "WorkloadTrace artifact (from --dump-trace) "
                         "instead of deriving a trace from the spec")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="apply a saved PlacementPlan artifact verbatim "
                         "(from --save-plan) — yesterday's search, no "
                         "re-search")
    ap.add_argument("--dump-trace", default=None, metavar="PATH",
                    help="after the run, save the observed per-expert "
                         "traffic as a WorkloadTrace artifact")
    ap.add_argument("--save-plan", default=None, metavar="PATH",
                    help="save the placement plan this run served")
    ap.add_argument("--trace-events", default=None, metavar="PATH",
                    help="record a full flight-recorder trace and save it "
                         "as Chrome trace JSON (Perfetto-loadable; analyze "
                         "with tools/trace_report.py) — shorthand for "
                         "observability.trace='full' + trace_path")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress informational output (warnings/errors "
                         "and --dump-config '-' data still print)")
    ap.add_argument("--verbose", action="store_true",
                    help="debug-level progress output")
    ap.add_argument("--out", default=None)
    # --- online-mode flags (repro.serve) ------------------------------- #
    ap.add_argument("--engine", default="sim", choices=["sim", "real"],
                    help="online mode: event-driven sim or real JAX experts")
    ap.add_argument("--tenants", default="A,B",
                    help="comma list of name[:board] tokens, boards A|B")
    ap.add_argument("--arrival", default="poisson",
                    help="arrival process per tenant (broadcasts): "
                         "poisson|bursty|diurnal|step")
    ap.add_argument("--rates", default="25",
                    help="mean req/s per tenant (broadcasts)")
    ap.add_argument("--slos", default="2.0",
                    help="end-to-end latency SLO seconds per tenant")
    ap.add_argument("--request-class", default="scan",
                    help="scan (board-scan locality) | random")
    ap.add_argument("--admission", default="none",
                    choices=["none", "queue_depth", "deadline", "token_bucket"])
    ap.add_argument("--max-queue", type=int, default=200)
    ap.add_argument("--bucket-rate", type=float, default=None,
                    help="token_bucket: admitted req/s per tenant "
                         "(default: the tenant mix's mean per-tenant rate)")
    ap.add_argument("--bucket-burst", type=float, default=50.0,
                    help="token_bucket: burst capacity in tokens")
    ap.add_argument("--autoscale", default="auto",
                    help="min,max executors; 'auto' = current fleet to 2x; "
                         "'none' disables scaling")
    ap.add_argument("--no-slo-priority", action="store_true",
                    help="disable deadline-EDF queue insertion")
    ap.add_argument("--tick", type=float, default=0.5,
                    help="telemetry/autoscaler control interval, sim seconds")
    # --- heterogeneous CPU co-execution -------------------------------- #
    ap.add_argument("--host-exec", action="store_true",
                    help="run host-DRAM-resident experts in place on the "
                         "CPU executors instead of stalling on a disk/PCIe "
                         "load; the scheduler prices min(execute_on_host, "
                         "load_then_execute_on_device) per arrival")
    ap.add_argument("--cpu-multiplier", type=float, default=0.0,
                    help="sim: derive the CPU service-time model as device "
                         "time x this factor (0 = the static measured CPU "
                         "constants; real mode measures the CPU line "
                         "directly)")
    ap.add_argument("--host-place", action="store_true",
                    help="--placement search: allow the search to plan "
                         "deliberate CPU residents (requires --host-exec)")
    # --- token-level decode (continuous batching + KV residency) -------- #
    ap.add_argument("--decode", action="store_true",
                    help="token-level decode: each request's terminal stage "
                         "becomes a prefill followed by a per-token decode "
                         "loop in a continuous batch, with paged KV blocks "
                         "resident in the executor's pool (sim and real "
                         "modes; online stays stage-level)")
    ap.add_argument("--decode-tokens", type=int, default=24,
                    help="decode length per request (the mean, for "
                         "decode.tokens_dist='geometric' specs)")
    ap.add_argument("--kv-evict", default="kv_aware",
                    choices=["kv_aware", "weight_only"],
                    help="under memory pressure: offload idle requests' KV "
                         "blocks to host DRAM (kv_aware) or keep KV pinned "
                         "and evict only expert weights (weight_only)")
    ap.add_argument("--kv-budget", type=float, default=0.5,
                    help="fraction of each device pool KV blocks may occupy "
                         "before offload/spill kicks in")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _deep_merge(base: dict, overlay: dict) -> dict:
    """Recursive dict merge, overlay wins; non-dict values replace."""
    out = dict(base)
    for k, v in overlay.items():
        if isinstance(v, dict) and isinstance(out.get(k), dict):
            out[k] = _deep_merge(out[k], v)
        else:
            out[k] = v
    return out


def _set_path(overlay: dict, dotted: str, value):
    node = overlay
    parts = dotted.split(".")
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = value


def _resolve_spec(args, ap: argparse.ArgumentParser) -> DeploymentSpec:
    """Flags-only, file-only, or partial override: flag > file > default.

    With --config, every config flag whose value differs from its parser
    default is deep-merged over the loaded spec (a flag explicitly set to
    its default value is indistinguishable from unset — edit the file for
    that). The merged dict re-enters ``DeploymentSpec.from_dict``, so
    cross-field validation runs eagerly on the final configuration."""
    if not args.config:
        return spec_from_args(args)
    spec = DeploymentSpec.load(args.config)
    overridden = [d for d in _CONFIG_DESTS
                  if getattr(args, d) != ap.get_default(d)]
    if not overridden:
        return spec
    overlay: dict = {}
    for d in overridden:
        if d in _TENANT_DESTS:
            continue                      # handled as a group below
        if d == "executors":
            n_gpu, n_cpu = args.executors
            _set_path(overlay, "fleet.gpu_per_device", n_gpu)
            _set_path(overlay, "fleet.cpu", n_cpu)
        elif d == "plan":
            _set_path(overlay, "fleet.plan_path", args.plan)
            _set_path(overlay, "fleet.placement", "plan")
        elif d == "no_slo_priority":
            _set_path(overlay, "serving.slo_priority", False)
        else:
            _set_path(overlay, _DEST_PATHS[d], getattr(args, d))
    if any(d in _TENANT_DESTS for d in overridden):
        overlay.setdefault("workload", {})["tenants"] = [
            t.to_dict() for t in _tenant_sections(args)]
    merged = _deep_merge(spec.to_dict(), overlay)
    try:
        return DeploymentSpec.from_dict(merged)
    except SpecError as e:
        flags = ", ".join("--" + d.replace("_", "-") for d in overridden)
        raise SpecError(
            f"merging {flags} over {args.config}: {e}") from None


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    try:
        obslog.set_level(obslog.level_from_flags(args.quiet, args.verbose))
    except ValueError as e:
        raise SystemExit(str(e))
    try:
        spec = _resolve_spec(args, ap)
    except SpecError as e:
        raise SystemExit(str(e))
    if args.trace_events:
        # shorthand: record at "full" unless the spec already opted into a
        # level, and auto-export to the given path after the run
        obs = dataclasses.replace(
            spec.observability,
            trace=spec.observability.trace
            if spec.observability.trace != "off" else "full",
            trace_path=args.trace_events)
        spec = dataclasses.replace(spec, observability=obs)

    if args.dump_config:
        if args.dump_config == "-":
            print(json.dumps(spec.to_dict(), indent=2, sort_keys=True))
        else:
            spec.save(args.dump_config)
            log.info(f"wrote {args.dump_config}")
        return spec.to_dict()

    log.debug(f"mode={spec.serving.mode} engine={spec.serving.engine} "
              f"policy={spec.policy.name} requests={spec.workload.requests}")
    try:
        sess = Session(spec)
    except (SpecError, ValueError) as e:
        raise SystemExit(str(e))
    result = sess.run()
    if args.dump_trace:
        sess.save_trace(args.dump_trace)
    if args.save_plan:
        sess.save_plan(args.save_plan)
    if args.trace_events:
        log.debug(f"wrote flight-recorder trace {args.trace_events}")
    log.info(json.dumps(result, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    main()
