"""Elastic executor scaling + graceful drain (large-scale runnability).

``ElasticController`` watches queue pressure on a periodic tick and grows or
shrinks the executor fleet between ``min_executors``/``max_executors``.
Scale-down is a *graceful drain*: the victim executor's queued groups are
re-scheduled through the dependency-aware scheduler (at-most-once, by request
id), exactly the path a node failure takes — so elasticity and fault
tolerance share one code path and one set of tests.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.serving import CoServeSystem, ExecutorSpec
from repro.core.simulator import ARRIVAL, INJECT, Simulation


@dataclasses.dataclass
class ElasticPolicy:
    min_executors: int = 1
    max_executors: int = 8
    scale_up_pending_s: float = 2.0    # avg queue time that triggers growth
    scale_down_pending_s: float = 0.2  # avg queue time that triggers shrink
    tick_s: float = 0.5
    cooldown_ticks: int = 2            # ticks between scaling actions


class ElasticController:
    """Periodic autoscaler driven through the simulator's INJECT events."""

    def __init__(self, system: CoServeSystem, spec: ExecutorSpec,
                 policy: ElasticPolicy = ElasticPolicy()):
        self.system = system
        self.spec = spec
        self.policy = policy
        self.actions: List[dict] = []
        self._cooldown = 0

    # ------------------------------------------------------------------ #
    def install(self, sim: Simulation, horizon_s: float):
        t = self.policy.tick_s
        while t <= horizon_s:
            sim.inject(t, self._tick)
            t += self.policy.tick_s

    # ------------------------------------------------------------------ #
    def _tick(self, sim: Simulation):
        live = self.system.live_executors()
        if not live:
            return
        if self._cooldown > 0:
            self._cooldown -= 1
            return
        pending = [ex.pending_time(sim.now) for ex in live]
        avg = sum(pending) / len(pending)
        if avg > self.policy.scale_up_pending_s \
                and len(live) < self.policy.max_executors:
            ex = self.system.add_executor(self.spec)
            self.actions.append(
                {"t": sim.now, "action": "add", "executor": ex.id,
                 "avg_pending": avg})
            self._cooldown = self.policy.cooldown_ticks
        elif avg < self.policy.scale_down_pending_s \
                and len(live) > self.policy.min_executors:
            victim = min(live, key=lambda e: e.pending_time(sim.now))
            self.drain(sim, victim)
            self.actions.append(
                {"t": sim.now, "action": "remove", "executor": victim.id,
                 "avg_pending": avg})
            self._cooldown = self.policy.cooldown_ticks

    # ------------------------------------------------------------------ #
    def drain(self, sim: Simulation, ex) -> None:
        """Graceful scale-down: re-schedule the victim's queued work."""
        orphans = self.system.fail_executor(ex, sim.now)
        for r in orphans:
            sim.push(sim.now, ARRIVAL, r)
        for peer in self.system.live_executors():
            sim.kick(peer, sim.now)
