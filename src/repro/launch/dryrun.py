import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
``.lower().compile()`` must succeed on the single-pod 16x16 mesh and the
2x16x16 multi-pod mesh for every assigned cell; ``memory_analysis()`` proves
the per-device footprint fits, ``cost_analysis()`` + the HLO collective sweep
feed EXPERIMENTS.md SSRoofline.

Usage:
  python -m repro.launch.dryrun --arch starcoder2_3b --shape train_4k
  python -m repro.launch.dryrun --sweep [--multi-pod] [--out out.json]
"""

import argparse
import json
import re
import time
import traceback

import jax  # noqa: E402  (must come after XLA_FLAGS)

from repro.configs import ARCH_IDS, applicable_shapes, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_cell, lower_cell  # noqa: E402


COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the optimized HLO."""
    from repro.launch.hlo import parse_collective_bytes
    return parse_collective_bytes(hlo_text)


def _compile_stats(arch, shape, mesh, n_periods=None) -> dict:
    # perf_counter: monotonic, so an NTP step mid-compile can't produce a
    # negative or wildly wrong duration (time.time() is wall clock)
    t0 = time.perf_counter()
    cell = build_cell(arch, shape, mesh, n_periods=n_periods)
    lowered = lower_cell(cell, mesh)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        "collective_bytes": coll,
        "n_periods": n_periods,
    }


def run_cell(arch: str, shape: str, mesh, verbose: bool = True,
             with_roofline: bool = True) -> dict:
    """Full-depth compile (validates sharding + memory) and, optionally,
    1-/2-period compiles to extrapolate true per-period costs (XLA counts a
    while-loop body once regardless of trip count)."""
    full = _compile_stats(arch, shape, mesh)
    cfg = get_config(arch)
    result = {"arch": arch, "shape": shape,
              "mesh": list(mesh.devices.shape), "ok": True, **full}

    if with_roofline:
        p1 = _compile_stats(arch, shape, mesh, n_periods=1)
        p2 = _compile_stats(arch, shape, mesh, n_periods=2)
        n = cfg.num_periods()

        def extrap(key):
            if key == "collective_bytes":
                kinds = set(p1[key]) | set(p2[key])
                return {k: p1[key].get(k, 0.0)
                        + (p2[key].get(k, 0.0) - p1[key].get(k, 0.0)) * (n - 1)
                        for k in kinds}
            return p1[key] + (p2[key] - p1[key]) * (n - 1)

        result["roofline"] = {
            "flops": extrap("flops"),
            "bytes_accessed": extrap("bytes_accessed"),
            "collective_bytes": extrap("collective_bytes"),
            "n_periods": n,
            "p1_flops": p1["flops"], "p2_flops": p2["flops"],
        }

    if verbose:
        r = result.get("roofline", full)
        coll = r["collective_bytes"]
        print(f"[{arch} x {shape} x {'x'.join(map(str, mesh.devices.shape))}] "
              f"ok: compile {full['compile_s']:.0f}s | "
              f"flops/dev {r['flops']:.3g} | "
              f"args {full['argument_bytes']/2**30:.2f} GiB | "
              f"temp {full['temp_bytes']/2**30:.2f} GiB | "
              f"coll {sum(coll.values())/2**20:.1f} MiB")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    results = []
    # incremental persistence: a crashed cell loses nothing
    def save():
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    if args.sweep:
        cells = [(a, s) for a in ARCH_IDS
                 for s in applicable_shapes(get_config(a))]
    else:
        cells = [(args.arch, args.shape)]

    failures = 0
    for mesh in meshes:
        single_pod = len(mesh.devices.shape) == 2
        for arch, shape in cells:
            try:
                results.append(run_cell(arch, shape, mesh,
                                        with_roofline=single_pod))
            except Exception as e:  # noqa: BLE001 — record and continue
                failures += 1
                results.append({
                    "arch": arch, "shape": shape,
                    "mesh": list(mesh.devices.shape), "ok": False,
                    "error": f"{type(e).__name__}: {e}"})
                print(f"[{arch} x {shape}] FAILED: {e}")
                traceback.print_exc()
            save()
    print(f"\n{len(results) - failures}/{len(results)} cells ok -> {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
