"""Deterministic synthetic data pipeline (LM tokens, audio frames, M-RoPE).

Documents-as-Markov-chains token stream: learnable structure (so the 100M
example's loss actually falls) while remaining fully offline/deterministic.
Sharded loading: each host materialises only its slice of the global batch
(``host_index``/``host_count``), matching multi-pod data loading.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4          # Markov out-degree: lower = more learnable
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        if self.global_batch % self.host_count:
            raise ValueError("global batch must divide across hosts")
        rng = np.random.RandomState(self.seed)
        v = self.vocab_size
        # sparse Markov transition table: v x branching successor ids
        self._succ = rng.randint(0, v, size=(v, self.branching)).astype(np.int32)

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.host_count

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a step: tokens + next-token labels."""
        b, s = self.local_batch, self.seq_len
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 131 + self.host_index) % (2**31))
        tokens = np.empty((b, s + 1), np.int32)
        tokens[:, 0] = rng.randint(0, self.vocab_size, size=b)
        choices = rng.randint(0, self.branching, size=(b, s))
        for t in range(s):
            tokens[:, t + 1] = self._succ[tokens[:, t], choices[:, t]]
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def batch_iterator(ds: SyntheticLMDataset, start_step: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield ds.batch(step)
        step += 1


def make_batch_for(cfg: ModelConfig, batch: int, seq: int, step: int = 0,
                   seed: int = 0) -> Dict[str, np.ndarray]:
    """One batch shaped for an architecture (adds modality-stub inputs)."""
    ds = SyntheticLMDataset(cfg.vocab_size if not cfg.logical_vocab_size
                            else cfg.logical_vocab_size,
                            seq, batch, seed=seed)
    out = dict(ds.batch(step))
    rng = np.random.RandomState(seed + step)
    if cfg.is_encoder_decoder:
        out["audio_embeds"] = rng.randn(
            batch, cfg.encoder_seq, cfg.d_model).astype(np.float32) * 0.02
    if cfg.mrope_sections:
        # stub vision frontend: text positions tripled (t=h=w), as for a
        # text-only segment; image patches would carry distinct h/w rows
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32)[None],
                              (batch, seq))
        out["positions"] = np.broadcast_to(pos[None], (3, batch, seq)).copy()
    return out
