from repro.data.pipeline import (SyntheticLMDataset, batch_iterator,
                                 make_batch_for)

__all__ = ["SyntheticLMDataset", "batch_iterator", "make_batch_for"]
