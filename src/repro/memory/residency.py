"""Per-tier residency: the device pool and the host-DRAM tier.

Source of truth: the only record of which experts occupy which tier's bytes
(capacity accounting, pin counts, in-flight markers) — ``MemoryHierarchy``
aggregates these per-tier views into the global ``Residency`` answer.

Both tiers track the same explicit per-expert state machine
(``tiers.Residency``) and both rank eviction victims through the shared
policy registry (``policies``). Two orderings are kept per tier — use order
(for LRU) and insertion order (for FIFO) — because the executor ``touch()``es
an expert on every batch: folding both into one counter silently turned FIFO
into LRU under load in the seed.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional

from repro.memory.policies import EvictionView, make_policy
from repro.memory.tiers import Residency

if TYPE_CHECKING:  # pragma: no cover — repro.core imports this package
    from repro.core.coe import CoEModel


class StateEpoch:
    """Monotone residency-transition counter shared across a hierarchy's
    tiers. Every membership change (pool add/remove, host insert/evict) and
    every ready-set transition bumps it, so consumers can validate cached
    derived state (settled peer holders, queue pending-time predictions)
    with one integer compare instead of rescanning tiers. Pin/unpin and
    LRU touches do NOT bump: they never change what a load would cost."""

    __slots__ = ("n",)

    def __init__(self):
        self.n = 0

    def bump(self):
        self.n += 1


class ReadySet(set):
    """``DevicePool.ready`` with transition tracking: tests and the warm
    placement path mutate the set directly (``pool.ready.add(eid)``), so the
    set itself bumps the shared epoch on any membership change — a settled
    copy appearing or vanishing invalidates peer-source and pending caches
    without those call sites knowing caches exist."""

    __slots__ = ("epoch",)

    def __init__(self, epoch: StateEpoch, iterable=()):
        super().__init__(iterable)
        self.epoch = epoch

    def add(self, eid):
        if eid not in self:
            self.epoch.bump()
        super().add(eid)

    def discard(self, eid):
        if eid in self:
            self.epoch.bump()
        super().discard(eid)

    def remove(self, eid):
        self.epoch.bump()
        super().remove(eid)

    def pop(self):
        self.epoch.bump()
        return super().pop()

    def clear(self):
        if self:
            self.epoch.bump()
        super().clear()

    def update(self, *others):
        self.epoch.bump()
        super().update(*others)

    def difference_update(self, *others):
        self.epoch.bump()
        super().difference_update(*others)


class DevicePool:
    """Device-memory expert pool (paper §4.1 'model pool').

    One pool per physical memory domain: executors on the same device (the
    paper's 3 GPU executors on one RTX3080Ti) *share* the pool — an expert
    loaded by one executor serves requests from all of them. Pinning is
    therefore counted (several executors may execute the same expert).
    """

    def __init__(self, capacity_bytes: int, coe: CoEModel, group: str = "",
                 epoch: Optional[StateEpoch] = None):
        self.capacity = capacity_bytes
        self.coe = coe
        self.group = group
        self.epoch = epoch if epoch is not None else StateEpoch()
        self.resident: Dict[str, int] = {}    # expert -> last-use counter
        self.insert_seq: Dict[str, int] = {}  # expert -> insertion counter
        self.pinned: Dict[str, int] = {}      # expert -> pin count
        self.ready: ReadySet = ReadySet(self.epoch)   # transfer complete
        self.loading: Dict[str, float] = {}   # expert -> expected done time
        self.used_bytes = 0
        # device bytes held by paged KV-cache blocks (token-level decode):
        # KV competes with expert weights for the same capacity, so
        # ``free_bytes`` subtracts both. Stays 0 when decode is off — the
        # arithmetic below is then bit-identical to the expert-only pool.
        self.kv_bytes = 0
        self.users: List = []                 # executors sharing this pool
        self._clock = 0

    def __contains__(self, expert_id: str) -> bool:
        return expert_id in self.resident

    def resident_ids(self) -> List[str]:
        return list(self.resident)

    def free_bytes(self) -> int:
        return self.capacity - self.used_bytes - self.kv_bytes

    def fits(self, expert_id: str) -> bool:
        return self.coe.spec(expert_id).mem_bytes <= self.capacity

    def touch(self, expert_id: str):
        self._clock += 1
        if expert_id in self.resident:
            self.resident[expert_id] = self._clock

    def pin(self, expert_id: str):
        self.pinned[expert_id] = self.pinned.get(expert_id, 0) + 1

    def unpin(self, expert_id: str):
        n = self.pinned.get(expert_id, 0) - 1
        if n <= 0:
            self.pinned.pop(expert_id, None)
        else:
            self.pinned[expert_id] = n

    def add(self, expert_id: str):
        size = self.coe.spec(expert_id).mem_bytes
        if size > self.free_bytes():
            raise MemoryError(
                f"pool overflow inserting {expert_id}: {size} > {self.free_bytes()}")
        self._clock += 1
        self.resident[expert_id] = self._clock
        self.insert_seq[expert_id] = self._clock
        self.used_bytes += size
        self.epoch.bump()

    def remove(self, expert_id: str):
        if expert_id in self.pinned:
            raise RuntimeError(f"evicting pinned expert {expert_id}")
        self.used_bytes -= self.coe.spec(expert_id).mem_bytes
        self.ready.discard(expert_id)
        self.insert_seq.pop(expert_id, None)
        del self.resident[expert_id]
        self.epoch.bump()

    def evictable(self) -> List[str]:
        return [e for e in self.resident
                if e not in self.pinned and e not in self.loading]

    # ------------------------------------------------------------------ #
    def residency(self, expert_id: str) -> Optional[Residency]:
        """This pool's view of the state machine (None = not here)."""
        if expert_id not in self.resident:
            return None
        if expert_id in self.pinned:
            return Residency.PINNED
        if expert_id in self.loading or expert_id not in self.ready:
            return Residency.LOADING
        return Residency.DEVICE

    def eviction_view(self, incoming_id: Optional[str] = None,
                      load_cost_fn=None, observed_load=None) -> EvictionView:
        cands = [e for e in self.evictable() if e != incoming_id]
        return EvictionView(coe=self.coe, candidates=cands,
                            use_order=self.resident,
                            insert_order=self.insert_seq,
                            resident=set(self.resident),
                            incoming_id=incoming_id,
                            load_cost_fn=load_cost_fn,
                            observed_load=observed_load)

    def snapshot(self) -> dict:
        return {"capacity_bytes": self.capacity,
                "used_bytes": self.used_bytes,
                "kv_bytes": self.kv_bytes,
                "resident": len(self.resident),
                "pinned": len(self.pinned),
                "loading": len(self.loading)}


class HostTier:
    """Host-DRAM expert cache shared by a device's executors (NUMA path).

    Evicted device experts fall back here; demand loads that pass through
    DRAM populate it; the cross-tier prefetcher promotes likely-next experts
    into it ahead of demand (``ready_at`` marks a promotion still in flight
    on the SSD link). Eviction order comes from the shared policy registry
    (probability-ordered for CoServe, LRU for the Samba-CoE baselines).
    """

    def __init__(self, capacity_bytes: int, coe: CoEModel, policy: str = "prob",
                 epoch: Optional[StateEpoch] = None):
        self.capacity = capacity_bytes
        self.coe = coe
        self.policy = policy
        self.epoch = epoch if epoch is not None else StateEpoch()
        self._strategy = make_policy(policy)
        self.resident: Dict[str, int] = {}   # expert -> last-use counter
        self.insert_seq: Dict[str, int] = {}
        self.ready_at: Dict[str, float] = {}  # promotion-in-flight done times
        self.used_bytes = 0
        self._clock = 0
        # live per-expert assignment counts ("observed" policy): the owning
        # CoServeSystem shares its expert_load dict here; None until wired
        self.observed_load = None

    def __contains__(self, expert_id: str) -> bool:
        return expert_id in self.resident

    def free_bytes(self) -> int:
        return self.capacity - self.used_bytes

    def touch(self, expert_id: str):
        self._clock += 1
        if expert_id in self.resident:
            self.resident[expert_id] = self._clock

    def ready_time(self, expert_id: str) -> float:
        """0.0 for settled residents; the SSD-leg completion time for an
        in-flight promotion."""
        return self.ready_at.get(expert_id, 0.0)

    def is_ready(self, expert_id: str, now: float) -> bool:
        return expert_id in self.resident \
            and self.ready_time(expert_id) <= now

    def insert(self, expert_id: str, ready_at: float = 0.0) -> List[str]:
        """Insert (evicting if needed); returns evicted ids.

        An expert larger than the whole tier can never fit: return early
        WITHOUT evicting (the seed emptied the entire cache and then failed
        to insert anyway — a destructive no-op).
        """
        if self.capacity <= 0:
            return []
        size = self.coe.spec(expert_id).mem_bytes
        if size > self.capacity:
            return []
        if expert_id in self.resident:
            self.touch(expert_id)
            # a settled copy never regresses to in-flight; an in-flight one
            # may settle (ready_at == 0) or keep its earlier completion
            if ready_at <= 0.0:
                self.ready_at.pop(expert_id, None)
            return []
        evicted = []
        while self.used_bytes + size > self.capacity and self.resident:
            victim = self._pick_victim()
            if victim is None:
                break
            evicted.append(victim)
            self._remove(victim)
        if self.used_bytes + size <= self.capacity:
            self._clock += 1
            self.resident[expert_id] = self._clock
            self.insert_seq[expert_id] = self._clock
            self.used_bytes += size
            if ready_at > 0.0:
                self.ready_at[expert_id] = ready_at
            self.epoch.bump()
        return evicted

    def _remove(self, expert_id: str):
        self.used_bytes -= self.coe.spec(expert_id).mem_bytes
        self.insert_seq.pop(expert_id, None)
        self.ready_at.pop(expert_id, None)
        del self.resident[expert_id]
        self.epoch.bump()

    def _pick_victim(self) -> Optional[str]:
        if not self.resident:
            return None
        order = self._strategy.order(EvictionView(
            coe=self.coe, candidates=list(self.resident),
            use_order=self.resident, insert_order=self.insert_seq,
            resident=set(self.resident), observed_load=self.observed_load))
        return order[0] if order else None

    def residency(self, expert_id: str) -> Optional[Residency]:
        return Residency.HOST if expert_id in self.resident else None

    def snapshot(self) -> dict:
        return {"capacity_bytes": self.capacity,
                "used_bytes": self.used_bytes,
                "resident": len(self.resident),
                "promotions_in_flight": len(self.ready_at)}
