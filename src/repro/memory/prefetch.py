"""Dependency-aware cross-tier prefetch (disk -> host ahead of demand).

Source of truth: the only issuer of speculative disk->host promotions, and
the owner of the speculation gates (``max_backlog_s`` /
``overlap_backlog_s``) that keep *all* speculative traffic — including the
executors' overlap prefetch, which asks ``speculation_ok`` — from queueing
ahead of demand loads.

The paper exploits the CoE dependency graph for device-pool *eviction*
(§4.3); the same property predicts *future loads*: while an upstream expert
executes, its likely downstream experts — weighted by the routing edge
probability times the downstream expert's pre-assessed P(use) — can be
promoted from disk into host DRAM so the eventual demand load pays only the
PCIe leg instead of the full SSD read (eMoE 2025 makes the same argument for
MoE gate predictions). Promotions ride the *shared* SSD channel, so the
prefetcher only issues them while the link is idle: a speculative read must
never queue ahead of demand traffic.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Set, Tuple

if TYPE_CHECKING:  # pragma: no cover — repro.core imports this package
    from repro.core.coe import CoEModel


@dataclasses.dataclass(frozen=True)
class PrefetchConfig:
    enabled: bool = True
    trigger: str = "exec"          # exec: promote when the upstream *starts
    #                                executing* (narrow window, demand-certain)
    #                                queue: promote when the upstream *joins a
    #                                queue* (wider overlap window, more
    #                                speculative SSD traffic — the downstream
    #                                demand is further from certain)
    min_weight: float = 0.01       # skip edges below this likelihood
    max_per_trigger: int = 2       # SSD reads issued per upstream execution
    max_backlog_s: float = 0.25    # only promote while the SSD link's queue
    #                                is shorter than this — a speculation must
    #                                not push demand traffic far back
    overlap_backlog_s: float = 1.0  # gate for device-pool overlap prefetch:
    #                                 its target has queued work (the load is
    #                                 certain, only its early issue order is
    #                                 speculative), so it tolerates a longer
    #                                 backlog than disk->host promotion


class CrossTierPrefetcher:
    """Promotes likely downstream experts disk -> host while their upstream
    executes. Owned by ``MemoryHierarchy``; inert on UMA (no host tier)."""

    def __init__(self, coe: "CoEModel", hierarchy, config: PrefetchConfig):
        if config.trigger not in ("exec", "queue"):
            raise ValueError(f"unknown prefetch trigger {config.trigger!r} "
                             "(expected 'exec' or 'queue')")
        self.coe = coe
        self.hierarchy = hierarchy
        self.config = config
        self.promotions = 0          # disk->host transfers issued
        self.promoted_bytes = 0      # speculative SSD traffic those cost
        self.hits = 0                # device loads served from a promotion
        self.evicted_unused = 0      # promotions lost from host before use
        self._promoted: Set[str] = set()

    # ------------------------------------------------------------------ #
    def candidates(self, upstream_id: str) -> List[Tuple[str, float]]:
        """(downstream expert, likelihood) pairs, most likely first.

        The routing module's ``chain_prob`` edges are the primary signal;
        declared ``depends_on`` edges without a routing probability fall back
        to the downstream expert's P(use) alone.
        """
        weights: Dict[str, float] = {}
        for nxt, cp in self.coe.routing.chain_prob.get(upstream_id, {}).items():
            p_use = self.coe.spec(nxt).usage_prob
            weights[nxt] = cp * (p_use if p_use > 0 else 1.0)
        for nxt in self.coe.downstream.get(upstream_id, []):
            weights.setdefault(nxt, self.coe.spec(nxt).usage_prob)
        return sorted(weights.items(), key=lambda kv: (-kv[1], kv[0]))

    # ------------------------------------------------------------------ #
    def on_execute(self, upstream_id: str, now: float):
        """Upstream expert starts executing: promote its likely followers.
        Fires under both triggers — with ``trigger="queue"`` the window
        *opens* at queue arrival, and execution start stays the last chance
        for anything the backlog gate deferred."""
        self._promote_followers(upstream_id, now)

    def on_enqueue(self, upstream_id: str, now: float):
        """Upstream expert joined a queue (group formed, not yet head): the
        queue-arrival trigger widens the overlap window to start here,
        buying more load/compute overlap per promotion but speculating
        further ahead of demand — the queued group may sit for a while, or
        the chain may never fire, so it costs more speculative SSD traffic."""
        if self.config.trigger == "queue":
            self._promote_followers(upstream_id, now)

    def _promote_followers(self, upstream_id: str, now: float):
        h = self.hierarchy
        if not self.config.enabled or h.host is None:
            return
        issued = 0
        for eid, w in self.candidates(upstream_id):
            if issued >= self.config.max_per_trigger:
                break
            if w < self.config.min_weight:
                break               # sorted descending: the rest are colder
            if eid in h.host or h.on_any_device(eid):
                continue            # already past the disk tier
            backlog = h.topology.disk_channel.busy_until - now
            if backlog > self.config.max_backlog_s:
                break               # demand traffic owns the SSD link
            mem = self.coe.spec(eid).mem_bytes
            if mem > h.host.capacity:
                continue
            leg = h.transfer.begin_host_promotion(now, mem, label=eid)
            evicted = h.host.insert(eid, ready_at=leg.done)
            # evicting settled host residents for a speculation is fine: the
            # policy already ranked them colder than this promotion's weight
            self.note_host_evictions(evicted)
            if eid in h.host:
                self.promotions += 1
                self.promoted_bytes += mem
                self._promoted.add(eid)
                issued += 1

    def note_host_evictions(self, evicted):
        """Promotions displaced from the host tier before any demand load
        saw them are wasted speculation — count them honestly."""
        self.evicted_unused += sum(1 for v in evicted if v in self._promoted)
        self._promoted.difference_update(evicted)

    def note_device_load(self, expert_id: str, served_from_host: bool):
        """Telemetry: a device load consumed (or missed) a promotion."""
        if expert_id in self._promoted:
            if served_from_host:
                self.hits += 1
            self._promoted.discard(expert_id)

    def snapshot(self) -> dict:
        return {"promotions": self.promotions, "hits": self.hits,
                "promoted_bytes": self.promoted_bytes,
                "trigger": self.config.trigger,
                "evicted_unused": self.evicted_unused,
                "outstanding": len(self._promoted)}
