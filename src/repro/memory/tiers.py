"""Tier topology: the storage hierarchy as a first-class object (paper §2.2).

A CoE catalog does not fit in device memory, so every expert lives somewhere
on a disk -> host DRAM -> device chain and serving is dominated by the
traffic between those tiers. ``TierSpec`` carries the per-device numbers
(bandwidths, fixed overheads, capacities); ``TierTopology`` instantiates the
shared transfer links between the tiers (one SSD link, one PCIe-class link)
so that *every* consumer — simulator, real engine, scheduler predictions,
profiler — sees the same hierarchy instead of re-deriving pieces of it.

UMA devices (the paper's Apple-M2-class board) collapse the middle tier:
there is no separate host cache and loads go disk -> unified memory over the
single storage link.

``Residency`` is the per-expert state machine the hierarchy tracks:

    DISK ──promote──> HOST ──load──> LOADING ──done──> DEVICE <──pin──> PINNED
      ^                 ^                                  │
      └── (never demoted past host) <──────evict───────────┘

On UMA the HOST state is skipped entirely.
"""
from __future__ import annotations

import dataclasses
import enum

from repro.memory.channels import TransferChannel


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Bandwidths in bytes/sec; fixed per-load overhead in seconds."""
    name: str
    disk_bw: float = 530e6           # paper NUMA: MICRON SSD 530 MB/s
    host_to_device_bw: float = 12e9  # PCIe-class host->HBM
    host_overhead: float = 0.010     # framework/layout overhead per load
    disk_overhead: float = 0.005
    unified: bool = False            # UMA: no separate host cache tier
    host_cache_bytes: int = 16 << 30
    device_bytes: int = 12 << 30


NUMA = TierSpec(name="numa", disk_bw=530e6, host_to_device_bw=12e9,
                unified=False, host_cache_bytes=16 << 30, device_bytes=12 << 30)
UMA = TierSpec(name="uma", disk_bw=3000e6, host_to_device_bw=40e9,
               host_overhead=0.030,  # paper: >60% of latency even on UMA
               unified=True, host_cache_bytes=0, device_bytes=24 << 30)
TPU_V5E = TierSpec(name="tpu_v5e", disk_bw=2000e6, host_to_device_bw=16e9,
                   unified=False, host_cache_bytes=128 << 30,
                   device_bytes=16 << 30)


class Residency(enum.Enum):
    """Where one expert currently lives in the hierarchy."""
    DISK = "disk"          # only on persistent storage
    HOST = "host"          # promoted into host DRAM (or promotion in flight)
    LOADING = "loading"    # transfer into a device pool in flight
    DEVICE = "device"      # resident and ready in a device pool
    PINNED = "pinned"      # resident and currently executing (un-evictable)


@dataclasses.dataclass
class TierTopology:
    """The shared links of one physical storage hierarchy.

    ``disk_channel`` is the SSD link (disk -> host on NUMA, disk -> unified
    memory on UMA); ``pcie_channel`` is the host -> device link (unused on
    UMA). All executors of one system share these two channels — concurrent
    transfers queue instead of each pretending it has the link to itself.
    """
    spec: TierSpec
    disk_channel: TransferChannel
    pcie_channel: TransferChannel

    @classmethod
    def from_spec(cls, spec: TierSpec) -> "TierTopology":
        return cls(
            spec=spec,
            disk_channel=TransferChannel(f"{spec.name}/ssd", spec.disk_bw),
            pcie_channel=TransferChannel(f"{spec.name}/pcie",
                                         spec.host_to_device_bw),
        )

    @property
    def unified(self) -> bool:
        return self.spec.unified
