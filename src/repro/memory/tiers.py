"""Tier topology: the storage hierarchy as a first-class object (paper §2.2).

Source of truth: this module owns the *shape* of the hierarchy — which links
exist and who rides which one. Pricing lives in ``transfer.TransferEngine``;
per-expert residency lives in ``residency``; this module only declares the
graph.

A CoE catalog does not fit in device memory, so every expert lives somewhere
on a disk -> host DRAM -> device chain and serving is dominated by the
traffic between those tiers. ``TierSpec`` carries the per-device numbers
(bandwidths, fixed overheads, capacities); ``TierTopology`` instantiates the
transfer links between the tiers as a per-device graph with three channel
classes: one SSD link that every device fans in on, one PCIe-class
host->device channel per accelerator (``links="per-device"``) or one channel
shared by the whole fleet (``links="shared"``, the single-board layout), and
— when ``TierSpec.peer_bw > 0`` — one NVLink/ICI-class *peer* ingress link
per device pool, so a replica of an expert already resident on a sibling
device materializes via a pool -> pool copy at peer bandwidth instead of a
host-DRAM reload over PCIe. Every consumer — simulator, real engine,
scheduler predictions, profiler — sees the same graph instead of
re-deriving pieces of it.

UMA devices (the paper's Apple-M2-class board) collapse the middle tier:
there is no separate host cache and loads go disk -> unified memory over the
single storage link.

``Residency`` is the per-expert state machine the hierarchy tracks:

    DISK ──promote──> HOST ──load──> LOADING ──done──> DEVICE <──pin──> PINNED
      ^                 ^                                  │
      └── (never demoted past host) <──────evict───────────┘

On UMA the HOST state is skipped entirely.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Sequence

from repro.memory.channels import TransferChannel

LINK_MODES = ("shared", "per-device")


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Bandwidths in bytes/sec; fixed per-load overhead in seconds."""
    name: str
    disk_bw: float = 530e6           # paper NUMA: MICRON SSD 530 MB/s
    host_to_device_bw: float = 12e9  # PCIe-class host->HBM
    host_overhead: float = 0.010     # framework/layout overhead per load
    disk_overhead: float = 0.005
    unified: bool = False            # UMA: no separate host cache tier
    host_cache_bytes: int = 16 << 30
    device_bytes: int = 12 << 30
    peer_bw: float = 0.0             # device<->device (NVLink/ICI-class)
    #                                  pool->pool copy bandwidth; 0 = no peer
    #                                  fabric (the single-board presets)
    peer_overhead: float = 0.002     # fixed per-copy overhead on the fabric


NUMA = TierSpec(name="numa", disk_bw=530e6, host_to_device_bw=12e9,
                unified=False, host_cache_bytes=16 << 30, device_bytes=12 << 30)
UMA = TierSpec(name="uma", disk_bw=3000e6, host_to_device_bw=40e9,
               host_overhead=0.030,  # paper: >60% of latency even on UMA
               unified=True, host_cache_bytes=0, device_bytes=24 << 30)
TPU_V5E = TierSpec(name="tpu_v5e", disk_bw=2000e6, host_to_device_bw=16e9,
                   unified=False, host_cache_bytes=128 << 30,
                   device_bytes=16 << 30)


class Residency(enum.Enum):
    """Where one expert currently lives in the hierarchy."""
    DISK = "disk"          # only on persistent storage
    HOST = "host"          # promoted into host DRAM (or promotion in flight)
    LOADING = "loading"    # transfer into a device pool in flight
    DEVICE = "device"      # resident and ready in a device pool
    PINNED = "pinned"      # resident and currently executing (un-evictable)


@dataclasses.dataclass
class TierTopology:
    """The link graph of one physical storage hierarchy.

    ``disk_channel`` is the SSD link (disk -> host on NUMA, disk -> unified
    memory on UMA); every device pool fans in on it. ``pcie_channels`` are
    the host -> device links (unused on UMA), keyed by device-pool group:
    with ``links="shared"`` there is exactly one channel (the single-board
    layout — every executor queues on it), with ``links="per-device"`` each
    accelerator pool gets its own channel, so two devices can pull experts
    from host DRAM concurrently while still contending on the one SSD.
    ``peer_channels`` are the third channel class (present only when the
    tier declares ``peer_bw``): per-pool NVLink/ICI ingress links for
    device -> device replica copies, keyed by the *destination* pool group —
    concurrent copies into one device queue on its ingress port while
    different devices receive concurrently. Concurrent transfers on one
    channel queue instead of each pretending it has the link to itself.
    """
    spec: TierSpec
    disk_channel: TransferChannel
    pcie_channels: Dict[str, TransferChannel]
    links: str = "shared"
    peer_channels: Dict[str, TransferChannel] = dataclasses.field(
        default_factory=dict)
    # plain attribute, not a property: ``TierSpec`` is frozen, so whether a
    # peer fabric exists is fixed at construction — and the scheduler's
    # assignment-cost path reads it per executor probe
    has_peer: bool = dataclasses.field(init=False)

    SHARED_KEY = ""   # pcie_channels key of the fleet-wide link (shared mode)

    def __post_init__(self):
        self.has_peer = self.spec.peer_bw > 0 and not self.spec.unified

    @classmethod
    def from_spec(cls, spec: TierSpec, groups: Sequence[str] = (),
                  links: str = "shared") -> "TierTopology":
        if links not in LINK_MODES:
            raise ValueError(f"unknown link mode {links!r} "
                             f"(expected one of {LINK_MODES})")
        if links == "per-device":
            chans = {g: TransferChannel(f"{spec.name}/pcie[{g}]",
                                        spec.host_to_device_bw)
                     for g in groups}
        else:
            chans = {cls.SHARED_KEY: TransferChannel(
                f"{spec.name}/pcie", spec.host_to_device_bw)}
        return cls(
            spec=spec,
            disk_channel=TransferChannel(f"{spec.name}/ssd", spec.disk_bw),
            pcie_channels=chans,
            links=links,
        )

    def pcie_for(self, group: str = "") -> TransferChannel:
        """The host->device channel a load into ``group``'s pool rides.
        Shared mode: the one fleet-wide link regardless of group. Per-device:
        the group's own link (created on first use for late-added pools)."""
        if self.links != "per-device":
            return self.pcie_channels[self.SHARED_KEY]
        ch = self.pcie_channels.get(group)
        if ch is None:
            ch = TransferChannel(f"{self.spec.name}/pcie[{group}]",
                                 self.spec.host_to_device_bw)
            self.pcie_channels[group] = ch
        return ch

    def peer_for(self, group: str) -> TransferChannel:
        """The peer ingress link a pool->pool copy into ``group`` rides
        (created on first use, like late-added per-device PCIe links).
        Only meaningful when the tier declares ``peer_bw``."""
        if not self.has_peer:
            raise ValueError(
                f"tier {self.spec.name!r} declares no peer fabric "
                "(peer_bw == 0 or unified memory)")
        ch = self.peer_channels.get(group)
        if ch is None:
            ch = TransferChannel(f"{self.spec.name}/peer[{group}]",
                                 self.spec.peer_bw)
            self.peer_channels[group] = ch
        return ch

    @property
    def pcie_channel(self) -> TransferChannel:
        """Single-link view (seed compat): the shared channel, or — per-device
        mode — the first device's channel. Group-aware callers should use
        ``pcie_for``."""
        if not self.pcie_channels:
            return self.pcie_for(self.SHARED_KEY)
        return next(iter(self.pcie_channels.values()))

    @property
    def unified(self) -> bool:
        return self.spec.unified
