"""Unified tiered-memory subsystem: disk -> host DRAM -> device residency.

The hierarchy the whole system reports against (paper §2.2, §4.3–4.4):

  ``TierSpec`` / ``TierTopology``   device numbers + the link graph (shared
                                    SSD fan-in, per-device or shared PCIe)
  ``TransferChannel``               one contended link (FIFO bandwidth sharing)
  ``TransferEngine``                the single load-latency source of truth
  ``DevicePool`` / ``HostTier``     per-tier residency with pluggable eviction
  ``Residency``                     DISK/HOST/LOADING/DEVICE/PINNED states
  ``CrossTierPrefetcher``           dependency-aware disk->host promotion
  ``MemoryHierarchy``               the facade CoServeSystem owns
"""
from repro.memory.channels import Transfer, TransferChannel
from repro.memory.hierarchy import MemoryHierarchy
from repro.memory.policies import (POLICY_NAMES, EvictionPolicy, EvictionView,
                                   make_policy)
from repro.memory.prefetch import CrossTierPrefetcher, PrefetchConfig
from repro.memory.residency import DevicePool, HostTier, ReadySet, StateEpoch
from repro.memory.tiers import (LINK_MODES, NUMA, TPU_V5E, UMA, Residency,
                                TierSpec, TierTopology)
from repro.memory.transfer import (TransferEngine, predicted_host_load_latency,
                                   predicted_load_latency)

__all__ = [
    "LINK_MODES", "Transfer", "TransferChannel", "MemoryHierarchy",
    "POLICY_NAMES",
    "EvictionPolicy", "EvictionView", "make_policy", "CrossTierPrefetcher",
    "PrefetchConfig", "DevicePool", "HostTier", "ReadySet", "StateEpoch",
    "NUMA", "TPU_V5E", "UMA",
    "Residency", "TierSpec", "TierTopology", "TransferEngine",
    "predicted_host_load_latency", "predicted_load_latency",
]
