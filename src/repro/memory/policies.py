"""Pluggable eviction policies, shared by every tier of the hierarchy.

Source of truth: the only place eviction *order* is defined — the device
pool's manager and the host tier both consume this registry, so a policy
name means the same ranking on every tier.

The seed hard-coded eviction orders twice: once in ``ExpertManager`` (device
pool) and once in ``HostCache._pick_victim`` (host tier), with subtly
different semantics. A policy is now one object implementing ``order``:
given the evictable candidates and a view of the tier, return them
best-victim-first. The device-pool manager and the host tier both consume
the same registry, so ``--policy``-style knobs mean the same thing on every
tier.

Policies (paper §4.3 + baselines + beyond-paper):

  dependency_prob  CoServe two-stage order: first *blocked* dependent
                   experts (no preliminary expert resident), by footprint
                   descending; then by pre-assessed P(use) ascending.
  prob             P(use) ascending (CoServe's stage 2 alone).
  lru              least-recently-used first (Samba-CoE history baseline).
  fifo             oldest *insertion* first — insertion order is tracked
                   separately from use order, so ``touch()`` (which the
                   executor calls on every batch) cannot perturb it. The
                   seed conflated the two counters, silently turning FIFO
                   into LRU under load.
  cost_benefit     P(use) * reload_cost / byte ascending (beyond-paper).
  observed         least *observed* load first (``CoServeSystem.expert_load``
                   assignment counts), with the ``dependency_prob`` order as
                   the cold-start fallback and tie-break — when traffic
                   diverges from the static priors (the regime the placement
                   search wins in), eviction stops thrashing the truly-hot
                   experts (ROADMAP "Eviction under wrong priors").
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, List, Mapping, Optional,
                    Set)

if TYPE_CHECKING:  # pragma: no cover — repro.core imports this package
    from repro.core.coe import CoEModel

POLICY_NAMES = ("dependency_prob", "lru", "fifo", "prob", "cost_benefit",
                "observed")


@dataclasses.dataclass
class EvictionView:
    """What a policy may look at when ranking victims on one tier."""
    coe: "CoEModel"
    candidates: List[str]                  # evictable experts on this tier
    use_order: Mapping[str, int]           # expert -> last-use counter
    insert_order: Mapping[str, int]        # expert -> insertion counter
    resident: Set[str]                     # everything resident on this tier
    incoming_id: Optional[str] = None      # expert the eviction makes room for
    load_cost_fn: Optional[Callable[[str], float]] = None
    observed_load: Optional[Mapping[str, float]] = None
    #                                      # live per-expert assignment counts
    #                                      # (CoServeSystem.expert_load); None
    #                                      # or empty = nothing observed yet


class EvictionPolicy:
    """Ranks eviction candidates, best victim first."""
    name = "base"

    def order(self, view: EvictionView) -> List[str]:
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    name = "lru"

    def order(self, view: EvictionView) -> List[str]:
        return sorted(view.candidates, key=lambda e: view.use_order[e])


class FIFOPolicy(EvictionPolicy):
    name = "fifo"

    def order(self, view: EvictionView) -> List[str]:
        return sorted(view.candidates, key=lambda e: view.insert_order[e])


class ProbPolicy(EvictionPolicy):
    name = "prob"

    def order(self, view: EvictionView) -> List[str]:
        return sorted(view.candidates,
                      key=lambda e: (view.coe.spec(e).usage_prob, e))


class CostBenefitPolicy(EvictionPolicy):
    name = "cost_benefit"

    def order(self, view: EvictionView) -> List[str]:
        def cb(eid: str):
            s = view.coe.spec(eid)
            reload_cost = view.load_cost_fn(eid) if view.load_cost_fn else 1.0
            return (s.usage_prob * reload_cost / max(1, s.mem_bytes), eid)
        return sorted(view.candidates, key=cb)


class DependencyProbPolicy(EvictionPolicy):
    """CoServe two-stage order (paper Fig. 10)."""
    name = "dependency_prob"

    def order(self, view: EvictionView) -> List[str]:
        resident = set(view.resident)
        if view.incoming_id is not None:
            resident.add(view.incoming_id)
        stage1, rest = [], []
        for eid in view.candidates:
            spec = view.coe.spec(eid)
            # blocked = a downstream expert none of whose preliminary experts
            # is resident: it cannot receive work until one of them loads
            blocked = spec.is_dependent and not any(
                up in resident for up in spec.depends_on)
            (stage1 if blocked else rest).append(eid)
        stage1.sort(key=lambda e: (-view.coe.spec(e).mem_bytes, e))
        rest.sort(key=lambda e: (view.coe.spec(e).usage_prob, e))
        return stage1 + rest


class ObservedLoadPolicy(EvictionPolicy):
    """Least observed load first; ``dependency_prob`` as cold-start fallback.

    ``view.observed_load`` carries the live assignment counts the system
    accumulated online. Before any traffic exists (or for experts that never
    received a request) the ranking degrades exactly to the two-stage
    ``dependency_prob`` order, so a cold system behaves like the paper's
    policy and diverging traffic re-ranks victims by what actually ran.
    """
    name = "observed"

    def order(self, view: EvictionView) -> List[str]:
        fallback = DependencyProbPolicy().order(view)
        if not view.observed_load:
            return fallback
        rank = {e: i for i, e in enumerate(fallback)}
        return sorted(view.candidates,
                      key=lambda e: (view.observed_load.get(e, 0), rank[e]))


_REGISTRY: Dict[str, type] = {p.name: p for p in (
    LRUPolicy, FIFOPolicy, ProbPolicy, CostBenefitPolicy,
    DependencyProbPolicy, ObservedLoadPolicy)}


def make_policy(name: str) -> EvictionPolicy:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(f"unknown eviction policy {name!r} "
                         f"(choose from {sorted(_REGISTRY)})") from None
