"""Shared transfer channels with bandwidth contention.

Source of truth: the only model of link occupancy — every contended
completion time in the system comes from ``TransferChannel.begin``, and
every backlog query reads ``busy_until`` here; no other code may track who
owns a link.

The seed modeled every executor's load path as a private link: N executors
could each stream an expert off the *same* SSD at full bandwidth. A
``TransferChannel`` is the corrected model: one physical link (SSD, PCIe)
that concurrent transfers must share. Transfers are serialized FIFO — a
transfer issued while the link is busy starts when the link frees, so two
same-instant loads finish in ~2x the time of one (the paper's §2.2
observation that switch traffic, not compute, is the bottleneck).

FIFO serialization (rather than processor-sharing) keeps completion times
final at issue time, which the event-driven simulator needs: a pushed
LOAD_DONE event never has to be re-scheduled, and per-link throughput is
identical to fair sharing for equal-size transfers.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Transfer:
    """One scheduled (possibly multi-leg) movement across the hierarchy."""
    issued: float         # when the transfer was requested
    start: float          # when a link first begins serving it
    done: float           # when the transfer completes
    host_landed: float = 0.0   # when the bytes reach host DRAM (two-leg
    #                            device loads: the SSD leg's completion;
    #                            0.0 when not applicable / already there)

    @property
    def wait(self) -> float:
        """Queueing delay before the first leg starts."""
        return self.start - self.issued

    @property
    def latency(self) -> float:
        """Issue-to-completion time (all waits + all service legs)."""
        return self.done - self.issued


class TransferChannel:
    """One shared link of the tier topology (SSD or PCIe class)."""

    def __init__(self, name: str, bandwidth: float):
        if bandwidth <= 0:
            raise ValueError(f"channel {name!r} needs positive bandwidth")
        self.name = name
        self.bandwidth = bandwidth
        self.busy_until = 0.0
        # --- stats (reported in Metrics.memory) ------------------------- #
        self.transfers = 0
        self.bytes_moved = 0
        self.busy_time = 0.0
        self.wait_time = 0.0

    def duration(self, nbytes: int, overhead: float = 0.0) -> float:
        """Uncontended service time for one transfer."""
        return overhead + nbytes / self.bandwidth

    def begin(self, now: float, nbytes: int,
              overhead: float = 0.0) -> Transfer:
        """Schedule a transfer; it queues behind anything already in flight."""
        start = max(now, self.busy_until)
        dur = self.duration(nbytes, overhead)
        done = start + dur
        self.busy_until = done
        self.transfers += 1
        self.bytes_moved += nbytes
        self.busy_time += dur
        self.wait_time += start - now
        return Transfer(issued=now, start=start, done=done)

    def idle_at(self, now: float) -> bool:
        return self.busy_until <= now

    def snapshot(self) -> dict:
        return {"transfers": self.transfers,
                "bytes_moved": self.bytes_moved,
                "busy_time_s": round(self.busy_time, 6),
                "wait_time_s": round(self.wait_time, 6)}
