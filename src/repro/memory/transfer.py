"""TransferEngine: the single source of truth for expert-switch cost.

Source of truth: this module is the ONLY load-latency formula in the system.
The seed computed load latency in three places (``core.memory.load_latency``,
``SimEngine.load_latency``, and the profiled values the real engine predicts
with) that could silently drift apart. Every path now goes through here:

  ``predicted_load_latency`` /  the closed-form uncontended cost — what the
  ``predicted_peer_copy_latency``  scheduler, work stealing, pending-time and
                               profiler use (decisions must not depend on
                               transient queue state);
  ``begin_device_load`` /      the *contended* cost — actual occupancy of the
  ``begin_host_load`` /        shared SSD / PCIe / peer channels, what the
  ``begin_host_promotion`` /   simulator charges a transfer when it really
  ``begin_peer_copy``          happens.

A transfer that finds its link busy queues behind the in-flight traffic, so
the simulated latency of a load is ``channel wait + service`` while its
predicted latency stays the service time alone.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.memory.channels import Transfer, TransferChannel
from repro.memory.tiers import TierSpec, TierTopology
from repro.obs.tracer import NULL_TRACER


def predicted_load_latency(spec: TierSpec, mem_bytes: int,
                           in_host_cache: bool) -> float:
    """Uncontended expert switch cost from its current tier into device
    memory (the paper's per-tier load-latency model, Fig. 4/5)."""
    if spec.unified or not in_host_cache:
        return spec.disk_overhead + spec.host_overhead + mem_bytes / spec.disk_bw \
            + (0.0 if spec.unified else mem_bytes / spec.host_to_device_bw)
    return spec.host_overhead + mem_bytes / spec.host_to_device_bw


def predicted_host_load_latency(spec: TierSpec, mem_bytes: int) -> float:
    """Uncontended disk -> host DRAM cost (CPU executors / promotions)."""
    return spec.disk_overhead + mem_bytes / spec.disk_bw


def predicted_peer_copy_latency(spec: TierSpec, mem_bytes: int) -> float:
    """Uncontended device -> device replica copy over the peer fabric."""
    if spec.peer_bw <= 0:
        raise ValueError(f"tier {spec.name!r} declares no peer fabric")
    return spec.peer_overhead + mem_bytes / spec.peer_bw


class TransferEngine:
    """Owns the shared channels of one ``TierTopology`` and prices every
    cross-tier movement on them."""

    def __init__(self, topology: TierTopology):
        self.topology = topology
        self.spec = topology.spec
        self.tracer = NULL_TRACER    # set by CoServeSystem when tracing
        # ``TierSpec`` is frozen, so every prediction is a pure function of
        # the byte count — memoized because the scheduler prices a load per
        # executor probe (128 probes per arrival at fleet scale)
        self._pred_memo: Dict[Tuple[int, bool], float] = {}
        self._peer_memo: Dict[int, float] = {}

    def _trace(self, ch: TransferChannel, leg: Transfer, mem_bytes: int,
               op: str, leg_name: str, label: str):
        """One ``xfer`` event per channel leg: the channel is the track,
        ``wait`` is the leg's time queued behind in-flight traffic."""
        self.tracer.emit(leg.start, "xfer", ch.name, label or op,
                         dur=leg.done - leg.start, op=op, leg=leg_name,
                         bytes=mem_bytes, wait=leg.wait)

    # --- predictions (uncontended, side-effect free) -------------------- #
    def predict(self, mem_bytes: int, in_host_cache: bool) -> float:
        key = (mem_bytes, in_host_cache)
        hit = self._pred_memo.get(key)
        if hit is None:
            hit = self._pred_memo[key] = predicted_load_latency(
                self.spec, mem_bytes, in_host_cache)
        return hit

    def predict_host(self, mem_bytes: int) -> float:
        return predicted_host_load_latency(self.spec, mem_bytes)

    def predict_peer(self, mem_bytes: int) -> float:
        hit = self._peer_memo.get(mem_bytes)
        if hit is None:
            hit = self._peer_memo[mem_bytes] = predicted_peer_copy_latency(
                self.spec, mem_bytes)
        return hit

    # --- contended transfers (occupy the shared links) ------------------ #
    def begin_device_load(self, now: float, mem_bytes: int,
                          in_host_cache: bool,
                          host_ready_at: float = 0.0,
                          group: str = "", label: str = "") -> Transfer:
        """Start moving an expert into device ``group``'s memory at ``now``.

        ``host_ready_at`` > now means a disk->host promotion of this expert
        is still in flight: the PCIe leg waits for it instead of re-reading
        the disk (the promotion already owns the SSD link). ``group`` selects
        the host->device channel (per-device link mode); the SSD fan-in is
        always shared.
        """
        t = self.spec
        traced = self.tracer.enabled
        if t.unified:
            # single unified-memory link: the whole load rides the SSD channel
            ch = self.topology.disk_channel
            leg = ch.begin(
                now, mem_bytes, overhead=t.disk_overhead + t.host_overhead)
            if traced:
                self._trace(ch, leg, mem_bytes, "device_load", "unified",
                            label)
            return leg
        pcie = self.topology.pcie_for(group)
        if in_host_cache:
            leg = pcie.begin(
                max(now, host_ready_at), mem_bytes, overhead=t.host_overhead)
            if traced:
                self._trace(pcie, leg, mem_bytes, "device_load", "pcie",
                            label)
            return Transfer(issued=now, start=leg.start, done=leg.done)
        # disk -> host -> device: the SSD leg then the PCIe leg, each
        # queueing on its own shared link
        disk_ch = self.topology.disk_channel
        disk_leg = disk_ch.begin(
            now, mem_bytes, overhead=t.disk_overhead)
        pcie_leg = pcie.begin(
            disk_leg.done, mem_bytes, overhead=t.host_overhead)
        if traced:
            self._trace(disk_ch, disk_leg, mem_bytes, "device_load", "disk",
                        label)
            self._trace(pcie, pcie_leg, mem_bytes, "device_load", "pcie",
                        label)
        return Transfer(issued=now, start=disk_leg.start, done=pcie_leg.done,
                        host_landed=disk_leg.done)

    def begin_host_load(self, now: float, mem_bytes: int,
                        label: str = "") -> Transfer:
        """Disk -> host DRAM on demand (CPU executors run from DRAM)."""
        ch = self.topology.disk_channel
        leg = ch.begin(now, mem_bytes, overhead=self.spec.disk_overhead)
        if self.tracer.enabled:
            self._trace(ch, leg, mem_bytes, "host_load", "disk", label)
        return leg

    def begin_host_promotion(self, now: float, mem_bytes: int,
                             label: str = "") -> Transfer:
        """Speculative disk -> host promotion (cross-tier prefetch)."""
        ch = self.topology.disk_channel
        leg = ch.begin(now, mem_bytes, overhead=self.spec.disk_overhead)
        if self.tracer.enabled:
            self._trace(ch, leg, mem_bytes, "promotion", "disk", label)
        return leg

    def begin_kv_offload(self, now: float, nbytes: int, group: str,
                         label: str = "") -> Transfer:
        """Device -> host spill of paged KV blocks: rides (and queues on)
        the group's host->device link in the reverse direction — the same
        contended channel expert loads ride, which is exactly why offloading
        idle KV competes with (and can defer) weight traffic."""
        ch = self.topology.disk_channel if self.spec.unified \
            else self.topology.pcie_for(group)
        leg = ch.begin(now, nbytes, overhead=self.spec.host_overhead)
        if self.tracer.enabled:
            self._trace(ch, leg, nbytes, "kv_offload", "pcie", label)
        return leg

    def begin_kv_reload(self, now: float, nbytes: int, group: str,
                        label: str = "") -> Transfer:
        """Host -> device reload of previously offloaded KV blocks: a batch
        whose KV was spilled pays this leg before its next decode step."""
        ch = self.topology.disk_channel if self.spec.unified \
            else self.topology.pcie_for(group)
        leg = ch.begin(now, nbytes, overhead=self.spec.host_overhead)
        if self.tracer.enabled:
            self._trace(ch, leg, nbytes, "kv_reload", "pcie", label)
        return leg

    def begin_peer_copy(self, now: float, mem_bytes: int,
                        group: str, label: str = "") -> Transfer:
        """Device -> device replica copy into ``group``'s pool over the peer
        fabric: rides (and queues on) the destination's peer ingress link
        only — neither the SSD fan-in nor any PCIe channel is touched, which
        is the whole point of materializing replicas pool -> pool."""
        ch = self.topology.peer_for(group)
        leg = ch.begin(now, mem_bytes, overhead=self.spec.peer_overhead)
        if self.tracer.enabled:
            self._trace(ch, leg, mem_bytes, "peer_copy", "peer", label)
        return leg

    # ------------------------------------------------------------------ #
    @staticmethod
    def _aggregate(per_link: dict) -> dict:
        agg = {"transfers": 0, "bytes_moved": 0,
               "busy_time_s": 0.0, "wait_time_s": 0.0}
        for snap in per_link.values():
            for k in agg:
                agg[k] += snap[k]
        agg["busy_time_s"] = round(agg["busy_time_s"], 6)
        agg["wait_time_s"] = round(agg["wait_time_s"], 6)
        return agg

    def snapshot(self) -> dict:
        """Per-link stats. ``disk_channel``/``pcie_channel`` keep the PR 2
        single-link keys (``pcie_channel`` aggregates across devices in
        per-device mode so existing bench trajectories stay comparable);
        ``pcie_channels``/``peer_channels`` break the host->device and
        device->device traffic out per link."""
        per_link = {ch.name: ch.snapshot()
                    for ch in self.topology.pcie_channels.values()}
        per_peer = {ch.name: ch.snapshot()
                    for ch in self.topology.peer_channels.values()}
        return {"disk_channel": self.topology.disk_channel.snapshot(),
                "pcie_channel": self._aggregate(per_link),
                "pcie_channels": per_link,
                "peer_channel": self._aggregate(per_peer),
                "peer_channels": per_peer,
                "links": self.topology.links}
